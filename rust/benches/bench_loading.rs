//! Trainer-side data-loading benchmark (Fig 8's datacenter tax):
//! serialize / encrypt / decrypt / deserialize tensor batches, and the
//! PJRT ingestion path when artifacts are present.

use dsi::dpp::TensorBatch;
use dsi::dwrf::crypto::StreamCipher;
use dsi::paper::harness::measure_loading_cost_per_byte;
use dsi::runtime::{artifacts_available, artifacts_dir, DlrmBatch, DlrmRuntime};
use dsi::schema::FeatureId;
use dsi::util::rng::Pcg32;
use dsi::util::timing::Bench;

fn make_batch(rng: &mut Pcg32, rows: usize) -> TensorBatch {
    let n_dense = 64;
    let mut sparse = Vec::new();
    for s in 0..16u32 {
        let mut offsets = vec![0u32];
        let mut ids = Vec::new();
        for _ in 0..rows {
            let n = rng.below(30) as usize;
            for _ in 0..n {
                ids.push(rng.below(1 << 20));
            }
            offsets.push(ids.len() as u32);
        }
        sparse.push((FeatureId(1000 + s), offsets, ids));
    }
    TensorBatch {
        rows,
        dense: (0..rows * n_dense).map(|_| rng.f32()).collect(),
        dense_names: (0..n_dense as u32).map(FeatureId).collect(),
        sparse,
        labels: vec![0.5; rows],
    }
}

fn main() {
    let mut rng = Pcg32::new(3);
    let tb = make_batch(&mut rng, 64);
    let cipher = StreamCipher::for_table("bench");
    let wire = tb.to_wire(&cipher, 1);
    println!("wire batch: {} rows, {} bytes", tb.rows, wire.len());

    Bench::print_header("client loading path (Fig 8 tax components)");
    let mut b = Bench::new();
    let n = wire.len() as u64;
    b.run("serialize", || {
        std::hint::black_box(tb.serialize());
        n
    });
    b.run("serialize+encrypt (worker tx)", || {
        std::hint::black_box(tb.to_wire(&cipher, 1));
        n
    });
    b.run("decrypt+deserialize (client rx)", || {
        std::hint::black_box(TensorBatch::from_wire(&cipher, 1, &wire).unwrap());
        n
    });
    let plain = tb.serialize();
    b.run("deserialize only", || {
        std::hint::black_box(TensorBatch::deserialize(&plain).unwrap());
        n
    });
    let per_byte = measure_loading_cost_per_byte(3);
    println!(
        "measured loading cost: {:.2} ns/byte → at RM1's 16.5 GB/s a \
         V100-node would spend {:.1} cores on loading",
        per_byte * 1e9,
        16.5e9 * per_byte / dsi::resources::HOST_CORE_EQUIV
    );

    if artifacts_available() {
        Bench::print_header("PJRT ingestion (tensor batch → DLRM step)");
        let rt = DlrmRuntime::load(&artifacts_dir()).unwrap();
        let mut params = rt.init_params(1).unwrap();
        let batch = DlrmBatch::synthetic(&rt.manifest, &mut rng);
        // Warm-up + measure steps/s.
        let t = std::time::Instant::now();
        let steps = 30;
        for _ in 0..steps {
            let (p, _) = rt.train_step(params, &batch).unwrap();
            params = p;
        }
        let dt = t.elapsed().as_secs_f64();
        println!(
            "train_step: {:.1} steps/s ({:.0} samples/s, batch {})",
            steps as f64 / dt,
            steps as f64 * rt.manifest.batch as f64 / dt,
            rt.manifest.batch
        );
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT bench)");
    }
}
