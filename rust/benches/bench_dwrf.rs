//! DWRF format benchmarks: write, plan, decode (map vs flattened;
//! checked vs fast decode; rows vs flatmap output) — the micro-level
//! levers behind Table 12's DPP row.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{generate_partition_samples, materialized_schema};
use dsi::dwrf::{
    DecodeMode, DwrfReader, DwrfWriter, Encoding, Projection, WriterOptions,
};
use dsi::schema::FeatureId;
use dsi::util::rng::Pcg32;
use dsi::util::timing::Bench;

fn main() {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale::bench();
    let mut rng = Pcg32::new(11);
    let schema = materialized_schema(&mut rng, &rm, &scale);
    let samples = generate_partition_samples(&mut rng, &schema, 2048, 0);
    let dense_ids: Vec<FeatureId> = schema.dense().map(|f| f.id).collect();
    let sparse_ids: Vec<FeatureId> = schema.sparse().map(|f| f.id).collect();
    let take = (schema.features.len() as f64 * rm.frac_feats_used()).round() as usize;
    let projection = Projection::new(
        schema.sample_projection(&mut rng, take, rm.popularity_zipf_s),
    );

    let build = |encoding: Encoding| -> Vec<u8> {
        let mut w = DwrfWriter::new(
            "bench",
            dense_ids.clone(),
            sparse_ids.clone(),
            WriterOptions {
                encoding,
                stripe_rows: 512,
                ..Default::default()
            },
        );
        w.write_all(samples.clone());
        w.finish()
    };

    Bench::print_header("DWRF write (2048 rows, 256 features)");
    let mut b = Bench::new();
    for (name, enc) in [("write/map", Encoding::Map), ("write/flattened", Encoding::Flattened)] {
        b.run(name, || {
            let bytes = build(enc);
            let n = bytes.len() as u64;
            std::hint::black_box(bytes);
            n
        });
    }

    let map_file = build(Encoding::Map);
    let flat_file = build(Encoding::Flattened);
    println!(
        "file sizes: map {} B, flattened {} B ({:+.1}% — the paper's FF \
         cost was +12% storage)",
        map_file.len(),
        flat_file.len(),
        (flat_file.len() as f64 / map_file.len() as f64 - 1.0) * 100.0
    );

    Bench::print_header("DWRF plan + decode under projection");
    let map_reader = DwrfReader::open_table(&map_file, "bench").unwrap();
    let flat_reader = DwrfReader::open_table(&flat_file, "bench").unwrap();
    let map_plan = map_reader.plan(&projection, None);
    let flat_plan = flat_reader.plan(&projection, None);
    let flat_plan_cr = flat_reader.plan(&projection, Some(1_310_720));
    println!(
        "plan: map reads {} B in {} I/Os; flattened {} B in {} I/Os; +CR {} \
         I/Os ({:.2}x over-read)",
        map_plan.read_bytes,
        map_plan.num_ios(),
        flat_plan.read_bytes,
        flat_plan.num_ios(),
        flat_plan_cr.num_ios(),
        flat_plan_cr.overread()
    );
    let map_bufs = map_reader.fetch_local(&map_file, &map_plan);
    let flat_bufs = flat_reader.fetch_local(&flat_file, &flat_plan);

    b.run("decode/map->rows", || {
        let mut n = 0u64;
        for s in 0..map_reader.meta.stripes.len() {
            let rows = map_reader
                .decode_stripe_rows(s, &map_bufs, &projection, DecodeMode::default())
                .unwrap();
            n += rows.len() as u64;
            std::hint::black_box(rows);
        }
        n * 100
    });
    b.run("decode/flat->rows (no FM)", || {
        let mut n = 0u64;
        for s in 0..flat_reader.meta.stripes.len() {
            let rows = flat_reader
                .decode_stripe_rows(s, &flat_bufs, &projection, DecodeMode::default())
                .unwrap();
            n += rows.len() as u64;
            std::hint::black_box(rows);
        }
        n * 100
    });
    b.run("decode/flat->columnar (FM) checked", || {
        let mut n = 0u64;
        for s in 0..flat_reader.meta.stripes.len() {
            let batch = flat_reader
                .decode_stripe_columnar(
                    s,
                    &flat_bufs,
                    &projection,
                    DecodeMode { fast: false },
                )
                .unwrap();
            n += batch.num_rows as u64;
            std::hint::black_box(batch);
        }
        n * 100
    });
    b.run("decode/flat->columnar (FM) fast (LO)", || {
        let mut n = 0u64;
        for s in 0..flat_reader.meta.stripes.len() {
            let batch = flat_reader
                .decode_stripe_columnar(
                    s,
                    &flat_bufs,
                    &projection,
                    DecodeMode { fast: true },
                )
                .unwrap();
            n += batch.num_rows as u64;
            std::hint::black_box(batch);
        }
        n * 100
    });
}
