//! Per-op transform microbenchmarks (Table 11's ops) + the §6.4 cycle
//! split on a representative session DAG.

use dsi::config::{RmConfig, RmId};
use dsi::data::ColumnarBatch;
use dsi::datagen::generate_partition_samples;
use dsi::schema::{FeatureId, FeatureKind, Schema};
use dsi::transforms::dag::session_dag;
use dsi::transforms::{Op, OpClass, Value};
use dsi::util::rng::Pcg32;
use dsi::util::timing::Bench;

fn sparse_value(rng: &mut Pcg32, rows: usize, avg_len: usize) -> Value {
    let mut offsets = vec![0u32];
    let mut ids = Vec::new();
    for _ in 0..rows {
        let n = rng.range(1, (avg_len * 2) as u64) as usize;
        for _ in 0..n {
            ids.push(rng.below(1 << 20));
        }
        offsets.push(ids.len() as u32);
    }
    Value::Sparse {
        offsets,
        ids,
        scores: None,
    }
}

fn main() {
    let mut rng = Pcg32::new(1);
    let rows = 512;
    let dense = Value::Dense((0..rows).map(|_| rng.f32() * 4.0 - 2.0).collect());
    let sparse = sparse_value(&mut rng, rows, 26);
    let sparse2 = sparse_value(&mut rng, rows, 26);

    Bench::print_header("transform ops (512-row batch, Table 11)");
    let mut b = Bench::new();
    let ops: Vec<(&str, Op, Vec<&Value>)> = vec![
        ("Clamp", Op::Clamp { lo: -1.0, hi: 1.0 }, vec![&dense]),
        ("Logit", Op::Logit { eps: 1e-4 }, vec![&dense]),
        ("BoxCox", Op::BoxCox { lambda: 0.5 }, vec![&dense]),
        ("Onehot", Op::Onehot { buckets: 64 }, vec![&dense]),
        (
            "GetLocalHour",
            Op::GetLocalHour {
                tz_offset_secs: -28800,
            },
            vec![&dense],
        ),
        (
            "Bucketize",
            Op::Bucketize {
                borders: (0..32).map(|i| i as f32 / 8.0 - 2.0).collect(),
            },
            vec![&dense],
        ),
        (
            "SigridHash",
            Op::SigridHash {
                salt: 3,
                modulus: 1 << 16,
            },
            vec![&sparse],
        ),
        ("FirstX", Op::FirstX { x: 16 }, vec![&sparse]),
        (
            "PositiveModulus",
            Op::PositiveModulus { modulus: 1000 },
            vec![&sparse],
        ),
        ("Enumerate", Op::Enumerate, vec![&sparse]),
        (
            "ComputeScore",
            Op::ComputeScore { mul: 2.0, add: 0.5 },
            vec![&sparse],
        ),
        (
            "MapId",
            Op::MapId {
                mapping: Default::default(),
                default: 1,
            },
            vec![&sparse],
        ),
        ("NGram", Op::NGram { n: 2 }, vec![&sparse]),
        ("Cartesian", Op::Cartesian, vec![&sparse, &sparse2]),
        (
            "IdListTransform",
            Op::IdListTransform,
            vec![&sparse, &sparse2],
        ),
        (
            "Sampling",
            Op::Sampling { rate: 0.5, seed: 1 },
            vec![&sparse],
        ),
    ];
    for (name, op, inputs) in &ops {
        let bytes = inputs.iter().map(|v| v.elements() * 8).sum::<usize>() as u64;
        b.run(name, || {
            let out = op.apply(inputs).unwrap();
            std::hint::black_box(&out);
            bytes
        });
    }

    // §6.4 cycle split on a full session DAG.
    Bench::print_header("session DAG cycle split (per RM, §6.4)");
    for id in RmId::ALL {
        let rm = RmConfig::get(id);
        let mut rng = Pcg32::new(7);
        let schema =
            Schema::synthetic(&mut rng, 120, 60, rm.avg_coverage, rm.avg_sparse_len);
        let samples = generate_partition_samples(&mut rng, &schema, 256, 0);
        let proj: Vec<FeatureId> =
            schema.features.iter().take(40).map(|f| f.id).collect();
        let dense_ids: Vec<FeatureId> = proj
            .iter()
            .filter(|f| {
                matches!(
                    schema.by_id(**f).map(|d| d.kind),
                    Some(FeatureKind::Dense)
                )
            })
            .copied()
            .collect();
        let sparse_ids: Vec<FeatureId> = proj
            .iter()
            .filter(|f| {
                !matches!(
                    schema.by_id(**f).map(|d| d.kind),
                    Some(FeatureKind::Dense)
                )
            })
            .copied()
            .collect();
        let batch = ColumnarBatch::from_samples(&samples, &dense_ids, &sparse_ids);
        let dag = session_dag(&mut rng, &rm, &schema, &proj);
        let (_, stats) = dag.execute(&batch).unwrap();
        let mut agg = stats;
        for _ in 0..4 {
            let (_, s) = dag.execute(&batch).unwrap();
            agg.merge(&s);
        }
        println!(
            "{}: feature-gen {:.0}% | sparse-norm {:.0}% | dense-norm {:.0}% \
             (paper: ~75/20/5)",
            rm.id.name(),
            agg.class_frac(OpClass::FeatureGen) * 100.0,
            agg.class_frac(OpClass::SparseNorm) * 100.0,
            agg.class_frac(OpClass::DenseNorm) * 100.0,
        );
    }
}
