//! Cross-job read-broker benchmark: N fully-overlapping sessions scan
//! the same table, independently vs through one shared [`ReadBroker`].
//! Reports total storage bytes read, broker hit rate, coalesced I/Os,
//! and saved bytes for N ∈ {1, 2, 4, 8}, verifies every brokered
//! session's wire output is byte-identical to the private-scan path,
//! and emits `target/broker_results.json`. CI criterion: 4 overlapping
//! sessions must cut total storage bytes read by >= 3x.
//!
//! A second, mixed-projection scenario runs 4 sessions whose
//! projections pairwise overlap on a popular core but each add private
//! features, with identical per-feature op chains (shared DAG
//! *prefixes*, distinct DAGs). It compares column-grain sharing
//! (`column_sharing = true` + a shared [`TransformCache`]) against the
//! stripe-grain ablation, gating on (a) byte-identical outputs in both
//! modes, (b) transform row-outputs actually skipped via cross-job
//! reuse, and (c) a lower broker resident-memory peak at column grain.

use dsi::broker::{MemoryBudget, ReadBroker};
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, TransformCache, WorkerCore};
use dsi::dwrf::WriterOptions;
use dsi::metrics::{EtlMetrics, Table};
use dsi::schema::{FeatureId, FeatureKind, Schema};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;

const SEED: u64 = 41;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
    /// Pairwise-overlapping sessions for the mixed-projection scenario.
    mixed: Vec<SessionSpec>,
}

fn build() -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 128,
            ..Default::default()
        },
        SEED,
        &GenOptions::default(),
    )
    .expect("build dataset");

    // A normalization session over ~25% of the features — the shape
    // every one of the N overlapping jobs runs.
    let mut rng = Pcg32::new(SEED ^ 0xB40C);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let spec = SessionSpec::from_dag(
        &h.table_name,
        0,
        u32::MAX,
        norm_dag(&h.schema, &proj),
        64,
    );

    // Mixed-projection sessions: a popular 8-feature core every session
    // shares, plus a private 6-feature slice each — so all pairs
    // overlap, but no projection contains another, and per-output
    // transform prefixes are identical exactly on the shared features.
    let pool: Vec<FeatureId> = h.schema.sample_projection(&mut rng, 32, 1.0);
    let mixed = (0..4)
        .map(|i| {
            let mut p: Vec<FeatureId> = pool[..8].to_vec();
            p.extend_from_slice(&pool[8 + 6 * i..8 + 6 * (i + 1)]);
            SessionSpec::from_dag(
                &h.table_name,
                0,
                u32::MAX,
                norm_dag(&h.schema, &p),
                64,
            )
        })
        .collect();
    World {
        cluster,
        catalog,
        spec,
        mixed,
    }
}

/// The per-feature normalization chain every benchmark session runs:
/// identical op parameters per feature, so two sessions projecting the
/// same feature share that output's whole DAG prefix.
fn norm_dag(schema: &Schema, proj: &[FeatureId]) -> TransformDag {
    let mut dag = TransformDag::default();
    for &fid in proj {
        match schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 11,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    dag
}

struct SessionRun {
    master: Master,
    core: WorkerCore,
    metrics: Arc<EtlMetrics>,
}

/// (seq, rows, dedup, bytes) per wire batch — enough to prove
/// byte-identity across paths.
type Wire = Vec<(u64, usize, bool, Vec<u8>)>;

fn new_session(world: &World, broker: Option<&Arc<ReadBroker>>) -> SessionRun {
    new_session_with(world, world.spec.clone(), broker, None)
}

fn new_session_with(
    world: &World,
    mut spec: SessionSpec,
    broker: Option<&Arc<ReadBroker>>,
    xform: Option<&Arc<TransformCache>>,
) -> SessionRun {
    spec.pipeline.shared_reads = broker.is_some();
    let master = match broker {
        Some(b) => Master::new_shared(
            &world.catalog,
            &world.cluster,
            spec.clone(),
            b,
        ),
        None => Master::new(&world.catalog, &world.cluster, spec.clone()),
    }
    .expect("master");
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(
        Arc::new(spec),
        world.cluster.clone(),
        metrics.clone(),
    );
    if let Some(h) = master.broker_handle() {
        core = core.with_broker(h);
    }
    if let Some(c) = xform {
        core = core.with_transform_cache(c.clone());
    }
    SessionRun {
        master,
        core,
        metrics,
    }
}

fn drain(run: &mut SessionRun) -> Wire {
    let w = run.master.register_worker();
    let mut wire = Wire::new();
    while let Some(split) = run.master.fetch_split(w) {
        for b in run.core.process_split(&split).expect("process split") {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        run.master.complete_split(w, split.id);
    }
    wire
}

/// One mixed-projection fleet run: the 4 pairwise-overlapping sessions
/// drained through one broker, at either sharing grain.
struct MixedRun {
    wires: Vec<Wire>,
    bytes_read: u64,
    transform_secs: f64,
    reuse_hits: u64,
    reused_rows: u64,
    column_hits: u64,
    column_fetches: u64,
    column_saved_bytes: u64,
    peak_resident: u64,
}

fn run_mixed(world: &World, column_sharing: bool) -> MixedRun {
    world.cluster.reset_stats();
    let budget = MemoryBudget::new(1 << 30);
    let broker = ReadBroker::new(world.cluster.clone(), budget.clone());
    // One transform cache across the whole fleet. The stripe-grain
    // ablation runs without it: that is the PR-3-era configuration the
    // column grain is measured against.
    let xform = if column_sharing {
        Some(Arc::new(TransformCache::new(256 << 20)))
    } else {
        None
    };
    let mut runs: Vec<SessionRun> = world
        .mixed
        .iter()
        .map(|s| {
            let mut spec = s.clone();
            spec.pipeline.column_sharing = column_sharing;
            new_session_with(world, spec, Some(&broker), xform.as_ref())
        })
        .collect();
    let wires: Vec<Wire> = runs.iter_mut().map(drain).collect();
    let mut transform_secs = 0.0;
    let mut reuse_hits = 0;
    let mut reused_rows = 0;
    for r in &runs {
        transform_secs += r.metrics.t_transform.secs();
        reuse_hits += r.metrics.transform_reuse_hits.get();
        reused_rows += r.metrics.transform_reused_rows.get();
    }
    MixedRun {
        wires,
        bytes_read: world.cluster.stats().bytes_read,
        transform_secs,
        reuse_hits,
        reused_rows,
        column_hits: broker.metrics.column_hits.get(),
        column_fetches: broker.metrics.column_fetches.get(),
        column_saved_bytes: broker.metrics.column_saved_bytes.get(),
        peak_resident: budget.peak(),
    }
}

fn main() {
    let world = build();

    // The private-scan reference output every brokered session must
    // reproduce byte-for-byte.
    let baseline_wire = drain(&mut new_session(&world, None));
    let total_rows: usize = baseline_wire.iter().map(|b| b.1).sum();

    let mut table = Table::new(
        "Cross-job shared reads: N fully-overlapping sessions \
         (RM1, 4096 rows), independent vs one ReadBroker",
        &[
            "N",
            "indep MB",
            "broker MB",
            "reduction",
            "hit rate",
            "coalesced I/Os",
            "saved MB",
            "identical",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_reduction = 0.0;
    let mut all_identical = true;
    for n in [1usize, 2, 4, 8] {
        // Independent: each session plans and fetches privately.
        world.cluster.reset_stats();
        for _ in 0..n {
            let wire = drain(&mut new_session(&world, None));
            assert_eq!(wire.len(), baseline_wire.len());
        }
        let indep_bytes = world.cluster.stats().bytes_read;

        // Brokered: all sessions registered up front (the concurrent-
        // jobs shape), then drained; each popular stripe is fetched and
        // decoded once.
        world.cluster.reset_stats();
        let broker =
            ReadBroker::new(world.cluster.clone(), MemoryBudget::new(1 << 30));
        let mut sessions: Vec<SessionRun> = (0..n)
            .map(|_| new_session(&world, Some(&broker)))
            .collect();
        let mut identical = true;
        for s in sessions.iter_mut() {
            let wire = drain(s);
            identical &= wire == baseline_wire;
        }
        let broker_bytes = world.cluster.stats().bytes_read;
        all_identical &= identical;

        let reduction = indep_bytes as f64 / broker_bytes.max(1) as f64;
        if n == 4 {
            crit_reduction = reduction;
        }
        let hit_rate = broker.metrics.hit_rate();
        table.row(&[
            format!("{n}"),
            format!("{:.2}", indep_bytes as f64 / 1e6),
            format!("{:.2}", broker_bytes as f64 / 1e6),
            format!("{reduction:.2}x"),
            format!("{hit_rate:.2}"),
            format!("{}", broker.metrics.coalesced_ios.get()),
            format!("{:.2}", broker.metrics.saved_bytes.get() as f64 / 1e6),
            format!("{identical}"),
        ]);
        let mut j = Json::obj();
        j.set("sessions", n as u64)
            .set("independent_bytes", indep_bytes)
            .set("broker_bytes", broker_bytes)
            .set("reduction", reduction)
            .set("broker_hit_rate", hit_rate)
            .set("shared_reads", broker.metrics.shared_reads.get())
            .set("broker_misses", broker.metrics.broker_misses.get())
            .set("saved_bytes", broker.metrics.saved_bytes.get())
            .set("coalesced_ios", broker.metrics.coalesced_ios.get())
            .set("outputs_identical", identical)
            .set("rows_per_session", total_rows as u64);
        arr.push(j);
    }
    table.print();

    // ---- Mixed projections with shared DAG prefixes: column grain vs
    // the stripe-grain ablation. ----
    // Per-spec private-scan references each brokered run must reproduce.
    let mixed_base: Vec<Wire> = world
        .mixed
        .iter()
        .map(|s| drain(&mut new_session_with(&world, s.clone(), None, None)))
        .collect();
    let col = run_mixed(&world, true);
    let ablation = run_mixed(&world, false);
    let col_identical = col.wires == mixed_base;
    let ablation_identical = ablation.wires == mixed_base;
    let transform_cut = col.reused_rows > 0 && col.column_hits > 0;
    let resident_cut = col.peak_resident < ablation.peak_resident;
    let mixed_pass =
        col_identical && ablation_identical && transform_cut && resident_cut;

    let mut mtable = Table::new(
        "Mixed projections: 4 sessions, 8 shared + 6 private features \
         each, identical per-feature op chains — column grain (+ shared \
         transform cache) vs the stripe-grain ablation",
        &[
            "grain",
            "MB read",
            "col hits",
            "col fetches",
            "xform reused rows",
            "xform s",
            "peak MB",
            "identical",
        ],
    );
    mtable.row(&[
        "column".to_string(),
        format!("{:.2}", col.bytes_read as f64 / 1e6),
        format!("{}", col.column_hits),
        format!("{}", col.column_fetches),
        format!("{}", col.reused_rows),
        format!("{:.3}", col.transform_secs),
        format!("{:.2}", col.peak_resident as f64 / 1e6),
        format!("{col_identical}"),
    ]);
    mtable.row(&[
        "stripe".to_string(),
        format!("{:.2}", ablation.bytes_read as f64 / 1e6),
        "-".to_string(),
        "-".to_string(),
        "0".to_string(),
        format!("{:.3}", ablation.transform_secs),
        format!("{:.2}", ablation.peak_resident as f64 / 1e6),
        format!("{ablation_identical}"),
    ]);
    mtable.print();
    println!(
        "\nmixed criterion: outputs byte-identical (column {col_identical}, \
         stripe ablation {ablation_identical}); transform row-outputs \
         skipped via cross-job reuse {} > 0: {transform_cut}; peak broker \
         resident bytes {} < {} (stripe grain): {resident_cut}: {}",
        col.reused_rows,
        col.peak_resident,
        ablation.peak_resident,
        if mixed_pass { "PASS" } else { "FAIL" }
    );

    let pass = crit_reduction >= 3.0 && all_identical && mixed_pass;
    println!(
        "\ncriterion @ N=4: storage-bytes reduction {crit_reduction:.2}x \
         (target >= 3x), per-session outputs byte-identical to the \
         non-broker path: {all_identical}: {}",
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("criterion_reduction_4x_sessions", crit_reduction);
    out.set("outputs_identical", all_identical);
    let mut mj = Json::obj();
    mj.set("sessions", 4u64)
        .set("column_bytes_read", col.bytes_read)
        .set("stripe_bytes_read", ablation.bytes_read)
        .set("column_hits", col.column_hits)
        .set("column_fetches", col.column_fetches)
        .set("column_saved_bytes", col.column_saved_bytes)
        .set("transform_reuse_hits", col.reuse_hits)
        .set("transform_reused_rows", col.reused_rows)
        .set("transform_secs_column", col.transform_secs)
        .set("transform_secs_stripe", ablation.transform_secs)
        .set("peak_resident_bytes_column", col.peak_resident)
        .set("peak_resident_bytes_stripe", ablation.peak_resident)
        .set("outputs_identical_column", col_identical)
        .set("outputs_identical_stripe_ablation", ablation_identical)
        .set("criterion_pass", mixed_pass);
    out.set("mixed_projection", mj);
    out.set("criterion_pass", pass);
    for path in dsi::util::bench::publish_results("broker", &out) {
        println!("wrote {path}");
    }
    // CI smoke: regressions that erode cross-job sharing below the
    // acceptance criterion fail the bench step.
    if !pass {
        std::process::exit(1);
    }
}
