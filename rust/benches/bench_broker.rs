//! Cross-job read-broker benchmark: N fully-overlapping sessions scan
//! the same table, independently vs through one shared [`ReadBroker`].
//! Reports total storage bytes read, broker hit rate, coalesced I/Os,
//! and saved bytes for N ∈ {1, 2, 4, 8}, verifies every brokered
//! session's wire output is byte-identical to the private-scan path,
//! and emits `target/broker_results.json`. CI criterion: 4 overlapping
//! sessions must cut total storage bytes read by >= 3x.

use dsi::broker::{MemoryBudget, ReadBroker};
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::WriterOptions;
use dsi::metrics::{EtlMetrics, Table};
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;

const SEED: u64 = 41;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
}

fn build() -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 128,
            ..Default::default()
        },
        SEED,
        &GenOptions::default(),
    )
    .expect("build dataset");

    // A normalization session over ~25% of the features — the shape
    // every one of the N overlapping jobs runs.
    let mut rng = Pcg32::new(SEED ^ 0xB40C);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let mut dag = TransformDag::default();
    for &fid in &proj {
        match h.schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 11,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 64);
    World {
        cluster,
        catalog,
        spec,
    }
}

struct SessionRun {
    master: Master,
    core: WorkerCore,
}

/// (seq, rows, dedup, bytes) per wire batch — enough to prove
/// byte-identity across paths.
type Wire = Vec<(u64, usize, bool, Vec<u8>)>;

fn new_session(world: &World, broker: Option<&Arc<ReadBroker>>) -> SessionRun {
    let mut spec = world.spec.clone();
    spec.pipeline.shared_reads = broker.is_some();
    let master = match broker {
        Some(b) => Master::new_shared(
            &world.catalog,
            &world.cluster,
            spec.clone(),
            b,
        ),
        None => Master::new(&world.catalog, &world.cluster, spec.clone()),
    }
    .expect("master");
    let metrics = Arc::new(EtlMetrics::default());
    let mut core =
        WorkerCore::new(Arc::new(spec), world.cluster.clone(), metrics);
    if let Some(h) = master.broker_handle() {
        core = core.with_broker(h);
    }
    SessionRun { master, core }
}

fn drain(run: &mut SessionRun) -> Wire {
    let w = run.master.register_worker();
    let mut wire = Wire::new();
    while let Some(split) = run.master.fetch_split(w) {
        for b in run.core.process_split(&split).expect("process split") {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        run.master.complete_split(w, split.id);
    }
    wire
}

fn main() {
    let world = build();

    // The private-scan reference output every brokered session must
    // reproduce byte-for-byte.
    let baseline_wire = drain(&mut new_session(&world, None));
    let total_rows: usize = baseline_wire.iter().map(|b| b.1).sum();

    let mut table = Table::new(
        "Cross-job shared reads: N fully-overlapping sessions \
         (RM1, 4096 rows), independent vs one ReadBroker",
        &[
            "N",
            "indep MB",
            "broker MB",
            "reduction",
            "hit rate",
            "coalesced I/Os",
            "saved MB",
            "identical",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_reduction = 0.0;
    let mut all_identical = true;
    for n in [1usize, 2, 4, 8] {
        // Independent: each session plans and fetches privately.
        world.cluster.reset_stats();
        for _ in 0..n {
            let wire = drain(&mut new_session(&world, None));
            assert_eq!(wire.len(), baseline_wire.len());
        }
        let indep_bytes = world.cluster.stats().bytes_read;

        // Brokered: all sessions registered up front (the concurrent-
        // jobs shape), then drained; each popular stripe is fetched and
        // decoded once.
        world.cluster.reset_stats();
        let broker =
            ReadBroker::new(world.cluster.clone(), MemoryBudget::new(1 << 30));
        let mut sessions: Vec<SessionRun> = (0..n)
            .map(|_| new_session(&world, Some(&broker)))
            .collect();
        let mut identical = true;
        for s in sessions.iter_mut() {
            let wire = drain(s);
            identical &= wire == baseline_wire;
        }
        let broker_bytes = world.cluster.stats().bytes_read;
        all_identical &= identical;

        let reduction = indep_bytes as f64 / broker_bytes.max(1) as f64;
        if n == 4 {
            crit_reduction = reduction;
        }
        let hit_rate = broker.metrics.hit_rate();
        table.row(&[
            format!("{n}"),
            format!("{:.2}", indep_bytes as f64 / 1e6),
            format!("{:.2}", broker_bytes as f64 / 1e6),
            format!("{reduction:.2}x"),
            format!("{hit_rate:.2}"),
            format!("{}", broker.metrics.coalesced_ios.get()),
            format!("{:.2}", broker.metrics.saved_bytes.get() as f64 / 1e6),
            format!("{identical}"),
        ]);
        let mut j = Json::obj();
        j.set("sessions", n as u64)
            .set("independent_bytes", indep_bytes)
            .set("broker_bytes", broker_bytes)
            .set("reduction", reduction)
            .set("broker_hit_rate", hit_rate)
            .set("shared_reads", broker.metrics.shared_reads.get())
            .set("broker_misses", broker.metrics.broker_misses.get())
            .set("saved_bytes", broker.metrics.saved_bytes.get())
            .set("coalesced_ios", broker.metrics.coalesced_ios.get())
            .set("outputs_identical", identical)
            .set("rows_per_session", total_rows as u64);
        arr.push(j);
    }
    table.print();

    let pass = crit_reduction >= 3.0 && all_identical;
    println!(
        "\ncriterion @ N=4: storage-bytes reduction {crit_reduction:.2}x \
         (target >= 3x), per-session outputs byte-identical to the \
         non-broker path: {all_identical}: {}",
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("criterion_reduction_4x_sessions", crit_reduction);
    out.set("outputs_identical", all_identical);
    out.set("criterion_pass", pass);
    let _ = std::fs::create_dir_all("target");
    let path = "target/broker_results.json";
    if std::fs::write(path, out.to_string_pretty()).is_ok() {
        println!("wrote {path}");
    }
    // CI smoke: regressions that erode cross-job sharing below the
    // acceptance criterion fail the bench step.
    if !pass {
        std::process::exit(1);
    }
}
