//! One-shot regeneration of every paper table/figure (`cargo bench`
//! umbrella target). Equivalent to `dsi paper --exp all` at standard
//! scale — prints the paper's reported rows next to measured values.

use dsi::config::SimScale;
use dsi::paper;

fn main() {
    let scale = SimScale::standard();
    let seed = 42;
    match paper::run_all(&scale, seed) {
        Ok(json) => {
            println!();
            for path in dsi::util::bench::publish_results("paper", &json) {
                println!("wrote {path}");
            }
        }
        Err(e) => {
            eprintln!("paper harness failed: {e:#}");
            std::process::exit(1);
        }
    }
}
