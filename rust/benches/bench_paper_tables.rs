//! One-shot regeneration of every paper table/figure (`cargo bench`
//! umbrella target). Equivalent to `dsi paper --exp all` at standard
//! scale — prints the paper's reported rows next to measured values.

use dsi::config::SimScale;
use dsi::paper;

fn main() {
    let scale = SimScale::standard();
    let seed = 42;
    match paper::run_all(&scale, seed) {
        Ok(json) => {
            let path = "target/paper_results.json";
            if std::fs::write(path, json.to_string_pretty()).is_ok() {
                println!("\nwrote {path}");
            }
        }
        Err(e) => {
            eprintln!("paper harness failed: {e:#}");
            std::process::exit(1);
        }
    }
}
