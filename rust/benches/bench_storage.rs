//! Storage-device model benchmarks: HDD vs SSD service under the I/O
//! patterns each Table 12 stage produces, plus raw cluster throughput.

use dsi::config::{DeviceSpec, SimScale};
use dsi::config::{RmConfig, RmId};
use dsi::dpp::PipelineOptions;
use dsi::dwrf::plan::COALESCE_WINDOW;
use dsi::dwrf::WriterOptions;
use dsi::paper::harness::{build_world, measure_pipeline, popularity_order};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::util::timing::Bench;

fn main() {
    // Raw device model: service times for the canonical patterns.
    Bench::print_header("device model service times");
    for dev in [DeviceSpec::hdd(), DeviceSpec::ssd()] {
        let small_random = dev.service_time(23_000, false);
        let coalesced = dev.service_time(1_250_000, false);
        let chunk_seq = dev.service_time(8 << 20, true);
        println!(
            "{:<14} 23KB random {:>8.2} ms | 1.25MB coalesced {:>7.2} ms | \
             8MB sequential {:>7.2} ms | max 4K IOPS {:>7.0}",
            dev.name,
            small_random * 1e3,
            coalesced * 1e3,
            chunk_seq * 1e3,
            dev.max_iops_4k()
        );
    }

    // Cluster read throughput (actual bytes + simulated device time).
    Bench::print_header("tectonic cluster reads (device-time accounted)");
    let cluster = Cluster::new(ClusterConfig::default());
    let f = cluster.create("bench");
    let data = vec![0xA5u8; 32 << 20];
    cluster.append(f, &data).unwrap();
    let mut b = Bench::new();
    b.run("read 8MB sequential-ish", || {
        cluster
            .read_range(
                f,
                dsi::dwrf::IoRange {
                    offset: 0,
                    len: 8 << 20,
                },
            )
            .unwrap();
        8 << 20
    });
    b.run("read 64x 20KB scattered", || {
        for i in 0..64u64 {
            cluster
                .read_range(
                    f,
                    dsi::dwrf::IoRange {
                        offset: (i * 517_123) % (30 << 20),
                        len: 20_000,
                    },
                )
                .unwrap();
        }
        64 * 20_000
    });
    let st = cluster.stats();
    println!(
        "cluster device accounting: {} reads, {} seeks, {:.1} device-sec, \
         {:.1} MB/s effective",
        st.reads,
        st.seeks,
        st.device_secs,
        st.read_mbps()
    );

    // End-to-end storage throughput per Table 12 layout (one partition).
    Bench::print_header("storage throughput by layout (RM1, Table 12 storage row)");
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 256,
        partitions: 2,
    };
    let probe = build_world(&rm, &scale, WriterOptions::default(), 5).unwrap();
    let order = popularity_order(&probe);
    let stages: Vec<(&str, WriterOptions, Option<u64>)> = vec![
        (
            "map (baseline)",
            WriterOptions {
                encoding: dsi::dwrf::Encoding::Map,
                stripe_rows: 128,
                ..Default::default()
            },
            None,
        ),
        (
            "FF",
            WriterOptions {
                stripe_rows: 128,
                ..Default::default()
            },
            None,
        ),
        (
            "FF+CR",
            WriterOptions {
                stripe_rows: 128,
                ..Default::default()
            },
            Some(COALESCE_WINDOW),
        ),
        (
            "FF+CR+FR",
            WriterOptions {
                stripe_rows: 128,
                feature_order: Some(order.clone()),
                ..Default::default()
            },
            Some(COALESCE_WINDOW),
        ),
        (
            "FF+CR+FR+LS",
            WriterOptions {
                stripe_rows: 1024,
                feature_order: Some(order),
                ..Default::default()
            },
            Some(COALESCE_WINDOW),
        ),
    ];
    for (name, writer, window) in stages {
        let world = build_world(&rm, &scale, writer, 5).unwrap();
        let pipeline = PipelineOptions {
            coalesce: window,
            ..Default::default()
        };
        let m = measure_pipeline(&world, pipeline, 64, 5).unwrap();
        println!(
            "{:<14} {:>9.1} MB/s storage | {:>7} reads | {:>7} seeks | \
             {:>8.0} rows/s worker",
            name, m.storage_mbps, m.storage.reads, m.storage.seeks, m.worker_sps
        );
    }
}
