//! RecD-style dedup benchmark: the paper-style table of what end-to-end
//! sample deduplication buys at each duplication factor — warehouse
//! bytes stored, storage bytes read, and preprocessing rows transformed,
//! DedupDWRF + dedup-aware DPP versus the flattened baseline on the
//! *same* sample multiset. Also emits `target/dedup_results.json`
//! alongside the other machine-readable paper tables.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset_dup;
use dsi::dedup::scan_table;
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::{Encoding, WriterOptions};
use dsi::metrics::{EtlMetrics, Table};
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

struct StageOut {
    stored_bytes: u64,
    read_bytes: u64,
    transform_rows: u64,
    samples: u64,
    tensor_tx_bytes: u64,
    wall_secs: f64,
    observed_factor: f64,
}

fn run_stage(encoding: Encoding, dup: usize, seed: u64) -> StageOut {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = dsi::warehouse::Catalog::new();
    let h = build_dataset_dup(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            encoding,
            stripe_rows: 256,
            ..Default::default()
        },
        seed,
        dup,
    )
    .expect("build dataset");
    let stored_bytes = catalog.get(&h.table_name).unwrap().total_bytes();
    let observed = scan_table(&cluster, &catalog, &h.table_name)
        .expect("scan")
        .within_partition()
        .factor();

    // A normalization session over ~25% of the features.
    let mut rng = Pcg32::new(seed ^ 0xbeef);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let mut dag = TransformDag::default();
    for &fid in &proj {
        match h.schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 7,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    let spec = Arc::new(SessionSpec::from_dag(
        &h.table_name,
        0,
        u32::MAX,
        dag,
        64,
    ));

    let master =
        Master::new(&catalog, &cluster, (*spec).clone()).expect("master");
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec, cluster.clone(), metrics.clone());
    cluster.reset_stats();
    let t = Instant::now();
    while let Some(split) = master.fetch_split(w) {
        core.process_split(&split).expect("process split");
        master.complete_split(w, split.id);
    }
    StageOut {
        stored_bytes,
        read_bytes: metrics.storage_rx_bytes.get(),
        transform_rows: metrics.transform_rows.get(),
        samples: metrics.samples.get(),
        tensor_tx_bytes: metrics.tensor_tx_bytes.get(),
        wall_secs: t.elapsed().as_secs_f64(),
        observed_factor: observed,
    }
}

fn main() {
    let seed = 17;
    let mut table = Table::new(
        "End-to-end dedup savings (DedupDWRF + dedup-aware DPP vs \
         flattened baseline, RM1, 4096 rows)",
        &[
            "dup",
            "observed",
            "stored MB (flat/dedup)",
            "stored x",
            "read MB (flat/dedup)",
            "read x",
            "preproc rows (flat/dedup)",
            "preproc x",
            "wire x",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_stored = 0.0;
    let mut crit_preproc = 0.0;
    for dup in [1usize, 2, 4, 8] {
        let flat = run_stage(Encoding::Flattened, dup, seed);
        let dd = run_stage(Encoding::Dedup, dup, seed);
        assert_eq!(flat.samples, dd.samples, "both paths deliver every row");
        let stored_x = flat.stored_bytes as f64 / dd.stored_bytes.max(1) as f64;
        let read_x = flat.read_bytes as f64 / dd.read_bytes.max(1) as f64;
        let preproc_x =
            flat.transform_rows as f64 / dd.transform_rows.max(1) as f64;
        let wire_x =
            flat.tensor_tx_bytes as f64 / dd.tensor_tx_bytes.max(1) as f64;
        if dup == 4 {
            crit_stored = stored_x;
            crit_preproc = preproc_x;
        }
        table.row(&[
            format!("{dup}"),
            format!("{:.2}", dd.observed_factor),
            format!(
                "{:.2}/{:.2}",
                flat.stored_bytes as f64 / 1e6,
                dd.stored_bytes as f64 / 1e6
            ),
            format!("{stored_x:.2}"),
            format!(
                "{:.2}/{:.2}",
                flat.read_bytes as f64 / 1e6,
                dd.read_bytes as f64 / 1e6
            ),
            format!("{read_x:.2}"),
            format!("{}/{}", flat.transform_rows, dd.transform_rows),
            format!("{preproc_x:.2}"),
            format!("{wire_x:.2}"),
        ]);
        let mut j = Json::obj();
        j.set("dup_factor", dup)
            .set("observed_factor", dd.observed_factor)
            .set("flat_stored_bytes", flat.stored_bytes)
            .set("dedup_stored_bytes", dd.stored_bytes)
            .set("stored_reduction", stored_x)
            .set("flat_read_bytes", flat.read_bytes)
            .set("dedup_read_bytes", dd.read_bytes)
            .set("read_reduction", read_x)
            .set("flat_preproc_rows", flat.transform_rows)
            .set("dedup_preproc_rows", dd.transform_rows)
            .set("preproc_reduction", preproc_x)
            .set("wire_reduction", wire_x)
            .set("flat_wall_secs", flat.wall_secs)
            .set("dedup_wall_secs", dd.wall_secs);
        arr.push(j);
    }
    table.print();
    let pass = crit_stored >= 2.0 && crit_preproc >= 2.0;
    println!(
        "\ncriterion @ dup=4: stored-bytes reduction {crit_stored:.2}x, \
         preprocessing-ops reduction {crit_preproc:.2}x (target >= 2x \
         each): {}",
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("criterion_pass", pass);
    for path in dsi::util::bench::publish_results("dedup", &out) {
        println!("wrote {path}");
    }
    // The CI smoke step relies on this exit code to catch regressions
    // that erode the dedup savings below the acceptance criterion.
    if !pass {
        std::process::exit(1);
    }
}
