//! End-to-end DPP worker pipeline benchmark per RM (Table 9's kQPS and
//! byte-rate columns) and the threaded-session throughput scaling.

use dsi::config::{NodeSpec, RmConfig, SimScale};
use dsi::dpp::{PipelineOptions, Session, SessionConfig, SessionSpec};
use dsi::dwrf::{Projection, WriterOptions};
use dsi::paper::harness::{build_world, measure_pipeline};
use dsi::resources::saturation;
use dsi::transforms::dag::session_dag;
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use std::time::Duration;

fn main() {
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 256,
        partitions: 2,
    };
    println!("\n=== worker pipeline per RM (single thread, real bytes) ===");
    for rm in RmConfig::all() {
        let world = build_world(&rm, &scale, WriterOptions::default(), 9).unwrap();
        let m = measure_pipeline(&world, PipelineOptions::default(), 64, 9).unwrap();
        let sat = saturation(&m.cost, &NodeSpec::c_v1());
        println!(
            "{}: {:>8.0} rows/s measured | cpu/sample {:>8.1} µs | \
             storage rx {:>6.1} KB/sample | tensor tx {:>6.1} KB/sample | \
             C-v1 saturation {:>8.0} rows/s ({})",
            rm.id.name(),
            m.worker_sps,
            m.cost.cpu_secs * 1e6,
            m.cost.net_rx_bytes / 1e3,
            m.cost.net_tx_bytes / 1e3,
            sat.max_samples_per_sec,
            sat.bottleneck.name(),
        );
    }

    println!("\n=== threaded session scaling (RM3) ===");
    let rm = RmConfig::get(dsi::config::RmId::Rm3);
    let world = build_world(&rm, &scale, WriterOptions::default(), 9).unwrap();
    for workers in [1usize, 2, 4] {
        let mut rng = Pcg32::new(17);
        let dag = session_dag(&mut rng, &rm, &world.schema, &world.projection);
        let mut spec =
            SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, 64);
        spec.projection = Projection::new(world.projection.iter().copied());
        let report = Session::run(
            &world.catalog,
            &world.cluster,
            spec,
            &SessionConfig {
                initial_workers: workers,
                max_workers: workers,
                clients: 1,
                ..Default::default()
            },
        )
        .unwrap();
        println!(
            "{} workers: {:>8.0} rows/s wall | {} rows | stall {:.3}s",
            workers,
            report.rows_per_sec,
            report.rows_delivered,
            report.client_stall_secs
        );
    }

    // Tracing overhead: the same 2-worker session once plain, once with
    // spans + telemetry on (informational — the acceptance bar for the
    // *untraced* path is held by the scaling runs above staying flat).
    println!("\n=== tracing overhead (RM3, 2 workers) ===");
    let run_rm3 = |tracing: bool| {
        let mut rng = Pcg32::new(17);
        let dag = session_dag(&mut rng, &rm, &world.schema, &world.projection);
        let mut spec =
            SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, 64);
        spec.projection = Projection::new(world.projection.iter().copied());
        spec.pipeline.tracing = tracing;
        Session::run(
            &world.catalog,
            &world.cluster,
            spec,
            &SessionConfig {
                initial_workers: 2,
                max_workers: 2,
                clients: 1,
                telemetry_every: tracing
                    .then_some(Duration::from_millis(10)),
                ..Default::default()
            },
        )
        .unwrap()
    };
    let plain = run_rm3(false);
    let traced = run_rm3(true);
    let overhead = 1.0 - traced.rows_per_sec / plain.rows_per_sec.max(1e-9);
    println!(
        "plain {:>8.0} rows/s | traced {:>8.0} rows/s | overhead {:+.1}% | \
         {} spans | stall: {}",
        plain.rows_per_sec,
        traced.rows_per_sec,
        overhead * 100.0,
        traced.obs.as_ref().map_or(0, |o| o.trace.len()),
        traced.stall_attribution.dominant(),
    );
    let obs = traced.obs.as_ref().expect("traced run has a sink");
    let mut out = Json::obj();
    out.set("stage_histograms", obs.histograms_json())
        .set("stall_attribution", traced.stall_attribution.to_json())
        .set("rows_per_sec_plain", plain.rows_per_sec)
        .set("rows_per_sec_traced", traced.rows_per_sec)
        .set("tracing_overhead_frac", overhead)
        .set("spans", obs.trace.len() as u64)
        .set("spans_dropped", obs.trace.dropped());
    if let Some(t) = &traced.telemetry {
        out.set("telemetry", t.to_json());
    }
    let _ = std::fs::create_dir_all("target");
    let path = "target/worker_telemetry.json";
    if std::fs::write(path, out.to_string_pretty()).is_ok() {
        println!("wrote {path}");
    }
}
