//! End-to-end DPP worker pipeline benchmark per RM (Table 9's kQPS and
//! byte-rate columns), the threaded-session throughput scaling, and the
//! wire-compression sweep (levels x duplication) with its CI gate:
//! zstd level 3 must cut dup=4 wire bytes >= 2x with byte-identical
//! decoded batches.

use dsi::config::{NodeSpec, RmConfig, RmId, SimScale};
use dsi::datagen::build_dataset_dup;
use dsi::dpp::{
    Master, PipelineOptions, Session, SessionConfig, SessionSpec,
    TensorBatch, WireCompression, WorkerCore,
};
use dsi::dwrf::crypto::StreamCipher;
use dsi::dwrf::{Projection, WriterOptions};
use dsi::metrics::EtlMetrics;
use dsi::paper::harness::{build_world, measure_pipeline};
use dsi::resources::saturation;
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::dag::session_dag;
use dsi::transforms::TransformDag;
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 256,
        partitions: 2,
    };
    println!("\n=== worker pipeline per RM (single thread, real bytes) ===");
    for rm in RmConfig::all() {
        let world = build_world(&rm, &scale, WriterOptions::default(), 9).unwrap();
        let m = measure_pipeline(&world, PipelineOptions::default(), 64, 9).unwrap();
        let sat = saturation(&m.cost, &NodeSpec::c_v1());
        println!(
            "{}: {:>8.0} rows/s measured | cpu/sample {:>8.1} µs | \
             storage rx {:>6.1} KB/sample | tensor tx {:>6.1} KB/sample | \
             C-v1 saturation {:>8.0} rows/s ({})",
            rm.id.name(),
            m.worker_sps,
            m.cost.cpu_secs * 1e6,
            m.cost.net_rx_bytes / 1e3,
            m.cost.net_tx_bytes / 1e3,
            sat.max_samples_per_sec,
            sat.bottleneck.name(),
        );
    }

    println!("\n=== threaded session scaling (RM3) ===");
    let rm = RmConfig::get(dsi::config::RmId::Rm3);
    let world = build_world(&rm, &scale, WriterOptions::default(), 9).unwrap();
    for workers in [1usize, 2, 4] {
        let mut rng = Pcg32::new(17);
        let dag = session_dag(&mut rng, &rm, &world.schema, &world.projection);
        let mut spec =
            SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, 64);
        spec.projection = Projection::new(world.projection.iter().copied());
        let report = Session::run(
            &world.catalog,
            &world.cluster,
            spec,
            &SessionConfig {
                initial_workers: workers,
                max_workers: workers,
                clients: 1,
                ..Default::default()
            },
        )
        .unwrap();
        println!(
            "{} workers: {:>8.0} rows/s wall | {} rows | stall {:.3}s",
            workers,
            report.rows_per_sec,
            report.rows_delivered,
            report.client_stall_secs
        );
    }

    // Tracing overhead: the same 2-worker session once plain, once with
    // spans + telemetry on (informational — the acceptance bar for the
    // *untraced* path is held by the scaling runs above staying flat).
    println!("\n=== tracing overhead (RM3, 2 workers) ===");
    let run_rm3 = |tracing: bool| {
        let mut rng = Pcg32::new(17);
        let dag = session_dag(&mut rng, &rm, &world.schema, &world.projection);
        let mut spec =
            SessionSpec::from_dag(&world.table, 0, u32::MAX, dag, 64);
        spec.projection = Projection::new(world.projection.iter().copied());
        spec.pipeline.tracing = tracing;
        Session::run(
            &world.catalog,
            &world.cluster,
            spec,
            &SessionConfig {
                initial_workers: 2,
                max_workers: 2,
                clients: 1,
                telemetry_every: tracing
                    .then_some(Duration::from_millis(10)),
                ..Default::default()
            },
        )
        .unwrap()
    };
    let plain = run_rm3(false);
    let traced = run_rm3(true);
    let overhead = 1.0 - traced.rows_per_sec / plain.rows_per_sec.max(1e-9);
    println!(
        "plain {:>8.0} rows/s | traced {:>8.0} rows/s | overhead {:+.1}% | \
         {} spans | stall: {}",
        plain.rows_per_sec,
        traced.rows_per_sec,
        overhead * 100.0,
        traced.obs.as_ref().map_or(0, |o| o.trace.len()),
        traced.stall_attribution.dominant(),
    );
    let obs = traced.obs.as_ref().expect("traced run has a sink");
    let mut out = Json::obj();
    out.set("stage_histograms", obs.histograms_json())
        .set("stall_attribution", traced.stall_attribution.to_json())
        .set("rows_per_sec_plain", plain.rows_per_sec)
        .set("rows_per_sec_traced", traced.rows_per_sec)
        .set("tracing_overhead_frac", overhead)
        .set("spans", obs.trace.len() as u64)
        .set("spans_dropped", obs.trace.dropped());
    if let Some(t) = &traced.telemetry {
        out.set("telemetry", t.to_json());
    }
    let _ = std::fs::create_dir_all("target");
    let path = "target/worker_telemetry.json";
    if std::fs::write(path, out.to_string_pretty()).is_ok() {
        println!("wrote {path}");
    }

    // Wire compression sweep: duplication {1,4} x zstd level {off,1,3,9}.
    // Batches span a whole partition (stripe = batch = 512 rows) so the
    // zstd window sees every scattered copy of a duplicated session —
    // the RecD observation that dup-heavy payloads are unusually
    // compressible, applied at the transport instead of the file.
    println!("\n=== wire compression sweep (RM1 flattened, dup x level) ===");
    let mut sweep = Vec::new();
    let mut gate_ratio = 0.0f64;
    for dup in [1usize, 4] {
        let (cluster, catalog, spec) = build_dup_world(dup);
        for level in [0i32, 1, 3, 9] {
            let mut s = spec.clone();
            s.pipeline.wire_compression = if level == 0 {
                WireCompression::Off
            } else {
                WireCompression::zstd(level)
            };
            let r = Session::run(
                &catalog,
                &cluster,
                s,
                &SessionConfig::default(),
            )
            .unwrap();
            let ratio = r.wire_compression_ratio();
            let lvl = if level == 0 {
                "off".to_string()
            } else {
                level.to_string()
            };
            println!(
                "dup {dup} | level {lvl:>3} | {:>8.0} rows/s | wire \
                 {:>7.1} KB (raw {:>7.1} KB, {ratio:.2}x) | stall {:.3}s",
                r.rows_per_sec,
                r.tensor_tx_bytes as f64 / 1e3,
                r.wire_raw_bytes as f64 / 1e3,
                r.client_stall_secs,
            );
            if dup == 4 && level == 3 {
                gate_ratio = ratio;
            }
            let mut e = Json::obj();
            e.set("dup", dup as u64)
                .set("zstd_level", level as u64)
                .set("rows_per_sec", r.rows_per_sec)
                .set("wire_bytes", r.tensor_tx_bytes)
                .set("wire_raw_bytes", r.wire_raw_bytes)
                .set("compression_ratio", ratio)
                .set("client_stall_secs", r.client_stall_secs)
                .set("worker_compress_secs", r.worker_compress_secs)
                .set("client_decode_secs", r.client_decode_secs);
            sweep.push(e);
        }
    }

    // Correctness half of the gate: the compressed wire must decode to
    // exactly the batches the uncompressed wire carries.
    let (cluster, catalog, spec) = build_dup_world(4);
    let mut off_spec = spec.clone();
    off_spec.pipeline.wire_compression = WireCompression::Off;
    let mut zstd_spec = spec;
    zstd_spec.pipeline.wire_compression = WireCompression::zstd(3);
    let base = drain_decoded(&cluster, &catalog, off_spec);
    let comp = drain_decoded(&cluster, &catalog, zstd_spec);
    let identical = base == comp;
    println!("decoded batches identical across off/zstd-3: {identical}");

    let mut res = Json::obj();
    res.set("sweep", Json::Arr(sweep))
        .set("gate_ratio_dup4_level3", gate_ratio)
        .set("gate_min_ratio", 2.0)
        .set("decoded_identical", identical);
    for path in dsi::util::bench::publish_results("worker", &res) {
        println!("wrote {path}");
    }
    if gate_ratio < 2.0 || !identical {
        eprintln!(
            "FAIL: wire compression gate: zstd-3 dup=4 ratio {gate_ratio:.2} \
             (need >= 2.0), decoded identical: {identical}"
        );
        std::process::exit(1);
    }
    println!(
        "PASS: zstd-3 cuts dup=4 wire bytes {gate_ratio:.2}x with \
         byte-identical decoded batches"
    );
}

/// RM1 dataset with `dup`-factor sample duplication, written Flattened
/// (duplicates physically materialized, scattered through the log) and a
/// pass-through session whose batches cover a whole partition.
fn build_dup_world(dup: usize) -> (Arc<Cluster>, Catalog, SessionSpec) {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 512,
        materialized_features: 64,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 128 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_dup(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 512,
            ..Default::default()
        },
        9,
        dup,
    )
    .unwrap();
    let mut dag = TransformDag::default();
    for f in h.schema.dense().take(4) {
        let i = dag.input_dense(f.id);
        dag.output(f.id, i);
    }
    for f in h.schema.sparse().take(8) {
        let i = dag.input_sparse(f.id);
        dag.output(f.id, i);
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 512);
    (cluster, catalog, spec)
}

/// Drain a single worker over the whole session, decoding every wire
/// batch client-side (dedup frames expanded).
fn drain_decoded(
    cluster: &Arc<Cluster>,
    catalog: &Catalog,
    spec: SessionSpec,
) -> Vec<TensorBatch> {
    let cipher = StreamCipher::for_table(&spec.table);
    let spec = Arc::new(spec);
    let master = Master::new(catalog, cluster, (*spec).clone()).unwrap();
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec.clone(), cluster.clone(), metrics);
    let mut out = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for wire in core.process_split(&split).unwrap() {
            let tb = if wire.dedup {
                dsi::dpp::codec::decode_wire_dedup(&cipher, &wire)
                    .unwrap()
                    .expand()
            } else {
                dsi::dpp::codec::decode_wire(&cipher, &wire).unwrap()
            };
            out.push(tb);
        }
        master.complete_split(w, split.id);
    }
    out
}
