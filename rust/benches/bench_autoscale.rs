//! Autoscaling benchmark: what the selectivity- and share-aware
//! feedback controller saves over a fixed-size DPP worker pool.
//!
//! Sweep 1 — selectivity {1.0, 0.5, 0.1} under a paced trainer: a fixed
//! `MAX_WORKERS` pool vs the controller (same spec, same pace). The
//! headline number is worker-seconds (∫ pool-size dt) at equal client
//! stall. Sweep 2 — broker twins: two identical sessions registered on
//! one ReadBroker; the first runs cold (pays fetch+decode), the second
//! serves from the shared buffer — the mostly-hitting twin must scale
//! below its cold twin. Emits `target/autoscale_results.json`.
//!
//! CI criteria: the sel=0.1 controller session uses >= 30% fewer
//! worker-seconds than the fixed pool with client stall no worse than
//! 10% higher (+100ms slack), and the hitting broker twin uses fewer
//! worker-seconds than its cold twin.

use dsi::broker::ReadBroker;
use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{
    run_session_on, Master, SessionConfig, SessionReport, SessionSpec,
};
use dsi::dwrf::WriterOptions;
use dsi::filter::RowPredicate;
use dsi::metrics::Table;
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Duration;

const SEED: u64 = 53;
const MAX_WORKERS: usize = 8;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
    total_rows: u64,
    /// (min_ts, max_ts, rows) per stripe, all partitions.
    stripe_spans: Vec<(u64, u64, u32)>,
}

fn build() -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 4096,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 128,
            ..Default::default()
        },
        SEED,
        &GenOptions {
            tick_max: 40, // spread timestamps so recency windows bite
            ..Default::default()
        },
    )
    .expect("build dataset");

    // A normalization session over ~25% of the features.
    let mut rng = Pcg32::new(SEED ^ 0xA5CA);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let mut dag = TransformDag::default();
    for &fid in &proj {
        match h.schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 13,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    // Small batches + single-slot worker buffers (below) keep every
    // session drain-bound: channel buffers must not absorb a filtered
    // session's whole output, or pool size would stop mattering and
    // worker-seconds would degenerate to total work for any pool.
    let spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 16);

    let table = catalog.get(&h.table_name).unwrap();
    let mut stripe_spans = Vec::new();
    for p in &table.partitions {
        let meta = Master::fetch_meta(&cluster, p.file).expect("footer");
        for s in &meta.stripes {
            stripe_spans.push((
                s.stats.min_timestamp,
                s.stats.max_timestamp,
                s.rows,
            ));
        }
    }
    World {
        cluster,
        catalog,
        spec,
        total_rows: table.total_rows(),
        stripe_spans,
    }
}

/// Approximate row-weighted timestamp quantile from stripe spans.
fn ts_quantile(spans: &[(u64, u64, u32)], q: f64) -> u64 {
    let mut sorted = spans.to_vec();
    sorted.sort_by_key(|s| s.0);
    let total: u64 = sorted.iter().map(|s| s.2 as u64).sum();
    let want = (q * total as f64).round() as u64;
    let mut cum = 0u64;
    for &(min, max, rows) in &sorted {
        if cum + rows as u64 >= want {
            let frac = want.saturating_sub(cum) as f64 / rows.max(1) as f64;
            return min + ((max - min) as f64 * frac) as u64;
        }
        cum += rows as u64;
    }
    sorted.iter().map(|s| s.1).max().unwrap_or(u64::MAX)
}

fn cfg(fixed: bool, pace: Option<f64>) -> SessionConfig {
    SessionConfig {
        initial_workers: if fixed { MAX_WORKERS } else { 2 },
        max_workers: MAX_WORKERS,
        clients: 1,
        buffer_per_worker: 1,
        autoscale_every: if fixed {
            None
        } else {
            Some(Duration::from_millis(1))
        },
        client_rows_per_sec: pace,
        kill_worker_after_batches: None,
        // Cheap time-series sampling so the bench emits a telemetry
        // artifact alongside its results JSON.
        telemetry_every: Some(Duration::from_millis(10)),
        ..Default::default()
    }
}

fn run(
    world: &World,
    spec: SessionSpec,
    fixed: bool,
    pace: Option<f64>,
) -> SessionReport {
    let master = Arc::new(
        Master::new(&world.catalog, &world.cluster, spec).expect("master"),
    );
    run_session_on(master, &world.cluster, &cfg(fixed, pace))
        .expect("session")
}

fn avg_workers(r: &SessionReport) -> f64 {
    r.worker_pool_secs / r.wall_secs.max(1e-9)
}

fn row_json(label: &str, sel: f64, r: &SessionReport) -> Json {
    let mut j = Json::obj();
    j.set("mode", label)
        .set("target_selectivity", sel)
        .set("rows_delivered", r.rows_delivered)
        .set("wall_secs", r.wall_secs)
        .set("worker_pool_secs", r.worker_pool_secs)
        .set("avg_workers", avg_workers(r))
        .set("peak_workers", r.peak_workers as u64)
        .set("final_workers", r.final_workers as u64)
        .set("workers_retired", r.workers_retired)
        .set("splits_requeued", r.splits_requeued)
        .set("client_stall_secs", r.client_stall_secs)
        .set("broker_hit_rate", r.broker_hit_rate)
        .set("stall_attribution", r.stall_attribution.to_json());
    j
}

fn main() {
    let world = build();
    let tmin = ts_quantile(&world.stripe_spans, 0.0);

    // Calibrate off a single-worker unpaced run: the sel-sweep pace is
    // half the single-worker session rate, so demand is real but a
    // small pool provably suffices — the fixed pool's other 7 workers
    // are pure provisioning waste the controller should reclaim.
    let calib = {
        let master = Arc::new(
            Master::new(&world.catalog, &world.cluster, world.spec.clone())
                .expect("calibration master"),
        );
        run_session_on(
            master,
            &world.cluster,
            &SessionConfig {
                initial_workers: 1,
                max_workers: 1,
                clients: 1,
                buffer_per_worker: 1,
                autoscale_every: None,
                client_rows_per_sec: None,
                kill_worker_after_batches: None,
                ..Default::default()
            },
        )
        .expect("calibration session")
    };
    assert_eq!(calib.rows_delivered, world.total_rows);
    let single_rate = calib.rows_delivered as f64 / calib.wall_secs.max(1e-9);
    let pace = (single_rate / 2.0).max(500.0);

    let mut table = Table::new(
        "Autoscaling: fixed 8-worker pool vs feedback controller \
         (RM1, 8192 rows, paced trainer)",
        &[
            "sel",
            "mode",
            "rows",
            "wall s",
            "worker-secs",
            "avg workers",
            "retired",
            "stall s",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_ws_saved = 0.0;
    let mut crit_stall_ok = false;
    // Rough per-row busy cost from the calibration run, split evenly
    // between fetch+decode and transform+load for the planning model.
    let per_row_busy =
        calib.worker_busy_secs / calib.rows_delivered.max(1) as f64;
    let unit_cost = 0.5 * per_row_busy;
    for sel in [1.0f64, 0.5, 0.1] {
        let spec = if sel >= 1.0 {
            world.spec.clone()
        } else {
            world.spec.clone().with_predicate(RowPredicate::TimestampRange {
                min: tmin,
                max: ts_quantile(&world.stripe_spans, sel),
            })
        };
        // Feed-forward plan estimate (reported next to measurements):
        // must shrink monotonically as the predicate narrows.
        let planned_busy_secs =
            Master::new(&world.catalog, &world.cluster, spec.clone())
                .expect("planner")
                .planned_worker_seconds(unit_cost, unit_cost);
        let fixed = run(&world, spec.clone(), true, Some(pace));
        let auto = run(&world, spec, false, Some(pace));
        assert_eq!(
            fixed.rows_delivered, auto.rows_delivered,
            "autoscaling must be lossless"
        );
        let saved = 1.0 - auto.worker_pool_secs / fixed.worker_pool_secs.max(1e-9);
        let stall_ok = auto.client_stall_secs
            <= fixed.client_stall_secs * 1.10 + 0.1;
        if (sel - 0.1).abs() < 1e-9 {
            crit_ws_saved = saved;
            crit_stall_ok = stall_ok;
        }
        for (label, r) in [("fixed", &fixed), ("auto", &auto)] {
            table.row(&[
                format!("{sel}"),
                label.to_string(),
                format!("{}", r.rows_delivered),
                format!("{:.2}", r.wall_secs),
                format!("{:.2}", r.worker_pool_secs),
                format!("{:.2}", avg_workers(r)),
                format!("{}", r.workers_retired),
                format!("{:.3}", r.client_stall_secs),
            ]);
            let mut j = row_json(label, sel, r);
            j.set("worker_secs_saved_frac", saved)
                .set("stall_ok", stall_ok)
                .set("planned_busy_secs", planned_busy_secs);
            arr.push(j);
        }
    }

    // Broker twins: both sessions register on the broker up front (the
    // concurrent-jobs shape), then run back to back — the second serves
    // almost entirely from the shared buffer and should right-size
    // below its cold twin.
    let broker =
        ReadBroker::with_budget_bytes(world.cluster.clone(), 1u64 << 30);
    let cold_master = Arc::new(
        Master::new_shared(
            &world.catalog,
            &world.cluster,
            world.spec.clone(),
            &broker,
        )
        .expect("cold master"),
    );
    let hit_master = Arc::new(
        Master::new_shared(
            &world.catalog,
            &world.cluster,
            world.spec.clone(),
            &broker,
        )
        .expect("hit master"),
    );
    // Pace the twins so the cold session provably needs ~3 workers:
    // per-worker *busy* capacity from the calibration run, times the
    // controller's own provisioning ratio.
    let busy_cap =
        calib.rows_delivered as f64 / calib.worker_busy_secs.max(1e-9);
    let broker_pace = 2.5 * 0.85 * busy_cap / 1.25;
    let cold = run_session_on(
        cold_master,
        &world.cluster,
        &cfg(false, Some(broker_pace)),
    )
    .expect("cold session");
    let hit = run_session_on(
        hit_master,
        &world.cluster,
        &cfg(false, Some(broker_pace)),
    )
    .expect("hit session");
    assert_eq!(cold.rows_delivered, hit.rows_delivered);
    for (label, r) in [("broker-cold", &cold), ("broker-hit", &hit)] {
        table.row(&[
            format!("hit={:.2}", r.broker_hit_rate),
            label.to_string(),
            format!("{}", r.rows_delivered),
            format!("{:.2}", r.wall_secs),
            format!("{:.2}", r.worker_pool_secs),
            format!("{:.2}", avg_workers(r)),
            format!("{}", r.workers_retired),
            format!("{:.3}", r.client_stall_secs),
        ]);
        arr.push(row_json(label, 1.0, r));
    }
    table.print();

    let crit_broker = hit.worker_pool_secs < cold.worker_pool_secs
        && hit.broker_hit_rate >= 0.5;
    let pass = crit_ws_saved >= 0.30 && crit_stall_ok && crit_broker;
    println!(
        "\ncriterion @ sel=0.1: worker-seconds saved {:.0}% (target >= \
         30%), stall parity {}; broker twins: hit {:.2} ws (hit rate \
         {:.2}) vs cold {:.2} ws: {}",
        crit_ws_saved * 100.0,
        crit_stall_ok,
        hit.worker_pool_secs,
        hit.broker_hit_rate,
        cold.worker_pool_secs,
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("pace_rows_per_sec", pace);
    out.set("criterion_worker_secs_saved_sel01", crit_ws_saved);
    out.set("criterion_stall_ok", crit_stall_ok);
    out.set("criterion_broker_hit_scales_below_cold", crit_broker);
    out.set("criterion_pass", pass);
    for path in dsi::util::bench::publish_results("autoscale", &out) {
        println!("wrote {path}");
    }
    // Telemetry artifact from the broker-hit session: attribution plus
    // the sampled pool / broker / drain time-series.
    let mut tel = Json::obj();
    tel.set("session", "broker-hit")
        .set("stall_attribution", hit.stall_attribution.to_json());
    if let Some(t) = &hit.telemetry {
        tel.set("telemetry", t.to_json());
    }
    let tpath = "target/autoscale_telemetry.json";
    if std::fs::write(tpath, tel.to_string_pretty()).is_ok() {
        println!("wrote {tpath}");
    }
    // CI smoke: a controller regression that stops saving
    // worker-seconds (or trades them for stalls) fails the build.
    if !pass {
        std::process::exit(1);
    }
}
