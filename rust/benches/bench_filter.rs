//! Filter-pushdown benchmark: what pushing the session row predicate
//! down to footer stats buys over the decode-then-filter baseline,
//! across target selectivities {1.0, 0.5, 0.1, 0.01} — at *two*
//! granularities: per-stripe stats (footer v2 behavior) and per-row-
//! group zone maps (footer v3). Reports bytes read off storage,
//! rows/bytes decoded, pruned groups, and delivered rows/s; proves all
//! three paths ship **byte-identical** wire batches; and proves
//! stripe-stat pruning issues **zero** I/Os for a fully-filtered
//! session. Emits `target/filter_results.json` alongside the other
//! machine-readable tables.
//!
//! CI criteria (exit 1 on failure):
//! * sel 0.1: row-group pushdown decodes ≥ 2x fewer rows and bytes
//!   than the decode-then-filter baseline;
//! * sel 0.01: row-group pruning decodes ≥ 4x fewer rows than
//!   stripe-only pruning, with byte-identical client output;
//! * fully-filtered sessions issue zero data I/O.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::WriterOptions;
use dsi::filter::RowPredicate;
use dsi::metrics::{EtlMetrics, Table};
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Instant;

const SEED: u64 = 29;

/// Wide stripes + fine zone maps: the regime where sub-stripe pruning
/// has room to work (a 0.01-selectivity window covers a fraction of one
/// stripe but a couple of its row groups).
const STRIPE_ROWS: usize = 1024;
const ROWS_PER_GROUP: usize = 64;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
    total_rows: u64,
    /// (min_ts, max_ts, rows) per stripe, all partitions.
    stripe_spans: Vec<(u64, u64, u32)>,
}

fn build() -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 4096,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: STRIPE_ROWS,
            rows_per_group: ROWS_PER_GROUP,
            ..Default::default()
        },
        SEED,
        &GenOptions {
            tick_max: 40, // spread timestamps so recency windows bite
            ..Default::default()
        },
    )
    .expect("build dataset");

    // A normalization session over ~25% of the features.
    let mut rng = Pcg32::new(SEED ^ 0xF11E);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let mut dag = TransformDag::default();
    for &fid in &proj {
        match h.schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 7,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 64);

    let table = catalog.get(&h.table_name).unwrap();
    let mut stripe_spans = Vec::new();
    for p in &table.partitions {
        let meta = Master::fetch_meta(&cluster, p.file).expect("footer");
        for s in &meta.stripes {
            stripe_spans.push((
                s.stats.min_timestamp,
                s.stats.max_timestamp,
                s.rows,
            ));
        }
    }
    World {
        cluster,
        catalog,
        spec,
        total_rows: table.total_rows(),
        stripe_spans,
    }
}

/// Approximate row-weighted timestamp quantile from stripe spans
/// (rows assumed uniform within a stripe).
fn ts_quantile(spans: &[(u64, u64, u32)], q: f64) -> u64 {
    let mut sorted = spans.to_vec();
    sorted.sort_by_key(|s| s.0);
    let total: u64 = sorted.iter().map(|s| s.2 as u64).sum();
    let want = (q * total as f64).round() as u64;
    let mut cum = 0u64;
    for &(min, max, rows) in &sorted {
        if cum + rows as u64 >= want {
            let frac = want.saturating_sub(cum) as f64 / rows.max(1) as f64;
            return min + ((max - min) as f64 * frac) as u64;
        }
        cum += rows as u64;
    }
    sorted.iter().map(|s| s.1).max().unwrap_or(u64::MAX)
}

/// Pushdown granularity of one run.
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    /// Decode-then-filter: no pruning at all.
    Base,
    /// Stripe-granular stats only (the pre-zone-map pushdown).
    Stripe,
    /// Stripe stats + row-group zone maps.
    Groups,
}

/// One wire batch, recorded for byte-identity checks across modes.
type WireRecord = (u64, usize, bool, Vec<u8>);

struct Out {
    read_bytes: u64,
    decoded_rows: u64,
    decoded_bytes: u64,
    delivered: u64,
    skipped_stripes: u64,
    skipped_bytes: u64,
    pruned_groups: u64,
    pruned_group_rows: u64,
    wall_secs: f64,
    /// Full wire stream, for byte-identity checks across modes.
    wire: Vec<WireRecord>,
}

fn run(world: &World, predicate: RowPredicate, mode: Mode) -> Out {
    let mut spec = world.spec.clone().with_predicate(predicate);
    spec.pipeline.pushdown = mode != Mode::Base;
    spec.pipeline.row_group_pruning = mode == Mode::Groups;
    let spec = Arc::new(spec);
    let master = Master::new(&world.catalog, &world.cluster, (*spec).clone())
        .expect("master");
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec, world.cluster.clone(), metrics.clone());
    world.cluster.reset_stats();
    let t = Instant::now();
    let mut wire = Vec::new();
    while let Some(split) = master.fetch_split(w) {
        for b in core.process_split(&split).expect("process split") {
            wire.push((b.seq, b.rows, b.dedup, b.bytes));
        }
        master.complete_split(w, split.id);
    }
    Out {
        read_bytes: metrics.storage_rx_bytes.get(),
        decoded_rows: metrics.decoded_rows.get(),
        decoded_bytes: metrics.extract_out_bytes.get(),
        delivered: metrics.samples.get(),
        skipped_stripes: metrics.skipped_stripes.get()
            + master.skipped_split_stripes() as u64,
        skipped_bytes: metrics.skipped_bytes.get(),
        pruned_groups: metrics.pruned_groups.get(),
        pruned_group_rows: metrics.pruned_group_rows.get(),
        wall_secs: t.elapsed().as_secs_f64(),
        wire,
    }
}

fn main() {
    let world = build();
    let tmin = ts_quantile(&world.stripe_spans, 0.0);
    let mut table = Table::new(
        "Filter pushdown: none vs stripe stats vs row-group zone maps \
         (RM1, 8192 rows, 1024-row stripes, 64-row groups, \
         timestamp-recency predicate)",
        &[
            "sel",
            "realized",
            "read MB (base/stripe/group)",
            "decoded rows (base/stripe/group)",
            "group vs stripe x",
            "pruned groups",
            "rows/s x (group/base)",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_decoded_x = 0.0;
    let mut crit_bytes_x = 0.0;
    let mut crit_rows_reduced = false;
    let mut crit_group_x = 0.0;
    let mut wires_identical = true;
    for sel in [1.0f64, 0.5, 0.1, 0.01] {
        let cut = if sel >= 1.0 {
            u64::MAX
        } else {
            ts_quantile(&world.stripe_spans, sel)
        };
        let pred = RowPredicate::TimestampRange {
            min: tmin,
            max: cut,
        };
        let base = run(&world, pred.clone(), Mode::Base);
        let stripe = run(&world, pred.clone(), Mode::Stripe);
        let group = run(&world, pred, Mode::Groups);
        assert_eq!(
            base.delivered, group.delivered,
            "pushdown must be lossless"
        );
        // The whole point of "pure speed": all three paths must ship
        // exactly the same bytes to the client.
        let same =
            base.wire == stripe.wire && stripe.wire == group.wire;
        wires_identical &= same;
        let realized = group.delivered as f64 / world.total_rows as f64;
        let dec_x =
            base.decoded_rows as f64 / group.decoded_rows.max(1) as f64;
        let bytes_x =
            base.decoded_bytes as f64 / group.decoded_bytes.max(1) as f64;
        let group_x = stripe.decoded_rows as f64
            / group.decoded_rows.max(1) as f64;
        let sps_x = (group.delivered as f64 / group.wall_secs.max(1e-9))
            / (base.delivered as f64 / base.wall_secs.max(1e-9)).max(1e-9);
        if (sel - 0.1).abs() < 1e-9 {
            crit_decoded_x = dec_x;
            crit_bytes_x = bytes_x;
            crit_rows_reduced = group.decoded_rows < base.decoded_rows;
        }
        if (sel - 0.01).abs() < 1e-9 {
            crit_group_x = group_x;
        }
        table.row(&[
            format!("{sel}"),
            format!("{realized:.3}"),
            format!(
                "{:.2}/{:.2}/{:.2}",
                base.read_bytes as f64 / 1e6,
                stripe.read_bytes as f64 / 1e6,
                group.read_bytes as f64 / 1e6
            ),
            format!(
                "{}/{}/{}",
                base.decoded_rows, stripe.decoded_rows, group.decoded_rows
            ),
            format!("{group_x:.2}"),
            format!("{}", group.pruned_groups),
            format!("{sps_x:.2}"),
        ]);
        let mut j = Json::obj();
        j.set("target_selectivity", sel)
            .set("realized_selectivity", realized)
            .set("base_read_bytes", base.read_bytes)
            .set("stripe_read_bytes", stripe.read_bytes)
            .set("push_read_bytes", group.read_bytes)
            .set("base_decoded_rows", base.decoded_rows)
            .set("stripe_decoded_rows", stripe.decoded_rows)
            .set("push_decoded_rows", group.decoded_rows)
            .set("decoded_rows_reduction", dec_x)
            .set("rowgroup_vs_stripe_reduction", group_x)
            .set("base_decoded_bytes", base.decoded_bytes)
            .set("push_decoded_bytes", group.decoded_bytes)
            .set("decoded_bytes_reduction", bytes_x)
            .set("delivered_rows", group.delivered)
            .set("skipped_stripes", group.skipped_stripes)
            .set("skipped_bytes", group.skipped_bytes)
            .set("pruned_groups", group.pruned_groups)
            .set("pruned_group_rows", group.pruned_group_rows)
            .set("wire_identical", same)
            .set("base_wall_secs", base.wall_secs)
            .set("push_wall_secs", group.wall_secs);
        arr.push(j);
    }
    table.print();

    // Fully-filtered session: every stripe pruned from footer stats —
    // zero data I/Os issued.
    let disjoint = RowPredicate::TimestampRange {
        min: u64::MAX - 1,
        max: u64::MAX,
    };
    let none = run(&world, disjoint, Mode::Groups);
    let zero_io = none.read_bytes == 0 && none.delivered == 0;
    println!(
        "\nfully-filtered session: {} bytes read, {} rows delivered, \
         {} stripes skipped ({})",
        none.read_bytes,
        none.delivered,
        none.skipped_stripes,
        if zero_io { "zero-I/O PASS" } else { "FAIL" }
    );

    let pass = crit_decoded_x >= 2.0
        && crit_bytes_x >= 2.0
        && crit_rows_reduced
        && crit_group_x >= 4.0
        && wires_identical
        && zero_io;
    println!(
        "\ncriteria: sel=0.1 decoded-rows reduction {crit_decoded_x:.2}x / \
         decoded-bytes {crit_bytes_x:.2}x (targets >= 2x); sel=0.01 \
         row-group vs stripe-only {crit_group_x:.2}x (target >= 4x); \
         wire byte-identical: {wires_identical}; zero-I/O on \
         fully-filtered: {zero_io}: {}",
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("zero_io_fully_filtered", zero_io);
    out.set("wire_identical_all", wires_identical);
    out.set("rowgroup_criterion_x", crit_group_x);
    out.set("criterion_pass", pass);
    for path in dsi::util::bench::publish_results("filter", &out) {
        println!("wrote {path}");
    }
    // CI smoke: regressions that erode pushdown below the acceptance
    // criteria fail the bench step.
    if !pass {
        std::process::exit(1);
    }
}
