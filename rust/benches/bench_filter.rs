//! Filter-pushdown benchmark: what pushing the session row predicate
//! down to stripe stats + selection vectors buys over the
//! decode-then-filter baseline, across target selectivities
//! {1.0, 0.5, 0.1, 0.01}. Reports bytes read off storage, rows/bytes
//! decoded, and delivered rows/s; also proves stripe-stat pruning
//! issues **zero** I/Os for a fully-filtered session. Emits
//! `target/filter_results.json` alongside the other machine-readable
//! tables.

use dsi::config::{RmConfig, RmId, SimScale};
use dsi::datagen::{build_dataset_with, GenOptions};
use dsi::dpp::{Master, SessionSpec, WorkerCore};
use dsi::dwrf::WriterOptions;
use dsi::filter::RowPredicate;
use dsi::metrics::{EtlMetrics, Table};
use dsi::schema::{FeatureId, FeatureKind};
use dsi::tectonic::{Cluster, ClusterConfig};
use dsi::transforms::{Op, TransformDag};
use dsi::util::json::Json;
use dsi::util::rng::Pcg32;
use dsi::warehouse::Catalog;
use std::sync::Arc;
use std::time::Instant;

const SEED: u64 = 29;

struct World {
    cluster: Arc<Cluster>,
    catalog: Catalog,
    spec: SessionSpec,
    total_rows: u64,
    /// (min_ts, max_ts, rows) per stripe, all partitions.
    stripe_spans: Vec<(u64, u64, u32)>,
}

fn build() -> World {
    let rm = RmConfig::get(RmId::Rm1);
    let scale = SimScale {
        rows_per_partition: 2048,
        materialized_features: 128,
        partitions: 2,
    };
    let cluster = Arc::new(Cluster::new(ClusterConfig {
        chunk_bytes: 256 << 10,
        ..Default::default()
    }));
    let catalog = Catalog::new();
    let h = build_dataset_with(
        &cluster,
        &catalog,
        &rm,
        &scale,
        WriterOptions {
            stripe_rows: 128,
            ..Default::default()
        },
        SEED,
        &GenOptions {
            tick_max: 40, // spread timestamps so recency windows bite
            ..Default::default()
        },
    )
    .expect("build dataset");

    // A normalization session over ~25% of the features.
    let mut rng = Pcg32::new(SEED ^ 0xF11E);
    let take = (h.schema.features.len() / 4).max(4);
    let proj: Vec<FeatureId> = h.schema.sample_projection(&mut rng, take, 1.0);
    let mut dag = TransformDag::default();
    for &fid in &proj {
        match h.schema.by_id(fid).map(|d| d.kind) {
            Some(FeatureKind::Dense) => {
                let i = dag.input_dense(fid);
                let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![i]);
                dag.output(fid, c);
            }
            _ => {
                let i = dag.input_sparse(fid);
                let s = dag.apply(
                    Op::SigridHash {
                        salt: 7,
                        modulus: 1 << 16,
                    },
                    vec![i],
                );
                dag.output(fid, s);
            }
        }
    }
    let spec = SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag, 64);

    let table = catalog.get(&h.table_name).unwrap();
    let mut stripe_spans = Vec::new();
    for p in &table.partitions {
        let meta = Master::fetch_meta(&cluster, p.file).expect("footer");
        for s in &meta.stripes {
            stripe_spans.push((
                s.stats.min_timestamp,
                s.stats.max_timestamp,
                s.rows,
            ));
        }
    }
    World {
        cluster,
        catalog,
        spec,
        total_rows: table.total_rows(),
        stripe_spans,
    }
}

/// Approximate row-weighted timestamp quantile from stripe spans
/// (rows assumed uniform within a stripe).
fn ts_quantile(spans: &[(u64, u64, u32)], q: f64) -> u64 {
    let mut sorted = spans.to_vec();
    sorted.sort_by_key(|s| s.0);
    let total: u64 = sorted.iter().map(|s| s.2 as u64).sum();
    let want = (q * total as f64).round() as u64;
    let mut cum = 0u64;
    for &(min, max, rows) in &sorted {
        if cum + rows as u64 >= want {
            let frac = want.saturating_sub(cum) as f64 / rows.max(1) as f64;
            return min + ((max - min) as f64 * frac) as u64;
        }
        cum += rows as u64;
    }
    sorted.iter().map(|s| s.1).max().unwrap_or(u64::MAX)
}

struct Out {
    read_bytes: u64,
    decoded_rows: u64,
    decoded_bytes: u64,
    delivered: u64,
    skipped_stripes: u64,
    skipped_bytes: u64,
    wall_secs: f64,
}

fn run(world: &World, predicate: RowPredicate, pushdown: bool) -> Out {
    let mut spec = world.spec.clone().with_predicate(predicate);
    spec.pipeline.pushdown = pushdown;
    let spec = Arc::new(spec);
    let master = Master::new(&world.catalog, &world.cluster, (*spec).clone())
        .expect("master");
    let w = master.register_worker();
    let metrics = Arc::new(EtlMetrics::default());
    let mut core = WorkerCore::new(spec, world.cluster.clone(), metrics.clone());
    world.cluster.reset_stats();
    let t = Instant::now();
    while let Some(split) = master.fetch_split(w) {
        core.process_split(&split).expect("process split");
        master.complete_split(w, split.id);
    }
    Out {
        read_bytes: metrics.storage_rx_bytes.get(),
        decoded_rows: metrics.decoded_rows.get(),
        decoded_bytes: metrics.extract_out_bytes.get(),
        delivered: metrics.samples.get(),
        skipped_stripes: metrics.skipped_stripes.get()
            + master.skipped_split_stripes() as u64,
        skipped_bytes: metrics.skipped_bytes.get(),
        wall_secs: t.elapsed().as_secs_f64(),
    }
}

fn main() {
    let world = build();
    let tmin = ts_quantile(&world.stripe_spans, 0.0);
    let mut table = Table::new(
        "Filter pushdown vs decode-then-filter (RM1, 4096 rows, \
         timestamp-recency predicate)",
        &[
            "sel",
            "realized",
            "read MB (base/push)",
            "read x",
            "decoded rows (base/push)",
            "decoded x",
            "skipped stripes",
            "rows/s x",
        ],
    );
    let mut arr = Vec::new();
    let mut crit_decoded_x = 0.0;
    let mut crit_bytes_x = 0.0;
    let mut crit_rows_reduced = false;
    for sel in [1.0f64, 0.5, 0.1, 0.01] {
        let cut = if sel >= 1.0 {
            u64::MAX
        } else {
            ts_quantile(&world.stripe_spans, sel)
        };
        let pred = RowPredicate::TimestampRange {
            min: tmin,
            max: cut,
        };
        let base = run(&world, pred.clone(), false);
        let push = run(&world, pred, true);
        assert_eq!(
            base.delivered, push.delivered,
            "pushdown must be lossless"
        );
        let realized = push.delivered as f64 / world.total_rows as f64;
        let read_x = base.read_bytes as f64 / push.read_bytes.max(1) as f64;
        let dec_x =
            base.decoded_rows as f64 / push.decoded_rows.max(1) as f64;
        let bytes_x =
            base.decoded_bytes as f64 / push.decoded_bytes.max(1) as f64;
        let sps_x = (push.delivered as f64 / push.wall_secs.max(1e-9))
            / (base.delivered as f64 / base.wall_secs.max(1e-9)).max(1e-9);
        if (sel - 0.1).abs() < 1e-9 {
            crit_decoded_x = dec_x;
            crit_bytes_x = bytes_x;
            crit_rows_reduced = push.decoded_rows < base.decoded_rows;
        }
        table.row(&[
            format!("{sel}"),
            format!("{realized:.3}"),
            format!(
                "{:.2}/{:.2}",
                base.read_bytes as f64 / 1e6,
                push.read_bytes as f64 / 1e6
            ),
            format!("{read_x:.2}"),
            format!("{}/{}", base.decoded_rows, push.decoded_rows),
            format!("{dec_x:.2}"),
            format!("{}", push.skipped_stripes),
            format!("{sps_x:.2}"),
        ]);
        let mut j = Json::obj();
        j.set("target_selectivity", sel)
            .set("realized_selectivity", realized)
            .set("base_read_bytes", base.read_bytes)
            .set("push_read_bytes", push.read_bytes)
            .set("read_reduction", read_x)
            .set("base_decoded_rows", base.decoded_rows)
            .set("push_decoded_rows", push.decoded_rows)
            .set("decoded_rows_reduction", dec_x)
            .set("base_decoded_bytes", base.decoded_bytes)
            .set("push_decoded_bytes", push.decoded_bytes)
            .set("decoded_bytes_reduction", bytes_x)
            .set("delivered_rows", push.delivered)
            .set("skipped_stripes", push.skipped_stripes)
            .set("skipped_bytes", push.skipped_bytes)
            .set("base_wall_secs", base.wall_secs)
            .set("push_wall_secs", push.wall_secs);
        arr.push(j);
    }
    table.print();

    // Fully-filtered session: every stripe pruned from footer stats —
    // zero data I/Os issued.
    let disjoint = RowPredicate::TimestampRange {
        min: u64::MAX - 1,
        max: u64::MAX,
    };
    let none = run(&world, disjoint, true);
    let zero_io = none.read_bytes == 0 && none.delivered == 0;
    println!(
        "\nfully-filtered session: {} bytes read, {} rows delivered, \
         {} stripes skipped ({})",
        none.read_bytes,
        none.delivered,
        none.skipped_stripes,
        if zero_io { "zero-I/O PASS" } else { "FAIL" }
    );

    let pass = crit_decoded_x >= 2.0
        && crit_bytes_x >= 2.0
        && crit_rows_reduced
        && zero_io;
    println!(
        "\ncriterion @ sel=0.1: decoded-rows reduction {crit_decoded_x:.2}x, \
         decoded-bytes reduction {crit_bytes_x:.2}x (targets >= 2x), \
         zero-I/O on fully-filtered: {zero_io}: {}",
        if pass { "PASS" } else { "FAIL" }
    );
    let mut out = Json::obj();
    out.set("table", Json::Arr(arr));
    out.set("zero_io_fully_filtered", zero_io);
    out.set("criterion_pass", pass);
    let _ = std::fs::create_dir_all("target");
    let path = "target/filter_results.json";
    if std::fs::write(path, out.to_string_pretty()).is_ok() {
        println!("wrote {path}");
    }
    // CI smoke: regressions that erode pushdown below the acceptance
    // criterion fail the bench step.
    if !pass {
        std::process::exit(1);
    }
}
