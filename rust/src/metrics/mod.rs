//! Metrics: thread-safe counters/gauges, per-stage time accounting, and the
//! aligned-table printer used by every paper experiment driver.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_or_recover, Mutex};
use std::time::Duration;

/// Monotonic counter (bytes, samples, splits, ...).
#[derive(Default, Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    // Relaxed is sound here: a Counter is an independent monotone cell —
    // no reader derives cross-variable invariants from it, so only the
    // per-cell total matters and `fetch_add` never loses updates at any
    // ordering.
    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    // Relaxed load: readers accept a slightly stale total (metrics are
    // sampled, not synchronized-with); the value is still a real prior
    // state of the counter, never garbage.
    #[inline]
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Gauge for sampled levels (buffer depth, worker count).
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    // Relaxed store/load: a gauge is last-writer-wins by design; samplers
    // tolerate staleness and no other state is published through it.
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Nanosecond-accumulating stage timer: `extract`, `transform`, `load`, ...
#[derive(Default, Debug)]
pub struct StageClock {
    ns: AtomicU64,
}

impl StageClock {
    // Relaxed fetch_add: each add folds a disjoint duration into one
    // monotone nanosecond cell. Concurrent adders never coordinate
    // through the clock, so no acquire/release edge is needed and the
    // final sum is exact (fetch_add is atomic read-modify-write).
    #[inline]
    pub fn add(&self, d: Duration) {
        self.ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    // Relaxed load: mid-run readers (stall attribution, autoscaler) want
    // a recent lower bound, not a synchronized snapshot.
    pub fn secs(&self) -> f64 {
        self.ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.ns.store(0, Ordering::Relaxed);
    }
}

/// The per-worker ETL stage metrics the paper reports in Fig 9 / Table 9.
#[derive(Default, Debug)]
pub struct EtlMetrics {
    pub storage_rx_bytes: Counter,   // compressed bytes off storage
    pub extract_out_bytes: Counter,  // decompressed/decoded bytes
    pub transform_out_bytes: Counter, // bytes after transforms
    pub tensor_tx_bytes: Counter,    // serialized tensor bytes to clients
    /// Pre-compression (raw) size of the tensor bytes behind
    /// `tensor_tx_bytes` — raw/tx is the wire compression ratio.
    pub wire_raw_bytes: Counter,
    pub samples: Counter,
    pub batches: Counter,
    /// Rows actually pushed through the transform DAG (== `samples` on
    /// the duplication-oblivious path; only unique payloads on the
    /// dedup-aware path).
    pub transform_rows: Counter,
    /// Rows whose preprocessing was skipped thanks to dedup.
    pub dedup_saved_rows: Counter,
    /// Rows decoded out of storage (post stripe-pruning, pre row
    /// selection) — the quantity predicate pushdown shrinks.
    pub decoded_rows: Counter,
    /// Rows dropped by the session's row predicate after decode.
    pub filtered_rows: Counter,
    /// Stripes this session received from the cross-job read broker's
    /// shared buffer — another session already paid the storage read,
    /// decryption, and decode.
    pub shared_reads: Counter,
    /// Transform outputs served from the cross-job transform cache:
    /// another session (or an earlier batch) already ran this sub-DAG
    /// over byte-identical input columns.
    pub transform_reuse_hits: Counter,
    /// Row-outputs those hits covered (hit outputs × batch rows) — the
    /// per-row transform work the cache skipped.
    pub transform_reused_rows: Counter,
    /// Stripes skipped whole by footer-stat pruning (zero I/Os issued).
    pub skipped_stripes: Counter,
    /// Wanted-stream bytes never fetched thanks to stripe pruning.
    pub skipped_bytes: Counter,
    /// Row groups pruned *inside* surviving stripes by footer v3 zone
    /// maps (sub-stripe granularity; fully-pruned stripes count under
    /// `skipped_stripes`).
    pub pruned_groups: Counter,
    /// Rows in those pruned groups — never decoded into batch rows.
    pub pruned_group_rows: Counter,
    /// Stream bytes pruned groups' group-scoped streams would have
    /// fetched (row-group-split layouts only).
    pub pruned_group_bytes: Counter,
    /// Rows drained by trainer-side clients (bumped by the session loop,
    /// not by workers) — the demand half of the autoscaler's throughput
    /// model.
    pub drained_rows: Counter,
    pub t_read: StageClock,
    pub t_extract: StageClock,
    pub t_transform: StageClock,
    pub t_load: StageClock,
    pub t_misc: StageClock,
    /// Time inside the wire codec (compress + frame) — a *subset* of
    /// `t_load`, kept separate so the compression tax is attributable
    /// without double-counting in [`total_secs`](Self::total_secs).
    pub t_compress: StageClock,
}

/// `StageClock` fields of [`EtlMetrics`] deliberately *excluded* from
/// [`total_secs`](EtlMetrics::total_secs). `dsi-lint` fails the build if
/// a clock field is neither summed there nor listed here with a
/// justification comment directly above its entry.
pub const TOTAL_SECS_EXEMPT: &[&str] = &[
    // t_compress is a subset of t_load (the wire codec runs inside the
    // load stage); summing it again would double-count busy time.
    "t_compress",
];

impl EtlMetrics {
    pub fn total_secs(&self) -> f64 {
        self.t_read.secs()
            + self.t_extract.secs()
            + self.t_transform.secs()
            + self.t_load.secs()
            + self.t_misc.secs()
    }

    /// Delivered rows per summed busy-second — a per-worker *efficiency*
    /// number, NOT wall-clock throughput: stage clocks accumulate across
    /// overlapping worker threads, so this understates throughput the
    /// moment two workers run concurrently. Use [`qps_wall`](Self::qps_wall)
    /// for throughput.
    pub fn rows_per_busy_sec(&self) -> f64 {
        let t = self.total_secs();
        if t == 0.0 {
            0.0
        } else {
            self.samples.get() as f64 / t
        }
    }

    /// Wall-clock throughput: delivered rows per elapsed second. The
    /// caller supplies the wall time (metrics can't know it — clocks
    /// here only accumulate busy time).
    pub fn qps_wall(&self, wall_secs: f64) -> f64 {
        if wall_secs <= 0.0 {
            0.0
        } else {
            self.samples.get() as f64 / wall_secs
        }
    }

    /// Delivered rows per transformed row (1.0 without dedup).
    pub fn preproc_dedup_factor(&self) -> f64 {
        let t = self.transform_rows.get();
        if t == 0 {
            1.0
        } else {
            self.samples.get() as f64 / t as f64
        }
    }

    /// Busy seconds spent fetching + decoding (the read and extract
    /// stages) — exactly the work a broker buffer hit skips. The
    /// autoscaler's throughput model uses its share of total busy time
    /// to rescale per-worker capacity as the hit rate drifts.
    pub fn fetch_decode_secs(&self) -> f64 {
        self.t_read.secs() + self.t_extract.secs()
    }

    /// Wire compression ratio: raw tensor bytes per byte actually put on
    /// the wire (1.0 with compression off or before any batch shipped).
    pub fn wire_compression_ratio(&self) -> f64 {
        let tx = self.tensor_tx_bytes.get();
        if tx == 0 {
            1.0
        } else {
            self.wire_raw_bytes.get() as f64 / tx as f64
        }
    }

    /// Observed predicate selectivity: delivered / (decoded + pruned-away
    /// would-be rows are excluded — this is the post-pruning survival
    /// rate). 1.0 when nothing was decoded or no filter ran.
    pub fn observed_selectivity(&self) -> f64 {
        let d = self.decoded_rows.get();
        if d == 0 {
            1.0
        } else {
            (d - self.filtered_rows.get().min(d)) as f64 / d as f64
        }
    }
}

/// Time-series of (x, y) points for figure reproduction.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn max_y(&self) -> f64 {
        self.points.iter().map(|p| p.1).fold(0.0, f64::max)
    }

    /// Normalize y values so the peak is 1.0 (paper figures are normalized).
    pub fn normalized(&self) -> Series {
        let m = self.max_y().max(1e-12);
        Series {
            name: self.name.clone(),
            points: self.points.iter().map(|&(x, y)| (x, y / m)).collect(),
        }
    }

    /// Render as a row of unicode sparkline glyphs for terminal figures.
    pub fn sparkline(&self, width: usize) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.points.is_empty() {
            return String::new();
        }
        let m = self.max_y().max(1e-12);
        let n = self.points.len();
        (0..width)
            .map(|i| {
                let idx = i * n / width;
                let y = self.points[idx].1 / m;
                GLYPHS[((y * 7.0).round() as usize).min(7)]
            })
            .collect()
    }
}

/// Aligned-column table printer for paper-style output.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n## {}\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Shared collector of log lines for experiment drivers (also lets tests
/// assert on driver output without capturing stdout).
#[derive(Default)]
pub struct Log {
    lines: Mutex<Vec<String>>,
}

impl Log {
    pub fn say(&self, s: impl Into<String>) {
        let s = s.into();
        println!("{s}");
        lock_or_recover(&self.lines, "metrics log").push(s);
    }

    pub fn lines(&self) -> Vec<String> {
        lock_or_recover(&self.lines, "metrics log").clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.add(3);
        c.inc();
        assert_eq!(c.get(), 4);
        c.reset();
        assert_eq!(c.get(), 0);
        let g = Gauge::default();
        g.set(9);
        assert_eq!(g.get(), 9);
    }

    #[test]
    fn stage_clock_accumulates() {
        let s = StageClock::default();
        s.add(Duration::from_millis(250));
        s.add(Duration::from_millis(750));
        assert!((s.secs() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Model", "GB/s"]);
        t.row_strs(&["RM1", "16.50"]);
        t.row_strs(&["RM2", "4.69"]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("RM1"));
        let lines: Vec<&str> = r.lines().collect();
        // header, separator, two rows (+title/blank)
        assert!(lines.len() >= 5);
    }

    #[test]
    fn series_normalizes_and_sparks() {
        let mut s = Series::new("util");
        for i in 0..10 {
            s.push(i as f64, (i % 5) as f64);
        }
        let n = s.normalized();
        assert!((n.max_y() - 1.0).abs() < 1e-12);
        assert_eq!(s.sparkline(10).chars().count(), 10);
    }

    #[test]
    fn etl_metrics_qps() {
        let m = EtlMetrics::default();
        m.samples.add(500);
        m.t_transform.add(Duration::from_millis(500));
        assert!((m.rows_per_busy_sec() - 1000.0).abs() < 1.0);
        assert!((m.qps_wall(0.5) - 1000.0).abs() < 1.0);
        assert_eq!(m.qps_wall(0.0), 0.0);
    }

    #[test]
    fn busy_sec_rate_understates_overlapped_throughput() {
        // Two workers, each 1s busy over the same 1s of wall time,
        // delivering 1000 rows total: true throughput is 1000 rows/s,
        // but summed busy-seconds is 2 — the regression qps() had.
        let m = EtlMetrics::default();
        m.samples.add(1000);
        m.t_read.add(Duration::from_millis(600));
        m.t_transform.add(Duration::from_millis(400));
        m.t_read.add(Duration::from_millis(500));
        m.t_transform.add(Duration::from_millis(500));
        assert!((m.qps_wall(1.0) - 1000.0).abs() < 1e-9);
        assert!((m.rows_per_busy_sec() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn fetch_decode_share_of_busy_time() {
        let m = EtlMetrics::default();
        m.t_read.add(Duration::from_millis(300));
        m.t_extract.add(Duration::from_millis(200));
        m.t_transform.add(Duration::from_millis(400));
        m.t_load.add(Duration::from_millis(100));
        assert!((m.fetch_decode_secs() - 0.5).abs() < 1e-9);
        assert!((m.total_secs() - 1.0).abs() < 1e-9);
        m.drained_rows.add(7);
        assert_eq!(m.drained_rows.get(), 7);
    }

    #[test]
    fn compress_clock_is_outside_total_and_ratio_tracks_bytes() {
        let m = EtlMetrics::default();
        assert_eq!(m.wire_compression_ratio(), 1.0);
        m.t_load.add(Duration::from_millis(400));
        m.t_compress.add(Duration::from_millis(300)); // subset of t_load
        assert!((m.total_secs() - 0.4).abs() < 1e-9);
        m.wire_raw_bytes.add(1000);
        m.tensor_tx_bytes.add(250);
        assert!((m.wire_compression_ratio() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn preproc_dedup_factor_tracks_savings() {
        let m = EtlMetrics::default();
        assert_eq!(m.preproc_dedup_factor(), 1.0);
        m.samples.add(400);
        m.transform_rows.add(100);
        m.dedup_saved_rows.add(300);
        assert!((m.preproc_dedup_factor() - 4.0).abs() < 1e-12);
    }
}
