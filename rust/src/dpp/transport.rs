//! TCP transport for the worker→client tensor stream: the actual
//! disaggregation boundary. In-process sessions use channels; this module
//! carries the identical wire frames over sockets so Workers and Clients
//! can live on different hosts (as in production, where each Client keeps
//! a capped set of connections to its partition of Workers).
//!
//! Frame: `[magic u32][seq u64][rows u32][len u32][raw u32][flags u8]
//! [payload]`, little endian. `len` is the on-wire payload size
//! (post-compression); `raw` is the declared pre-compression size, which
//! the receiver uses to bound decompression allocations *before* making
//! them. Flags: bit 0 = payload is a dedup wire batch, bit 1 = payload
//! uses the section-framed compression codec. Uncompressed frames must
//! declare `raw == len`. The payload is the already-encrypted
//! `WireBatch` body, so the transport adds framing only — TLS-equivalent
//! protection is the payload encryption applied at serialization time.
//!
//! Hot-path shape: `send_batch` issues header + payload as one vectored
//! write (with a short-write continuation loop — `IoSlice::
//! advance_slices` needs a newer MSRV); `recv_batch` reads the payload
//! into reserved-but-unwritten capacity via `Read::take`, so a 64 MiB
//! frame does not pay a zero-fill memset per receive.

use super::worker::WireBatch;
use std::io::{IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};

const FRAME_MAGIC: u32 = 0xD51_F00D;

const HEADER_LEN: usize = 25;

const FLAG_DEDUP: u8 = 0b01;
const FLAG_COMPRESSED: u8 = 0b10;

/// Largest frame payload accepted off the wire (64 MiB — far above any
/// real tensor batch). The length field comes from an untrusted peer: a
/// corrupt header must bound the receive allocation, not choose it.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Largest *declared uncompressed* payload accepted for a given frame
/// cap. zstd on tensor sections rarely exceeds ~4x even on pathological
/// duplication, so 4x bounds the decompression allocation a lying frame
/// can demand while never rejecting a legitimate one.
pub fn max_raw_bytes(frame_cap: usize) -> usize {
    frame_cap.saturating_mul(4)
}

fn invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Send one batch over a TCP stream at the transport-wide cap.
pub fn send_batch(stream: &mut TcpStream, b: &WireBatch) -> std::io::Result<()> {
    send_batch_capped(stream, b, MAX_FRAME_BYTES)
}

/// Send one batch with a session frame cap (`PipelineOptions::
/// max_frame_bytes`, itself bounded by [`MAX_FRAME_BYTES`]). Errors
/// (instead of silently truncating through `as u32`) when the batch
/// can't be represented in the frame header, and refuses to emit a
/// frame the receive side would reject.
pub fn send_batch_capped<W: Write>(
    w: &mut W,
    b: &WireBatch,
    cap: usize,
) -> std::io::Result<()> {
    let cap = cap.min(MAX_FRAME_BYTES);
    if b.bytes.len() > cap {
        return Err(invalid(format!(
            "frame payload {} exceeds cap {cap}",
            b.bytes.len()
        )));
    }
    let rows: u32 = b
        .rows
        .try_into()
        .map_err(|_| invalid(format!("row count {} overflows frame header", b.rows)))?;
    let raw: u32 = b.raw_len.try_into().map_err(|_| {
        invalid(format!("raw size {} overflows frame header", b.raw_len))
    })?;
    if !b.compressed && b.raw_len != b.bytes.len() {
        return Err(invalid(format!(
            "uncompressed frame declares raw {} but carries {} bytes",
            b.raw_len,
            b.bytes.len()
        )));
    }
    if b.compressed && b.raw_len > max_raw_bytes(cap) {
        return Err(invalid(format!(
            "declared raw size {} exceeds decompression cap {}",
            b.raw_len,
            max_raw_bytes(cap)
        )));
    }
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..12].copy_from_slice(&b.seq.to_le_bytes());
    header[12..16].copy_from_slice(&rows.to_le_bytes());
    header[16..20].copy_from_slice(&(b.bytes.len() as u32).to_le_bytes());
    header[20..24].copy_from_slice(&raw.to_le_bytes());
    header[24] = (b.dedup as u8) * FLAG_DEDUP
        + (b.compressed as u8) * FLAG_COMPRESSED;
    // One vectored write for header + payload (instead of two syscalls
    // per frame), continuing through short writes: a partial vectored
    // write must still yield a well-formed frame.
    let total = HEADER_LEN + b.bytes.len();
    let mut written = 0usize;
    while written < total {
        let res = if written < HEADER_LEN {
            let bufs =
                [IoSlice::new(&header[written..]), IoSlice::new(&b.bytes)];
            w.write_vectored(&bufs)
        } else {
            w.write(&b.bytes[written - HEADER_LEN..])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "connection closed mid-frame",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Receive one batch from a TCP stream at the transport-wide cap;
/// `Ok(None)` on clean end-of-stream.
pub fn recv_batch(stream: &mut TcpStream) -> std::io::Result<Option<WireBatch>> {
    recv_batch_capped(stream, MAX_FRAME_BYTES)
}

/// Receive one batch with a session frame cap; `Ok(None)` on clean
/// end-of-stream. Only a connection closed *between* frames is clean —
/// a cut mid-header (or mid-payload) is an error, never a silent
/// truncation of the stream. Every header field is validated before the
/// payload allocation it sizes.
pub fn recv_batch_capped<R: Read>(
    r: &mut R,
    cap: usize,
) -> std::io::Result<Option<WireBatch>> {
    let cap = cap.min(MAX_FRAME_BYTES);
    let mut header = [0u8; HEADER_LEN];
    let mut filled = 0usize;
    while filled < header.len() {
        match r.read(&mut header[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None); // closed on a frame boundary
                }
                return Err(invalid(format!(
                    "connection closed mid-header ({filled} of {} bytes)",
                    header.len()
                )));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(invalid(format!("bad frame magic {magic:#x}")));
    }
    let seq = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let rows = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
    let len = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
    let raw_len =
        u32::from_le_bytes(header[20..24].try_into().unwrap()) as usize;
    let flags = header[24];
    if len > cap {
        // A corrupt frame must not demand an attacker-chosen (up to
        // 4 GiB) allocation before a single payload byte arrives.
        return Err(invalid(format!("frame length {len} exceeds cap {cap}")));
    }
    if flags & !(FLAG_DEDUP | FLAG_COMPRESSED) != 0 {
        return Err(invalid(format!("unknown frame flags {flags:#04x}")));
    }
    let dedup = flags & FLAG_DEDUP != 0;
    let compressed = flags & FLAG_COMPRESSED != 0;
    if compressed {
        if raw_len > max_raw_bytes(cap) {
            // Bound what the decoder will be asked to allocate from the
            // header alone — a lying raw size dies here, before any
            // payload byte is read or buffered.
            return Err(invalid(format!(
                "declared raw size {raw_len} exceeds decompression cap {}",
                max_raw_bytes(cap)
            )));
        }
    } else if raw_len != len {
        return Err(invalid(format!(
            "uncompressed frame declares raw {raw_len} but carries {len} \
             bytes"
        )));
    }
    // Read into reserved-but-unwritten capacity: `take` caps the read at
    // the validated length and `read_to_end` appends without the
    // `vec![0u8; len]` zero-fill pass.
    let mut bytes = Vec::with_capacity(len);
    let got = r.by_ref().take(len as u64).read_to_end(&mut bytes)?;
    if got < len {
        return Err(invalid(format!(
            "connection closed mid-payload ({got} of {len} bytes)"
        )));
    }
    Ok(Some(WireBatch {
        seq,
        rows,
        dedup,
        compressed,
        raw_len,
        bytes,
    }))
}

/// Serve a stream of batches to the first client that connects, then
/// close. Returns the bound address immediately; the serving happens on
/// a background thread (the DPP Worker's "serve tensors" half).
pub fn serve_batches(
    batches: Vec<WireBatch>,
) -> std::io::Result<(std::net::SocketAddr, std::thread::JoinHandle<std::io::Result<()>>)>
{
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let handle = std::thread::spawn(move || -> std::io::Result<()> {
        let (mut stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        for b in &batches {
            send_batch(&mut stream, b)?;
        }
        Ok(())
    });
    Ok((addr, handle))
}

/// Client half: connect and drain all batches.
pub fn fetch_all(addr: std::net::SocketAddr) -> std::io::Result<Vec<WireBatch>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut out = Vec::new();
    while let Some(b) = recv_batch(&mut stream)? {
        out.push(b);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::TensorBatch;
    use crate::dwrf::crypto::StreamCipher;
    use crate::schema::FeatureId;

    fn batch(seq: u64) -> WireBatch {
        let tb = TensorBatch {
            rows: 4,
            dense: vec![seq as f32; 8],
            dense_names: vec![FeatureId(0), FeatureId(1)],
            sparse: vec![(FeatureId(9), vec![0, 1, 2, 2, 3], vec![7, 8, 9])],
            labels: vec![0.0, 1.0, 1.0, 0.0],
        };
        let cipher = StreamCipher::for_table("tcp");
        // dedup flag must survive the framing
        WireBatch::plain(seq, 4, seq % 2 == 1, tb.to_wire(&cipher, seq))
    }

    #[test]
    fn tcp_roundtrip_preserves_batches() {
        let batches: Vec<WireBatch> = (0..16).map(batch).collect();
        let (addr, server) = serve_batches(batches.clone()).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), 16);
        let cipher = StreamCipher::for_table("tcp");
        for (a, b) in got.iter().zip(batches.iter()) {
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.dedup, b.dedup);
            assert_eq!(a.compressed, b.compressed);
            assert_eq!(a.raw_len, b.raw_len);
            assert_eq!(a.bytes, b.bytes);
            // Payload decrypts + deserializes on the far side.
            let tb = TensorBatch::from_wire(&cipher, a.seq, &a.bytes).unwrap();
            assert_eq!(tb.rows, 4);
            assert_eq!(tb.dense[0], a.seq as f32);
        }
    }

    #[test]
    fn tcp_full_worker_stream() {
        // End to end: a real WorkerCore's output (compressed by default)
        // shipped over TCP and consumed like a trainer would.
        use crate::config::{RmConfig, RmId, SimScale};
        use crate::datagen::build_dataset;
        use crate::dpp::{Master, SessionSpec, WorkerCore};
        use crate::dwrf::{Projection, WriterOptions};
        use crate::metrics::EtlMetrics;
        use crate::tectonic::{Cluster, ClusterConfig};
        use crate::transforms::TransformDag;
        use std::sync::Arc;

        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        }));
        let catalog = crate::warehouse::Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &SimScale::tiny(),
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            33,
        )
        .unwrap();
        let feats: Vec<_> =
            h.schema.features.iter().take(6).map(|f| f.id).collect();
        let mut dag = TransformDag::default();
        for &f in &feats {
            let i = dag.input(f);
            dag.output(f, i);
        }
        let mut spec = SessionSpec::from_dag(&h.table_name, 0, 9, dag, 16);
        spec.projection = Projection::new(feats);
        let spec = Arc::new(spec);
        let master = Master::new(&catalog, &cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let metrics = Arc::new(EtlMetrics::default());
        let mut core = WorkerCore::new(spec.clone(), cluster, metrics);
        let mut all = Vec::new();
        while let Some(split) = master.fetch_split(w) {
            all.extend(core.process_split(&split).unwrap());
            master.complete_split(w, split.id);
        }
        let n = all.len();
        assert!(all.iter().all(|b| b.compressed), "default wire is zstd");
        let (addr, server) = serve_batches(all).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), n);
        let cipher = StreamCipher::for_table(&spec.table);
        let rows: usize = got
            .iter()
            .map(|b| crate::dpp::codec::decode_wire(&cipher, b).unwrap().rows)
            .sum();
        assert_eq!(rows, 128);
    }

    #[test]
    fn oversized_length_header_rejected_before_allocation() {
        // A valid-magic frame claiming a ~4 GiB payload must be refused
        // from the header alone — no allocation, no read.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut header = [0u8; HEADER_LEN];
            header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
            header[12..16].copy_from_slice(&4u32.to_le_bytes());
            header[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
            s.write_all(&header).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream).unwrap_err();
        h.join().unwrap();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn lying_raw_length_rejected_before_allocation() {
        // Compressed flag + a ~4 GiB declared raw size: rejected from
        // the header, before the payload is read or buffered.
        let mut header = [0u8; HEADER_LEN];
        header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        header[12..16].copy_from_slice(&4u32.to_le_bytes());
        header[16..20].copy_from_slice(&8u32.to_le_bytes());
        header[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        header[24] = FLAG_COMPRESSED;
        let mut frame = header.to_vec();
        frame.extend_from_slice(&[0u8; 8]);
        let err =
            recv_batch_capped(&mut &frame[..], MAX_FRAME_BYTES).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("decompression cap"), "{err}");
        // An uncompressed frame whose raw field disagrees with len is
        // equally malformed.
        header[24] = 0;
        let err =
            recv_batch_capped(&mut &header[..], MAX_FRAME_BYTES).unwrap_err();
        assert!(err.to_string().contains("declares raw"), "{err}");
        // Unknown flag bits are a framing error, not silently ignored.
        header[20..24].copy_from_slice(&8u32.to_le_bytes());
        header[24] = 0b100;
        let err =
            recv_batch_capped(&mut &header[..], MAX_FRAME_BYTES).unwrap_err();
        assert!(err.to_string().contains("unknown frame flags"), "{err}");
    }

    #[test]
    fn send_refuses_wire_truncation() {
        // Row counts beyond u32 and payloads beyond the frame cap must
        // error out instead of truncating through `as u32` (a receiver
        // would otherwise get a silently-wrong frame).
        let mut sink = Vec::new();
        let big_rows = WireBatch::plain(
            0,
            u32::MAX as usize + 1,
            false,
            Vec::new(),
        );
        let err = send_batch_capped(&mut sink, &big_rows, MAX_FRAME_BYTES)
            .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("row count"), "{err}");
        let big_payload =
            WireBatch::plain(0, 1, false, vec![0u8; MAX_FRAME_BYTES + 1]);
        let err = send_batch_capped(&mut sink, &big_payload, MAX_FRAME_BYTES)
            .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("payload"), "{err}");
        // A raw/len mismatch on an uncompressed frame never leaves the
        // sender (the receiver would reject it anyway).
        let mut lying = WireBatch::plain(0, 1, false, vec![0u8; 4]);
        lying.raw_len = 5;
        let err = send_batch_capped(&mut sink, &lying, MAX_FRAME_BYTES)
            .unwrap_err();
        assert!(err.to_string().contains("declares raw"), "{err}");
        // Nor does a compressed frame whose raw size exceeds what the
        // receiver will accept.
        let mut inflated = WireBatch::plain(0, 1, false, vec![0u8; 4]);
        inflated.compressed = true;
        inflated.raw_len = max_raw_bytes(MAX_FRAME_BYTES) + 1;
        let err = send_batch_capped(&mut sink, &inflated, MAX_FRAME_BYTES)
            .unwrap_err();
        assert!(err.to_string().contains("decompression cap"), "{err}");
        assert!(sink.is_empty(), "no partial frames emitted");
    }

    /// A writer that accepts at most `chunk` bytes per call — including
    /// across the slices of one vectored write — to force every
    /// short-write continuation path.
    struct Trickle {
        out: Vec<u8>,
        chunk: usize,
    }

    impl Write for Trickle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.chunk);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn write_vectored(
            &mut self,
            bufs: &[IoSlice<'_>],
        ) -> std::io::Result<usize> {
            let mut left = self.chunk;
            let mut wrote = 0usize;
            for b in bufs {
                if left == 0 {
                    break;
                }
                let n = b.len().min(left);
                self.out.extend_from_slice(&b[..n]);
                wrote += n;
                left -= n;
            }
            Ok(wrote)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_vectored_writes_still_produce_well_formed_frames() {
        // 3-byte writes split inside the header, across the
        // header/payload boundary, and inside the payload; the frames
        // must reassemble bit-exactly.
        let batches = vec![batch(0), batch(1)];
        let mut w = Trickle {
            out: Vec::new(),
            chunk: 3,
        };
        for b in &batches {
            send_batch_capped(&mut w, b, MAX_FRAME_BYTES).unwrap();
        }
        let mut r: &[u8] = &w.out;
        for b in &batches {
            let got = recv_batch_capped(&mut r, MAX_FRAME_BYTES)
                .unwrap()
                .expect("frame present");
            assert_eq!(got.seq, b.seq);
            assert_eq!(got.rows, b.rows);
            assert_eq!(got.dedup, b.dedup);
            assert_eq!(got.compressed, b.compressed);
            assert_eq!(got.raw_len, b.raw_len);
            assert_eq!(got.bytes, b.bytes);
        }
        assert!(recv_batch_capped(&mut r, MAX_FRAME_BYTES).unwrap().is_none());
    }

    #[test]
    fn max_size_boundary_frame_roundtrips() {
        // Exactly-at-cap frames stay legal (the guard is off-by-one
        // sensitive in both directions). Use a small real payload but a
        // header-boundary row count.
        let tb = TensorBatch {
            rows: 4,
            dense: vec![1.0; 8],
            dense_names: vec![FeatureId(0), FeatureId(1)],
            sparse: vec![],
            labels: vec![0.0; 4],
        };
        let cipher = StreamCipher::for_table("tcp");
        let b = WireBatch::plain(
            7,
            u32::MAX as usize,
            false,
            tb.to_wire(&cipher, 7),
        );
        let (addr, server) = serve_batches(vec![b.clone()]).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rows, u32::MAX as usize);
        assert_eq!(got[0].bytes, b.bytes);
    }

    #[test]
    fn corrupt_frame_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // One full header of zeros: bad magic.
            s.write_all(&[0u8; HEADER_LEN]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream);
        h.join().unwrap();
        assert!(err.is_err());
    }

    #[test]
    fn mid_header_close_is_error_not_silent_truncation() {
        // A peer that dies 24 bytes into a 25-byte header lost data:
        // that must surface as an error, not as clean end-of-stream
        // (which would silently under-deliver training rows).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&[0u8; HEADER_LEN - 1]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream).unwrap_err();
        h.join().unwrap();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("mid-header"), "{err}");
    }

    #[test]
    fn mid_payload_close_is_error() {
        let b = batch(3);
        let mut frame = Vec::new();
        send_batch_capped(&mut frame, &b, MAX_FRAME_BYTES).unwrap();
        frame.truncate(HEADER_LEN + b.bytes.len() / 2);
        let err =
            recv_batch_capped(&mut &frame[..], MAX_FRAME_BYTES).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("mid-payload"), "{err}");
    }

    #[test]
    fn close_on_frame_boundary_is_clean_end_of_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            drop(s); // close without writing anything
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let got = recv_batch(&mut stream).unwrap();
        h.join().unwrap();
        assert!(got.is_none());
    }
}
