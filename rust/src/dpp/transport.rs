//! TCP transport for the worker→client tensor stream: the actual
//! disaggregation boundary. In-process sessions use channels; this module
//! carries the identical wire frames over sockets so Workers and Clients
//! can live on different hosts (as in production, where each Client keeps
//! a capped set of connections to its partition of Workers).
//!
//! Frame: `[magic u32][seq u64][rows u32][len u32][flags u8][payload]`,
//! little endian (flags bit 0: payload is a dedup wire batch). The
//! payload is the already-encrypted `WireBatch` body, so the transport
//! adds framing only — TLS-equivalent protection is the payload
//! encryption applied at serialization time.

use super::worker::WireBatch;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

const FRAME_MAGIC: u32 = 0xD51_F00D;

/// Largest frame payload accepted off the wire (64 MiB — far above any
/// real tensor batch). The length field comes from an untrusted peer: a
/// corrupt header must bound the receive allocation, not choose it.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

fn invalid(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Send one batch over a stream. Errors (instead of silently truncating
/// through `as u32`) when the batch can't be represented in the frame
/// header.
pub fn send_batch(stream: &mut TcpStream, b: &WireBatch) -> std::io::Result<()> {
    if b.bytes.len() > MAX_FRAME_BYTES {
        return Err(invalid(format!(
            "frame payload {} exceeds cap {MAX_FRAME_BYTES}",
            b.bytes.len()
        )));
    }
    let rows: u32 = b
        .rows
        .try_into()
        .map_err(|_| invalid(format!("row count {} overflows frame header", b.rows)))?;
    let mut header = [0u8; 21];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..12].copy_from_slice(&b.seq.to_le_bytes());
    header[12..16].copy_from_slice(&rows.to_le_bytes());
    header[16..20].copy_from_slice(&(b.bytes.len() as u32).to_le_bytes());
    header[20] = b.dedup as u8;
    stream.write_all(&header)?;
    stream.write_all(&b.bytes)
}

/// Receive one batch; `Ok(None)` on clean end-of-stream. Only a
/// connection closed *between* frames is clean — a cut mid-header (or
/// mid-payload) is an error, never a silent truncation of the stream.
pub fn recv_batch(stream: &mut TcpStream) -> std::io::Result<Option<WireBatch>> {
    let mut header = [0u8; 21];
    let mut filled = 0usize;
    while filled < header.len() {
        match stream.read(&mut header[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None); // closed on a frame boundary
                }
                return Err(invalid(format!(
                    "connection closed mid-header ({filled} of {} bytes)",
                    header.len()
                )));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame magic {magic:#x}"),
        ));
    }
    let seq = u64::from_le_bytes(header[4..12].try_into().unwrap());
    let rows = u32::from_le_bytes(header[12..16].try_into().unwrap()) as usize;
    let len = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        // A corrupt frame must not demand an attacker-chosen (up to
        // 4 GiB) allocation before a single payload byte arrives.
        return Err(invalid(format!(
            "frame length {len} exceeds cap {MAX_FRAME_BYTES}"
        )));
    }
    let dedup = header[20] & 1 == 1;
    let mut bytes = vec![0u8; len];
    stream.read_exact(&mut bytes)?;
    Ok(Some(WireBatch {
        seq,
        rows,
        dedup,
        bytes,
    }))
}

/// Serve a stream of batches to the first client that connects, then
/// close. Returns the bound address immediately; the serving happens on
/// a background thread (the DPP Worker's "serve tensors" half).
pub fn serve_batches(
    batches: Vec<WireBatch>,
) -> std::io::Result<(std::net::SocketAddr, std::thread::JoinHandle<std::io::Result<()>>)>
{
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let handle = std::thread::spawn(move || -> std::io::Result<()> {
        let (mut stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        for b in &batches {
            send_batch(&mut stream, b)?;
        }
        Ok(())
    });
    Ok((addr, handle))
}

/// Client half: connect and drain all batches.
pub fn fetch_all(addr: std::net::SocketAddr) -> std::io::Result<Vec<WireBatch>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut out = Vec::new();
    while let Some(b) = recv_batch(&mut stream)? {
        out.push(b);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dpp::TensorBatch;
    use crate::dwrf::crypto::StreamCipher;
    use crate::schema::FeatureId;

    fn batch(seq: u64) -> WireBatch {
        let tb = TensorBatch {
            rows: 4,
            dense: vec![seq as f32; 8],
            dense_names: vec![FeatureId(0), FeatureId(1)],
            sparse: vec![(FeatureId(9), vec![0, 1, 2, 2, 3], vec![7, 8, 9])],
            labels: vec![0.0, 1.0, 1.0, 0.0],
        };
        let cipher = StreamCipher::for_table("tcp");
        WireBatch {
            seq,
            rows: 4,
            dedup: seq % 2 == 1, // flag must survive the framing
            bytes: tb.to_wire(&cipher, seq),
        }
    }

    #[test]
    fn tcp_roundtrip_preserves_batches() {
        let batches: Vec<WireBatch> = (0..16).map(batch).collect();
        let (addr, server) = serve_batches(batches.clone()).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), 16);
        let cipher = StreamCipher::for_table("tcp");
        for (a, b) in got.iter().zip(batches.iter()) {
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.dedup, b.dedup);
            assert_eq!(a.bytes, b.bytes);
            // Payload decrypts + deserializes on the far side.
            let tb = TensorBatch::from_wire(&cipher, a.seq, &a.bytes).unwrap();
            assert_eq!(tb.rows, 4);
            assert_eq!(tb.dense[0], a.seq as f32);
        }
    }

    #[test]
    fn tcp_full_worker_stream() {
        // End to end: a real WorkerCore's output shipped over TCP and
        // consumed like a trainer would.
        use crate::config::{RmConfig, RmId, SimScale};
        use crate::datagen::build_dataset;
        use crate::dpp::{Master, SessionSpec, WorkerCore};
        use crate::dwrf::{Projection, WriterOptions};
        use crate::metrics::EtlMetrics;
        use crate::tectonic::{Cluster, ClusterConfig};
        use crate::transforms::TransformDag;
        use std::sync::Arc;

        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        }));
        let catalog = crate::warehouse::Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &SimScale::tiny(),
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            33,
        )
        .unwrap();
        let feats: Vec<_> =
            h.schema.features.iter().take(6).map(|f| f.id).collect();
        let mut dag = TransformDag::default();
        for &f in &feats {
            let i = dag.input(f);
            dag.output(f, i);
        }
        let mut spec = SessionSpec::from_dag(&h.table_name, 0, 9, dag, 16);
        spec.projection = Projection::new(feats);
        let spec = Arc::new(spec);
        let master = Master::new(&catalog, &cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let metrics = Arc::new(EtlMetrics::default());
        let mut core = WorkerCore::new(spec.clone(), cluster, metrics);
        let mut all = Vec::new();
        while let Some(split) = master.fetch_split(w) {
            all.extend(core.process_split(&split).unwrap());
            master.complete_split(w, split.id);
        }
        let n = all.len();
        let (addr, server) = serve_batches(all).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), n);
        let cipher = StreamCipher::for_table(&spec.table);
        let rows: usize = got
            .iter()
            .map(|b| {
                TensorBatch::from_wire(&cipher, b.seq, &b.bytes)
                    .unwrap()
                    .rows
            })
            .sum();
        assert_eq!(rows, 128);
    }

    #[test]
    fn oversized_length_header_rejected_before_allocation() {
        // A valid-magic frame claiming a ~4 GiB payload must be refused
        // from the header alone — no allocation, no read.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut header = [0u8; 21];
            header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
            header[12..16].copy_from_slice(&4u32.to_le_bytes());
            header[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
            s.write_all(&header).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream).unwrap_err();
        h.join().unwrap();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn send_refuses_wire_truncation() {
        // Row counts beyond u32 and payloads beyond the frame cap must
        // error out instead of truncating through `as u32` (a receiver
        // would otherwise get a silently-wrong frame).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accepter = std::thread::spawn(move || listener.accept().unwrap());
        let mut stream = TcpStream::connect(addr).unwrap();
        let _held = accepter.join().unwrap();
        let big_rows = WireBatch {
            seq: 0,
            rows: u32::MAX as usize + 1,
            dedup: false,
            bytes: Vec::new(),
        };
        let err = send_batch(&mut stream, &big_rows).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("row count"), "{err}");
        let big_payload = WireBatch {
            seq: 0,
            rows: 1,
            dedup: false,
            bytes: vec![0u8; MAX_FRAME_BYTES + 1],
        };
        let err = send_batch(&mut stream, &big_payload).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("payload"), "{err}");
    }

    #[test]
    fn max_size_boundary_frame_roundtrips() {
        // Exactly-at-cap frames stay legal (the guard is off-by-one
        // sensitive in both directions). Use a small real payload but a
        // header-boundary row count.
        let tb = TensorBatch {
            rows: 4,
            dense: vec![1.0; 8],
            dense_names: vec![FeatureId(0), FeatureId(1)],
            sparse: vec![],
            labels: vec![0.0; 4],
        };
        let cipher = StreamCipher::for_table("tcp");
        let b = WireBatch {
            seq: 7,
            rows: u32::MAX as usize,
            dedup: false,
            bytes: tb.to_wire(&cipher, 7),
        };
        let (addr, server) = serve_batches(vec![b.clone()]).unwrap();
        let got = fetch_all(addr).unwrap();
        server.join().unwrap().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rows, u32::MAX as usize);
        assert_eq!(got[0].bytes, b.bytes);
    }

    #[test]
    fn corrupt_frame_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // One full header of zeros: bad magic (a 20-byte write —
            // the pre-dedup-flag header size — only exercised the
            // clean-EOF path and asserted nothing).
            s.write_all(&[0u8; 21]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream);
        h.join().unwrap();
        assert!(err.is_err());
    }

    #[test]
    fn mid_header_close_is_error_not_silent_truncation() {
        // A peer that dies 20 bytes into a 21-byte header lost data:
        // that must surface as an error, not as clean end-of-stream
        // (which would silently under-deliver training rows).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&[0u8; 20]).unwrap();
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let err = recv_batch(&mut stream).unwrap_err();
        h.join().unwrap();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("mid-header"), "{err}");
    }

    #[test]
    fn close_on_frame_boundary_is_clean_end_of_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            drop(s); // close without writing anything
        });
        let mut stream = TcpStream::connect(addr).unwrap();
        let got = recv_batch(&mut stream).unwrap();
        h.join().unwrap();
        assert!(got.is_none());
    }
}
