//! DPP Workers — the data plane (§3.2.1): stateless executors that
//! "extract, transform, and (partially) load training data":
//!
//! 1. **extract** — read raw Tectonic extents, decrypt, decompress,
//!    decode into batches, filtering unused features;
//! 2. **transform** — run the session's per-feature transform DAG;
//! 3. **load** — batch features into tensors and serialize them onto the
//!    wire for Clients, keeping a small buffer to absorb transient
//!    delays.
//!
//! [`WorkerCore`] is the synchronous pipeline (benchable in isolation);
//! [`Worker`] wraps it in a thread with a bounded tensor buffer and the
//! Master heartbeat loop.

use super::cache::{
    batch_content_fingerprint, dag_node_fingerprints, prefix_inputs,
    session_fingerprint, TensorCache, TransformCache,
};
use super::codec::WirePacker;
use super::master::{Master, WorkerId};
use super::spec::SessionSpec;
use super::split::Split;
use super::tensor::{DedupTensorBatch, TensorBatch};
use crate::broker::BrokerHandle;
use crate::data::ColumnarBatch;
use crate::dwrf::crypto::StreamCipher;
use crate::dwrf::{DecodeMode, DedupStripe, DwrfReader, Encoding, FileMeta};
use crate::metrics::EtlMetrics;
use crate::obs::{ObsHandle, Stage};
use crate::schema::FeatureId;
use crate::tectonic::{Cluster, FileId};
use crate::transforms::Value;
use anyhow::Result;
use std::collections::HashMap;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

/// One fetched stripe on the private decode path: (stripe index,
/// fetched buffers, row-group survival mask from the plan).
type PlannedStripeBufs = (usize, crate::dwrf::IoBuffers, Option<Vec<bool>>);

/// A serialized tensor batch on the worker→client wire.
#[derive(Clone, Debug)]
pub struct WireBatch {
    pub seq: u64,
    /// Trainer-visible rows (after dedup expansion, when applicable).
    pub rows: usize,
    /// Payload is a [`DedupTensorBatch`] (inverse-keyed unique tensors)
    /// rather than a plain [`TensorBatch`]; the Client expands it.
    pub dedup: bool,
    /// Payload uses the section-framed wire codec (zstd per feature
    /// stream) rather than the plain serialization.
    pub compressed: bool,
    /// Declared pre-compression payload size. For uncompressed frames
    /// this must equal `bytes.len()`; for compressed frames it bounds
    /// every decode-side allocation before it is made.
    pub raw_len: usize,
    pub bytes: Vec<u8>,
}

impl WireBatch {
    /// An uncompressed frame (the legacy wire: plain serialization,
    /// encrypted).
    pub fn plain(seq: u64, rows: usize, dedup: bool, bytes: Vec<u8>) -> WireBatch {
        WireBatch {
            seq,
            rows,
            dedup,
            compressed: false,
            raw_len: bytes.len(),
            bytes,
        }
    }
}

/// The synchronous extract→transform→load pipeline.
pub struct WorkerCore {
    pub spec: Arc<SessionSpec>,
    cluster: Arc<Cluster>,
    cipher: StreamCipher,
    /// Footer cache (worker-local; rebuilt from storage after restart —
    /// workers hold no session-critical state).
    meta_cache: HashMap<FileId, Arc<FileMeta>>,
    pub metrics: Arc<EtlMetrics>,
    /// Optional shared preprocessed-tensor cache (§7.5).
    tensor_cache: Option<Arc<TensorCache>>,
    /// Optional cross-job transform-output cache: per-output results
    /// keyed by (input-content fingerprint, canonical DAG-prefix
    /// fingerprint), so sessions sharing a DAG prefix transform each
    /// unique payload once fleet-wide.
    transform_cache: Option<Arc<TransformCache>>,
    /// Per-output transform-cache plan, parallel to `spec.dag.outputs`:
    /// (producing node, DAG-prefix fingerprint, sub-DAG input features).
    xform_plan: Vec<(usize, u64, Vec<FeatureId>)>,
    /// Optional cross-job read broker (shared storage scans); used when
    /// `PipelineOptions::shared_reads` is on.
    broker: Option<BrokerHandle>,
    /// Wire encoder (per-feature zstd framing, or the legacy plain wire
    /// when compression is off); owns the zstd context + scratch.
    packer: WirePacker,
    fingerprint: u64,
    seq: u64,
    /// Optional span sink; `tid` is this worker's trace lane and
    /// `cur_split` labels spans with the split being processed.
    obs: Option<ObsHandle>,
    tid: u32,
    cur_split: u64,
}

impl WorkerCore {
    pub fn new(
        spec: Arc<SessionSpec>,
        cluster: Arc<Cluster>,
        metrics: Arc<EtlMetrics>,
    ) -> WorkerCore {
        let xform_plan = {
            let fps = dag_node_fingerprints(&spec.dag);
            spec.dag
                .outputs
                .iter()
                .map(|&(_, n)| (n, fps[n], prefix_inputs(&spec.dag, n)))
                .collect()
        };
        WorkerCore {
            cipher: StreamCipher::for_table(&spec.table),
            fingerprint: session_fingerprint(&spec),
            // Real sessions validate options at Master intake; a bad
            // level/dictionary here means the caller skipped that.
            packer: WirePacker::new(&spec.pipeline)
                .expect("valid wire_compression options"),
            xform_plan,
            spec,
            cluster,
            meta_cache: HashMap::new(),
            metrics,
            tensor_cache: None,
            transform_cache: None,
            broker: None,
            seq: 0,
            obs: None,
            tid: 0,
            cur_split: 0,
        }
    }

    /// Attach a shared preprocessed-tensor cache (§7.5): identical
    /// (session, split) work is served from memory, skipping storage,
    /// extraction, and transformation.
    pub fn with_tensor_cache(mut self, cache: Arc<TensorCache>) -> WorkerCore {
        self.tensor_cache = Some(cache);
        self
    }

    /// Attach a cross-job transform-output cache: outputs whose DAG
    /// prefix and input bytes match an entry any session computed are
    /// served from memory, and only the missing sub-DAGs run. Outputs
    /// are byte-identical either way (every transform op is
    /// deterministic).
    pub fn with_transform_cache(
        mut self,
        cache: Arc<TransformCache>,
    ) -> WorkerCore {
        self.transform_cache = Some(cache);
        self
    }

    /// Attach the session's read-broker handle (from
    /// [`Master::broker_handle`]): stripes are fetched and decoded once
    /// across every attached session, then filtered / transformed
    /// per-session downstream.
    pub fn with_broker(mut self, handle: BrokerHandle) -> WorkerCore {
        self.broker = Some(handle);
        self
    }

    /// Emit per-stage spans + histogram records on `handle`, lane `tid`
    /// (the worker id). A `None` handle costs one branch per stage.
    pub fn with_obs(mut self, handle: ObsHandle, tid: u32) -> WorkerCore {
        self.obs = Some(handle);
        self.tid = tid;
        self
    }

    #[inline]
    fn span(&self, stage: Stage, t0: Instant) {
        if let Some(h) = &self.obs {
            h.span(self.tid, self.cur_split, stage, t0);
        }
    }

    fn reader_for(&mut self, file: FileId) -> Result<DwrfReader> {
        let meta = match self.meta_cache.get(&file) {
            Some(m) => m.clone(),
            None => {
                let m = match &self.broker {
                    // Cross-session footer cache.
                    Some(h) => h.broker.footer(file)?,
                    None => Arc::new(Master::fetch_meta(&self.cluster, file)?),
                };
                self.meta_cache.insert(file, m.clone());
                m
            }
        };
        Ok(DwrfReader::from_meta(
            (*meta).clone(),
            &self.spec.table,
        ))
    }

    /// Process one split end-to-end, producing wire-ready tensor batches.
    pub fn process_split(&mut self, split: &Split) -> Result<Vec<WireBatch>> {
        let spec = self.spec.clone();
        let m = self.metrics.clone();
        self.cur_split = split.id.0;

        // ---- tensor cache: a prior identical job/epoch already did this
        // split's work (§7.5) ----
        if let Some(cache) = &self.tensor_cache {
            if let Some(batches) = cache.get(self.fingerprint, split) {
                for b in batches.iter() {
                    m.tensor_tx_bytes.add(b.bytes.len() as u64);
                    m.wire_raw_bytes.add(b.raw_len as u64);
                    m.samples.add(b.rows as u64);
                    m.batches.inc();
                }
                return Ok(batches.as_ref().clone());
            }
        }

        // ---- read: plan + fetch raw extents from storage ----
        // With pushdown on, the predicate prunes provably-empty stripes
        // here — before any I/O is issued — and, one level down,
        // provably-empty *row groups* inside surviving stripes (footer
        // v3 zone maps): their rows never decode, and on
        // row-group-split flattened files their byte ranges are dropped
        // from the I/O plan outright. The baseline plans every stripe
        // and filters after decode.
        let t = Instant::now();
        let reader = self.reader_for(split.file)?;
        let pushdown_pred = if spec.pipeline.pushdown {
            spec.predicate.as_ref()
        } else {
            None
        };
        let plan = reader.plan_stripes_granular(
            &spec.projection,
            spec.pipeline.coalesce,
            split.stripe_start,
            split.stripe_count,
            pushdown_pred,
            spec.pipeline.row_group_pruning,
        );
        m.skipped_stripes.add(plan.skipped_stripes.len() as u64);
        m.skipped_bytes.add(plan.skipped_bytes);
        m.pruned_groups.add(plan.pruned_groups);
        m.pruned_group_rows.add(plan.pruned_group_rows);
        m.pruned_group_bytes.add(plan.pruned_group_bytes);
        self.span(Stage::Plan, t);

        // The dedup path evaluates the DAG once per unique payload, which
        // is only sound when no op reads the row index (`Sampling` does);
        // such sessions silently fall back to the oblivious path.
        let use_dedup = spec.pipeline.dedup_aware
            && reader.meta.encoding == Encoding::Dedup
            && !spec.dag.row_index_sensitive();

        let shared = if spec.pipeline.shared_reads {
            self.broker.clone()
        } else {
            None
        };
        let wire = if let Some(h) = shared {
            // ---- shared-read path: fetch through the broker. Each
            // surviving stripe (or column) is fetched + decoded once
            // across all attached sessions (the broker cannot apply any
            // one session's predicate); this session's row-group mask,
            // projection, predicate, and transforms apply to its own
            // view downstream — pruned groups are dropped before their
            // rows are ever materialized into this session's batches.
            //
            // Column grain serves this session's projection from any
            // *wider* cached decode, per-(file, stripe, column). The
            // stripe-grain path stays as the `column_sharing = false`
            // ablation, and as the fallback for Map files (row-wise
            // streams don't split into columns) and for oblivious scans
            // of Dedup files (which need the broker's expanded view).
            let use_columns = spec.pipeline.column_sharing
                && (reader.meta.encoding == Encoding::Flattened
                    || (reader.meta.encoding == Encoding::Dedup
                        && use_dedup));
            if use_columns {
                let t_fetch = Instant::now();
                let mut handles = Vec::new();
                for sp in &plan.stripes {
                    let served = h.broker.get_columns(
                        h.session,
                        split.file,
                        sp.stripe,
                    )?;
                    if served.from_buffer {
                        m.shared_reads.inc();
                    } else {
                        m.storage_rx_bytes.add(served.fetched_bytes);
                    }
                    let keep = sp.group_mask.as_ref().map(|mask| {
                        reader.meta.stripes[sp.stripe].keep_rows(mask)
                    });
                    handles.push((sp.stripe, served, keep));
                }
                m.t_read.add(t.elapsed());
                self.span(Stage::Fetch, t_fetch);
                if use_dedup {
                    let t_dec = Instant::now();
                    let stripes = handles
                        .iter()
                        .map(|(stripe, served, keep)| {
                            let ds = reader.assemble_dedup(
                                *stripe,
                                &spec.projection,
                                &served.cols,
                            )?;
                            Ok(match keep {
                                Some(k) => ds.filter_rows(k),
                                None => ds,
                            })
                        })
                        .collect::<Result<Vec<DedupStripe>>>()?;
                    self.span(Stage::Decode, t_dec);
                    self.finish_dedup(stripes)?
                } else {
                    let t_dec = Instant::now();
                    let batches = handles
                        .iter()
                        .map(|(stripe, served, keep)| {
                            let b = reader.assemble_columnar(
                                *stripe,
                                &spec.projection,
                                &served.cols,
                            )?;
                            Ok(match keep {
                                Some(k) => b.gather(k),
                                None => b,
                            })
                        })
                        .collect::<Result<Vec<ColumnarBatch>>>()?;
                    self.span(Stage::Decode, t_dec);
                    self.finish_oblivious(batches)?
                }
            } else {
                let t_fetch = Instant::now();
                let mut handles = Vec::new();
                for sp in &plan.stripes {
                    let served = h
                        .broker
                        .get_stripe(h.session, split.file, sp.stripe)?;
                    if served.from_buffer {
                        m.shared_reads.inc();
                    } else {
                        m.storage_rx_bytes.add(served.fetched_bytes);
                    }
                    let keep = sp.group_mask.as_ref().map(|mask| {
                        reader.meta.stripes[sp.stripe].keep_rows(mask)
                    });
                    handles.push((served.stripe, keep));
                }
                m.t_read.add(t.elapsed());
                self.span(Stage::Fetch, t_fetch);
                if use_dedup {
                    let t_dec = Instant::now();
                    let stripes = handles
                        .iter()
                        .map(|(s, keep)| {
                            let ds = s.to_dedup(&spec.projection)?;
                            Ok(match keep {
                                Some(k) => ds.filter_rows(k),
                                None => ds,
                            })
                        })
                        .collect::<Result<Vec<DedupStripe>>>()?;
                    self.span(Stage::Decode, t_dec);
                    self.finish_dedup(stripes)?
                } else {
                    let t_dec = Instant::now();
                    let batches: Vec<ColumnarBatch> = handles
                        .iter()
                        .map(|(s, keep)| {
                            s.to_columnar_masked(
                                &spec.projection,
                                keep.as_deref(),
                            )
                        })
                        .collect();
                    self.span(Stage::Decode, t_dec);
                    self.finish_oblivious(batches)?
                }
            }
        } else {
            // ---- private path: per-session I/O + decode. The plan's
            // I/O set already excludes pruned row groups' stream
            // extents where the layout permits.
            let t_fetch = Instant::now();
            let mut bufs_per_stripe = Vec::new();
            for sp in &plan.stripes {
                let bufs = self.cluster.execute_ios(split.file, &sp.ios)?;
                m.storage_rx_bytes.add(bufs.bytes());
                bufs_per_stripe.push((
                    sp.stripe,
                    bufs,
                    sp.group_mask.clone(),
                ));
            }
            m.t_read.add(t.elapsed());
            self.span(Stage::Fetch, t_fetch);
            if use_dedup {
                let stripes = self.decode_dedup(&reader, &bufs_per_stripe)?;
                self.finish_dedup(stripes)?
            } else {
                let batches =
                    self.decode_oblivious(&reader, &bufs_per_stripe)?;
                self.finish_oblivious(batches)?
            }
        };
        if let Some(cache) = &self.tensor_cache {
            cache.put(self.fingerprint, split, Arc::new(wire.clone()));
        }
        Ok(wire)
    }

    /// Private-path decode: decrypt + decompress + decode each fetched
    /// stripe into a columnar batch (the shared path gets these from the
    /// broker's decode-once buffer instead). The per-stripe row-group
    /// mask is honored: pruned groups never become batch rows.
    fn decode_oblivious(
        &mut self,
        reader: &DwrfReader,
        bufs_per_stripe: &[PlannedStripeBufs],
    ) -> Result<Vec<ColumnarBatch>> {
        let spec = self.spec.clone();
        let t = Instant::now();
        let mode = DecodeMode {
            fast: spec.pipeline.fast_decode,
        };
        let mut batches: Vec<ColumnarBatch> = Vec::new();
        for (stripe, bufs, mask) in bufs_per_stripe {
            let mask = mask.as_deref();
            let batch = if spec.pipeline.flatmap {
                // Flatmap path: storage → columnar directly.
                reader.decode_stripe_columnar_masked(
                    *stripe,
                    bufs,
                    &spec.projection,
                    mode,
                    mask,
                )?
            } else {
                // Baseline path: storage → row maps → columnar (the extra
                // format conversions +FM removes).
                let rows = reader.decode_stripe_rows_masked(
                    *stripe,
                    bufs,
                    &spec.projection,
                    mode,
                    mask,
                )?;
                let mut dense_ids: Vec<_> = rows
                    .iter()
                    .flat_map(|s| s.dense.iter().map(|(f, _)| *f))
                    .collect();
                dense_ids.sort();
                dense_ids.dedup();
                let mut sparse_ids: Vec<_> = rows
                    .iter()
                    .flat_map(|s| s.sparse.iter().map(|(f, _)| *f))
                    .collect();
                sparse_ids.sort();
                sparse_ids.dedup();
                ColumnarBatch::from_samples(&rows, &dense_ids, &sparse_ids)
            };
            batches.push(batch);
        }
        self.metrics.t_extract.add(t.elapsed());
        self.span(Stage::Decode, t);
        Ok(batches)
    }

    /// Run the session DAG over one batch. With a transform cache
    /// attached, each output is first looked up by (content fingerprint
    /// of its sub-DAG's input columns, DAG-prefix fingerprint); only the
    /// sub-DAGs of missing outputs execute, and their results are
    /// published for other sessions. Without a cache this is exactly
    /// [`TransformDag::execute`] — and with one, outputs are still
    /// byte-identical, because every op is deterministic in its inputs.
    fn transform_batch(
        &self,
        batch: &ColumnarBatch,
    ) -> Result<Vec<(FeatureId, Value)>> {
        let spec = &self.spec;
        let Some(cache) = self.transform_cache.clone() else {
            let (outputs, _stats) = spec.dag.execute(batch)?;
            return Ok(outputs);
        };
        let mut keys = Vec::with_capacity(self.xform_plan.len());
        let mut cached: Vec<Option<Arc<Value>>> =
            Vec::with_capacity(self.xform_plan.len());
        let mut missing_nodes: Vec<usize> = Vec::new();
        for (node, prefix_fp, inputs) in &self.xform_plan {
            let content_fp = batch_content_fingerprint(batch, inputs);
            let hit = cache.get(content_fp, *prefix_fp);
            if hit.is_none() {
                missing_nodes.push(*node);
            }
            keys.push((content_fp, *prefix_fp));
            cached.push(hit);
        }
        let hits = cached.iter().filter(|c| c.is_some()).count();
        if hits > 0 {
            self.metrics.transform_reuse_hits.add(hits as u64);
            self.metrics
                .transform_reused_rows
                .add((hits * batch.num_rows) as u64);
        }
        let slots = if missing_nodes.is_empty() {
            Vec::new()
        } else {
            missing_nodes.sort_unstable();
            missing_nodes.dedup();
            let (slots, _stats) =
                spec.dag.execute_subset(batch, &missing_nodes)?;
            slots
        };
        let mut outputs = Vec::with_capacity(spec.dag.outputs.len());
        for (i, &(fid, node)) in spec.dag.outputs.iter().enumerate() {
            let v = match &cached[i] {
                Some(v) => (**v).clone(),
                None => {
                    let v = slots[node]
                        .clone()
                        .expect("missing output was computed");
                    let (cfp, pfp) = keys[i];
                    cache.put(cfp, pfp, Arc::new(v.clone()));
                    v
                }
            };
            outputs.push((fid, v));
        }
        Ok(outputs)
    }

    /// The duplication-oblivious filter→transform→load stages over
    /// decoded stripe batches (every encoding; Dedup stripes arrive
    /// already expanded).
    fn finish_oblivious(
        &mut self,
        raw: Vec<ColumnarBatch>,
    ) -> Result<Vec<WireBatch>> {
        let spec = self.spec.clone();
        let m = self.metrics.clone();

        // ---- filter: selection vectors over decoded rows ----
        let t = Instant::now();
        let mut batches: Vec<ColumnarBatch> = Vec::new();
        for batch in raw {
            m.decoded_rows.add(batch.num_rows as u64);
            m.extract_out_bytes.add(batch.approx_bytes() as u64);
            // Row filter: a partially-matching stripe decodes once; the
            // predicate yields a selection vector and only surviving
            // rows flow into transform + load.
            let batch = match spec.predicate.as_ref() {
                Some(p) => {
                    let keep = p.select_batch(&batch).ones();
                    m.filtered_rows.add((batch.num_rows - keep.len()) as u64);
                    if keep.len() == batch.num_rows {
                        batch
                    } else {
                        batch.with_selection(keep).compact()
                    }
                }
                None => batch,
            };
            if batch.num_rows > 0 {
                batches.push(batch);
            }
        }
        m.t_extract.add(t.elapsed());
        self.span(Stage::Decode, t);

        // ---- transform: run the DAG per stripe batch (outputs served
        // from the cross-job transform cache when one is attached) ----
        let t = Instant::now();
        let mut transformed = Vec::new();
        for batch in batches {
            let outputs = self.transform_batch(&batch)?;
            let out_bytes: usize = outputs
                .iter()
                .map(|(_, v)| v.elements() * 8)
                .sum();
            m.transform_out_bytes.add(out_bytes as u64);
            m.transform_rows.add(batch.num_rows as u64);
            let rows = batch.num_rows;
            // Move the labels out — the batch is spent after the DAG ran.
            transformed.push((outputs, batch.labels, rows));
        }
        m.t_transform.add(t.elapsed());
        self.span(Stage::Transform, t);

        // ---- load: batch into tensors, encode + encrypt in one pass ----
        let t = Instant::now();
        let mut wire = Vec::new();
        for (outputs, labels, num_rows) in &transformed {
            let mut row = 0;
            while row < *num_rows {
                let end = (row + spec.batch_size).min(*num_rows);
                let tb = TensorBatch::from_outputs(outputs, labels, row, end);
                let seq = self.seq;
                self.seq += 1;
                let t_enc = Instant::now();
                let wb = self.packer.encode_tensor(&self.cipher, seq, &tb)?;
                m.t_compress.add(t_enc.elapsed());
                m.tensor_tx_bytes.add(wb.bytes.len() as u64);
                m.wire_raw_bytes.add(wb.raw_len as u64);
                m.samples.add((end - row) as u64);
                m.batches.inc();
                wire.push(wb);
                row = end;
            }
        }
        m.t_load.add(t.elapsed());
        self.span(Stage::Load, t);
        Ok(wire)
    }

    /// Private-path dedup decode: unique payloads + inverse, without
    /// expansion (the shared path gets these from the broker instead).
    /// Row-group masks prune at the expansion index: dropped rows leave
    /// the inverse, and payloads only they referenced compact away.
    fn decode_dedup(
        &mut self,
        reader: &DwrfReader,
        bufs_per_stripe: &[PlannedStripeBufs],
    ) -> Result<Vec<DedupStripe>> {
        let spec = self.spec.clone();
        let t = Instant::now();
        let mode = DecodeMode {
            fast: spec.pipeline.fast_decode,
        };
        let mut stripes = Vec::new();
        for (stripe, bufs, mask) in bufs_per_stripe {
            stripes.push(reader.decode_stripe_dedup_masked(
                *stripe,
                bufs,
                &spec.projection,
                mode,
                mask.as_deref(),
            )?);
        }
        self.metrics.t_extract.add(t.elapsed());
        self.span(Stage::Decode, t);
        Ok(stripes)
    }

    /// The dedup-aware stages (RecD): filter rows without expansion,
    /// transform each unique payload **once**, and ship inverse-keyed
    /// wire batches the Client expands — per-row extract/transform/wire
    /// cost collapses by the stripe's duplication factor.
    fn finish_dedup(
        &mut self,
        raw: Vec<DedupStripe>,
    ) -> Result<Vec<WireBatch>> {
        let spec = self.spec.clone();
        let m = self.metrics.clone();

        // ---- filter: unique payloads only ----
        let t = Instant::now();
        let mut stripes = Vec::new();
        for ds in raw {
            m.decoded_rows.add(ds.rows() as u64);
            m.extract_out_bytes.add(ds.unique.approx_bytes() as u64);
            // Row filter without expansion: the predicate reads per-row
            // labels/timestamps and answers feature presence through the
            // inverse index — content-keyed, so it composes with dedup.
            // Unreferenced unique payloads are compacted away before the
            // transform stage ever sees them.
            let ds = match spec.predicate.as_ref() {
                Some(p) => {
                    let keep = p
                        .select_rows(&ds.labels, &ds.timestamps, &|f, r| {
                            crate::filter::batch_presence(
                                &ds.unique,
                                f,
                                ds.inverse[r] as usize,
                            )
                        })
                        .ones();
                    m.filtered_rows.add((ds.rows() - keep.len()) as u64);
                    if keep.len() == ds.rows() {
                        ds
                    } else {
                        ds.filter_rows(&keep)
                    }
                }
                None => ds,
            };
            if ds.rows() > 0 {
                stripes.push(ds);
            }
        }
        m.t_extract.add(t.elapsed());
        self.span(Stage::Decode, t);

        // ---- transform: each unique payload exactly once ----
        let t = Instant::now();
        let mut transformed = Vec::new();
        for ds in stripes {
            let outputs = self.transform_batch(&ds.unique)?;
            let out_bytes: usize =
                outputs.iter().map(|(_, v)| v.elements() * 8).sum();
            m.transform_out_bytes.add(out_bytes as u64);
            m.transform_rows.add(ds.unique.num_rows as u64);
            m.dedup_saved_rows
                .add((ds.rows() - ds.unique.num_rows) as u64);
            transformed.push((outputs, ds));
        }
        m.t_transform.add(t.elapsed());
        self.span(Stage::Transform, t);

        // ---- load: inverse-keyed wire batches over the full rows ----
        let t = Instant::now();
        let mut wire = Vec::new();
        for (outputs, ds) in &transformed {
            // Scratch map: global unique id → slot in this wire batch.
            let mut slot: Vec<u32> = vec![u32::MAX; ds.unique.num_rows];
            let rows = ds.rows();
            let mut row = 0;
            while row < rows {
                let end = (row + spec.batch_size).min(rows);
                let mut local_uniques: Vec<u32> = Vec::new();
                let mut local_inverse: Vec<u32> =
                    Vec::with_capacity(end - row);
                for r in row..end {
                    let u = ds.inverse[r] as usize;
                    if slot[u] == u32::MAX {
                        slot[u] = local_uniques.len() as u32;
                        local_uniques.push(u as u32);
                    }
                    local_inverse.push(slot[u]);
                }
                for &u in &local_uniques {
                    slot[u as usize] = u32::MAX;
                }
                let db = DedupTensorBatch {
                    inverse: local_inverse,
                    labels: ds.labels[row..end].to_vec(),
                    unique: TensorBatch::from_outputs_gather(
                        outputs,
                        &local_uniques,
                    ),
                };
                let seq = self.seq;
                self.seq += 1;
                let t_enc = Instant::now();
                let wb = self.packer.encode_dedup(&self.cipher, seq, &db)?;
                m.t_compress.add(t_enc.elapsed());
                m.tensor_tx_bytes.add(wb.bytes.len() as u64);
                m.wire_raw_bytes.add(wb.raw_len as u64);
                m.samples.add((end - row) as u64);
                m.batches.inc();
                wire.push(wb);
                row = end;
            }
        }
        m.t_load.add(t.elapsed());
        self.span(Stage::Load, t);
        Ok(wire)
    }
}

/// A threaded Worker: fetch-split loop + bounded tensor buffer + Master
/// heartbeats. Buffer capacity bounds memory (the paper: "a small buffer
/// of tensors in each Worker's memory").
pub struct Worker {
    pub id: WorkerId,
    handle: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    pub produced: Arc<AtomicU64>,
}

impl Worker {
    /// Spawn a worker thread streaming batches into `tx`.
    pub fn spawn(
        master: Arc<Master>,
        cluster: Arc<Cluster>,
        spec: Arc<SessionSpec>,
        metrics: Arc<EtlMetrics>,
        tx: SyncSender<WireBatch>,
    ) -> Worker {
        let id = master.register_worker();
        let stop = Arc::new(AtomicBool::new(false));
        let produced = Arc::new(AtomicU64::new(0));
        let stop2 = stop.clone();
        let produced2 = produced.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dpp-worker-{id}"))
            .spawn(move || {
                let mut core = WorkerCore::new(spec, cluster, metrics);
                if let Some(h) = master.broker_handle() {
                    // Shared-read session: fetch through the broker.
                    core = core.with_broker(h);
                }
                if let Some(h) = master.obs_handle() {
                    // Traced session: worker id is the trace lane.
                    core = core.with_obs(h, id as u32);
                }
                // Relaxed stop checks: `stop` is a one-way latch; a
                // delayed read costs at most one extra loop iteration
                // and no data rides on the flag.
                while !stop2.load(Ordering::Relaxed) {
                    let Some(split) = master.fetch_split(id) else {
                        if master.is_done() {
                            break;
                        }
                        if master.is_draining(id) {
                            // Retired by the autoscaler. Any lease this
                            // worker held was completed above (the
                            // split loop is synchronous), so exiting
                            // here drains cleanly: nothing requeues,
                            // no rows are lost.
                            master.worker_drained(id);
                            break;
                        }
                        // Idle workers are alive: heartbeat so the
                        // reaper never fences a worker that is merely
                        // waiting (a requeued split must always find a
                        // live leaseholder), and a reaped-but-running
                        // worker revives instead of spinning forever.
                        master.heartbeat(
                            id,
                            buffered_estimate(&produced2),
                            0.05,
                            0.3,
                            0.1,
                        );
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        continue;
                    };
                    match core.process_split(&split) {
                        Ok(batches) => {
                            let mut ok = true;
                            for b in batches {
                                // Bounded buffer: block until the client
                                // drains (backpressure).
                                let t_send = Instant::now();
                                let mut item = b;
                                // Relaxed `produced` bump and stop
                                // check: the counter is a monotone
                                // statistic; batch handoff itself
                                // synchronizes through the channel.
                                loop {
                                    match tx.try_send(item) {
                                        Ok(()) => {
                                            produced2
                                                .fetch_add(1, Ordering::Relaxed);
                                            break;
                                        }
                                        Err(TrySendError::Full(back)) => {
                                            if stop2.load(Ordering::Relaxed) {
                                                ok = false;
                                                break;
                                            }
                                            item = back;
                                            master.heartbeat(
                                                id,
                                                buffered_estimate(&produced2),
                                                0.2,
                                                0.3,
                                                0.2,
                                            );
                                            std::thread::sleep(
                                                std::time::Duration::from_micros(
                                                    200,
                                                ),
                                            );
                                        }
                                        Err(TrySendError::Disconnected(_)) => {
                                            ok = false;
                                            break;
                                        }
                                    }
                                }
                                if !ok {
                                    break;
                                }
                                // Send span covers backpressure waits —
                                // the wire/loading tax of Table 9.
                                core.span(Stage::WireSend, t_send);
                            }
                            if ok {
                                master.complete_split(id, split.id);
                                master.heartbeat(
                                    id,
                                    buffered_estimate(&produced2),
                                    0.9,
                                    0.4,
                                    0.4,
                                );
                            } else {
                                master.worker_failed(id);
                                return;
                            }
                        }
                        Err(_) => {
                            master.worker_failed(id);
                            return;
                        }
                    }
                }
            })
            .expect("spawn worker");
        Worker {
            id,
            handle: Some(handle),
            stop,
            produced,
        }
    }

    /// Simulate a crash: the thread stops without completing its split.
    //
    // Relaxed store: setting the one-way stop latch; the worker loop
    // tolerates reading it late (see the spawn loop's comment).
    pub fn kill(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Whether the worker thread has exited (completed, drained after
    /// retirement, or crashed) — joining a finished worker can't block
    /// the session control loop.
    pub fn is_finished(&self) -> bool {
        match &self.handle {
            Some(h) => h.is_finished(),
            None => true,
        }
    }

    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn buffered_estimate(produced: &AtomicU64) -> usize {
    // The worker cannot see the channel depth directly; report recent
    // production as a proxy (the Session refines this from the client
    // side). Relaxed: a heuristic read of a monotone counter.
    (produced.load(Ordering::Relaxed) % 8) as usize + 1
}

impl Drop for Worker {
    fn drop(&mut self) {
        // Relaxed: one-way stop latch (see the spawn loop's comment);
        // the join below is the real synchronization point.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Client-side receiver half of a worker's tensor stream.
pub type WireRx = Receiver<WireBatch>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dwrf::{Projection, WriterOptions};
    use crate::schema::FeatureKind;
    use crate::tectonic::ClusterConfig;
    use crate::transforms::{Op, TransformDag};
    use crate::warehouse::Catalog;

    fn setup(flatmap: bool) -> (Arc<Cluster>, Catalog, Arc<SessionSpec>) {
        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        }));
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            13,
        )
        .unwrap();
        // Simple DAG: normalize one dense + hash one sparse feature.
        let dense = h
            .schema
            .features
            .iter()
            .find(|f| matches!(f.kind, FeatureKind::Dense))
            .unwrap()
            .id;
        let sparse = h
            .schema
            .features
            .iter()
            .find(|f| !matches!(f.kind, FeatureKind::Dense))
            .unwrap()
            .id;
        let mut dag = TransformDag::default();
        let d = dag.input_dense(dense);
        let c = dag.apply(Op::Clamp { lo: -3.0, hi: 3.0 }, vec![d]);
        dag.output(dense, c);
        let s = dag.input_sparse(sparse);
        let hh = dag.apply(
            Op::SigridHash {
                salt: 1,
                modulus: 1000,
            },
            vec![s],
        );
        dag.output(sparse, hh);
        let mut spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 8);
        spec.pipeline.flatmap = flatmap;
        (cluster, catalog, Arc::new(spec))
    }

    #[test]
    fn core_processes_split_to_tensors() {
        let (cluster, catalog, spec) = setup(true);
        let master = Master::new(&catalog, &cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let metrics = Arc::new(EtlMetrics::default());
        let mut core = WorkerCore::new(spec.clone(), cluster, metrics.clone());
        let split = master.fetch_split(w).unwrap();
        let wire = core.process_split(&split).unwrap();
        // 2 stripes × 16 rows, batch 8 → 4 batches.
        assert_eq!(wire.len(), 4);
        assert!(wire.iter().all(|b| b.rows == 8));
        assert!(metrics.storage_rx_bytes.get() > 0);
        assert!(metrics.tensor_tx_bytes.get() > 0);
        assert_eq!(metrics.samples.get(), 32);
        // Default options compress the wire; batches decode on the
        // client side through the codec.
        assert!(wire.iter().all(|b| b.compressed));
        let cipher = StreamCipher::for_table(&core.spec.table);
        let tb = crate::dpp::codec::decode_wire(&cipher, &wire[0]).unwrap();
        assert_eq!(tb.rows, 8);
        assert_eq!(tb.dense_names.len(), 1);
        assert_eq!(tb.sparse.len(), 1);
        assert!(tb.sparse[0].2.iter().all(|&id| id < 1000), "hashed ids");
        assert!(tb.dense.iter().all(|&v| (-3.0..=3.0).contains(&v)));
    }

    #[test]
    fn flatmap_and_rowpath_produce_same_tensors() {
        let (cluster, catalog, spec_fm) = setup(true);
        let (_, _, _) = setup(false); // layout compatibility
        let mut spec_rows = (*spec_fm).clone();
        spec_rows.pipeline.flatmap = false;
        let master =
            Master::new(&catalog, &cluster, (*spec_fm).clone()).unwrap();
        let w = master.register_worker();
        let split = master.fetch_split(w).unwrap();

        let m1 = Arc::new(EtlMetrics::default());
        let m2 = Arc::new(EtlMetrics::default());
        let mut c1 = WorkerCore::new(spec_fm.clone(), cluster.clone(), m1);
        let mut c2 =
            WorkerCore::new(Arc::new(spec_rows), cluster.clone(), m2);
        let w1 = c1.process_split(&split).unwrap();
        let w2 = c2.process_split(&split).unwrap();
        let cipher = StreamCipher::for_table(&spec_fm.table);
        for (a, b) in w1.iter().zip(w2.iter()) {
            let ta = crate::dpp::codec::decode_wire(&cipher, a).unwrap();
            let tb = crate::dpp::codec::decode_wire(&cipher, b).unwrap();
            assert_eq!(ta, tb);
        }
    }

    #[test]
    fn broker_path_produces_identical_wire() {
        use crate::broker::ReadBroker;
        let (cluster, catalog, spec) = setup(true);
        // Private baseline.
        let master = Master::new(&catalog, &cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let m1 = Arc::new(EtlMetrics::default());
        let mut base_core =
            WorkerCore::new(spec.clone(), cluster.clone(), m1);
        let mut base = Vec::new();
        while let Some(split) = master.fetch_split(w) {
            base.extend(base_core.process_split(&split).unwrap());
            master.complete_split(w, split.id);
        }
        // Broker path over the same session spec.
        let broker = ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let sspec = (*spec).clone();
        let sm = Master::new_shared(&catalog, &cluster, sspec.clone(), &broker)
            .unwrap();
        let sw = sm.register_worker();
        let m2 = Arc::new(EtlMetrics::default());
        let mut core =
            WorkerCore::new(Arc::new(sspec), cluster.clone(), m2.clone());
        core = core.with_broker(sm.broker_handle().unwrap());
        let mut got = Vec::new();
        while let Some(split) = sm.fetch_split(sw) {
            got.extend(core.process_split(&split).unwrap());
            sm.complete_split(sw, split.id);
        }
        assert_eq!(base.len(), got.len());
        for (a, b) in base.iter().zip(got.iter()) {
            assert_eq!(a.seq, b.seq);
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.dedup, b.dedup);
            assert_eq!(a.raw_len, b.raw_len);
            assert_eq!(a.bytes, b.bytes, "wire must be byte-identical");
        }
        assert!(m2.storage_rx_bytes.get() > 0, "single session still reads");
    }

    #[test]
    fn master_rejects_invalid_wire_options() {
        use crate::dpp::spec::WireCompression;
        let (cluster, catalog, spec) = setup(true);
        let mut bad_cap = (*spec).clone();
        bad_cap.pipeline.max_frame_bytes = 1024; // below the floor
        assert!(Master::new(&catalog, &cluster, bad_cap).is_err());
        let mut bad_level = (*spec).clone();
        bad_level.pipeline.wire_compression = WireCompression::zstd(99);
        assert!(Master::new(&catalog, &cluster, bad_level).is_err());
    }

    #[test]
    fn retired_threaded_worker_exits_and_loses_no_rows() {
        let (cluster, catalog, spec) = setup(true);
        let master = Arc::new(
            Master::new(&catalog, &cluster, (*spec).clone()).unwrap(),
        );
        let metrics = Arc::new(EtlMetrics::default());
        let (tx, rx) = std::sync::mpsc::sync_channel(64);
        let worker = Worker::spawn(
            master.clone(),
            cluster.clone(),
            spec.clone(),
            metrics.clone(),
            tx,
        );
        // Retire right away: whatever lease it holds drains to
        // completion, then the thread exits — without the session being
        // done and without a requeue.
        master.retire_worker(worker.id);
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(10);
        while !worker.is_finished() && std::time::Instant::now() < deadline
        {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(worker.is_finished(), "retired worker must exit");
        worker.join();
        assert_eq!(master.live_workers(), 0);
        // A replacement finishes whatever remains; between the two
        // channels every row arrives exactly once.
        let (tx2, rx2) = std::sync::mpsc::sync_channel(64);
        let w2 = Worker::spawn(master.clone(), cluster, spec, metrics, tx2);
        let mut rows = 0usize;
        while let Ok(b) =
            rx.recv_timeout(std::time::Duration::from_millis(200))
        {
            rows += b.rows;
        }
        while let Ok(b) = rx2.recv_timeout(std::time::Duration::from_secs(10))
        {
            rows += b.rows;
        }
        w2.join();
        assert!(master.is_done());
        assert_eq!(
            rows as u64,
            master.total_rows(),
            "retirement drains leases: no rows lost, none duplicated"
        );
    }

    #[test]
    fn threaded_worker_drains_session() {
        let (cluster, catalog, spec) = setup(true);
        let master =
            Arc::new(Master::new(&catalog, &cluster, (*spec).clone()).unwrap());
        let metrics = Arc::new(EtlMetrics::default());
        let (tx, rx) = std::sync::mpsc::sync_channel(64);
        let worker = Worker::spawn(
            master.clone(),
            cluster,
            spec.clone(),
            metrics.clone(),
            tx,
        );
        let mut rows = 0usize;
        while let Ok(b) = rx.recv_timeout(std::time::Duration::from_secs(10)) {
            rows += b.rows;
        }
        worker.join();
        assert_eq!(rows as u64, master.total_rows());
        assert!(master.is_done());
    }
}
