//! Splits: "independent and self-contained work items for the data plane
//! ... that represent successive rows of the entire dataset" (§3.2.1).
//!
//! A split is a run of stripes within one partition file. The Master
//! enumerates partition footers once at session start (control-plane
//! I/O) and slices each file into splits.

use crate::tectonic::FileId;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SplitId(pub u64);

/// One self-contained work item.
#[derive(Clone, Debug, PartialEq)]
pub struct Split {
    pub id: SplitId,
    pub file: FileId,
    /// Partition day (for bookkeeping / popularity accounting).
    pub day: u32,
    /// First stripe index in the file.
    pub stripe_start: usize,
    /// Number of stripes.
    pub stripe_count: usize,
    /// Total rows covered (from the footer).
    pub rows: u64,
}

/// Slice a partition's stripe row-counts into splits.
pub fn splits_for_partition(
    next_id: &mut u64,
    file: FileId,
    day: u32,
    stripe_rows: &[u32],
    stripes_per_split: usize,
) -> Vec<Split> {
    assert!(stripes_per_split > 0);
    let mut out = Vec::new();
    let mut i = 0;
    while i < stripe_rows.len() {
        let count = stripes_per_split.min(stripe_rows.len() - i);
        let rows: u64 = stripe_rows[i..i + count].iter().map(|&r| r as u64).sum();
        out.push(Split {
            id: SplitId(*next_id),
            file,
            day,
            stripe_start: i,
            stripe_count: count,
            rows,
        });
        *next_id += 1;
        i += count;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_cover_all_stripes_exactly_once() {
        let mut id = 0;
        let rows = vec![100u32, 100, 100, 100, 50];
        let splits = splits_for_partition(&mut id, FileId(1), 0, &rows, 2);
        assert_eq!(splits.len(), 3);
        assert_eq!(splits[0].stripe_count, 2);
        assert_eq!(splits[2].stripe_count, 1);
        let total_rows: u64 = splits.iter().map(|s| s.rows).sum();
        assert_eq!(total_rows, 450);
        // Stripes tile the file.
        let mut covered = vec![false; rows.len()];
        for s in &splits {
            for k in s.stripe_start..s.stripe_start + s.stripe_count {
                assert!(!covered[k]);
                covered[k] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        assert_eq!(id, 3);
    }

    #[test]
    fn ids_are_unique_across_partitions() {
        let mut id = 0;
        let a = splits_for_partition(&mut id, FileId(1), 0, &[10, 10], 1);
        let b = splits_for_partition(&mut id, FileId(2), 1, &[10], 1);
        let mut ids: Vec<u64> =
            a.iter().chain(b.iter()).map(|s| s.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3);
    }

    #[test]
    fn empty_partition_yields_no_splits() {
        let mut id = 0;
        assert!(splits_for_partition(&mut id, FileId(1), 0, &[], 2).is_empty());
    }
}
