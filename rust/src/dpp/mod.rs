//! DPP — the **Data PreProcessing Service** (§3.2.1), the paper's system
//! contribution: a disaggregated online-preprocessing service that reads
//! raw training data from storage, preprocesses it into ready-to-load
//! tensors, and serves them to trainers, scaling out to eliminate data
//! stalls.
//!
//! Control plane: [`Master`] — session spec intake, split generation and
//! work distribution, fault tolerance (checkpointing + stateless-worker
//! restart), and the auto-scaling controller.
//!
//! Data plane: [`WorkerCore`]/[`Worker`] — the extract→transform→load
//! loop over real bytes (tectonic I/O → DWRF decode → transform DAGs →
//! tensor batches); [`Client`] — the trainer-side hook with partitioned
//! round-robin routing to a bounded set of workers. The bytes between
//! the two are produced by [`codec`]: per-feature-stream zstd framing
//! (`PipelineOptions::wire_compression`) encrypted and decoded without
//! intermediate copies.
//!
//! Cross-job sharing: a Master built with [`Master::new_shared`]
//! attaches the session to a [`crate::broker::ReadBroker`] so workers
//! fetch stripes through the shared decode-once path
//! (`PipelineOptions::shared_reads`) — at per-(file, stripe, column)
//! grain when `PipelineOptions::column_sharing` is on, so overlapping
//! projections serve from any wider cached decode — and the
//! [`TensorCache`] / [`TransformCache`] can charge the same
//! [`crate::broker::MemoryBudget`] as the broker's buffers. The
//! [`TransformCache`] extends reuse into the transform stage: outputs
//! keyed by (input-content, DAG-prefix) fingerprints are computed once
//! across every session sharing a DAG prefix.

pub mod cache;
pub mod client;
pub mod codec;
pub mod master;
pub mod service;
pub mod spec;
pub mod split;
pub mod tensor;
pub mod transport;
pub mod worker;

pub use cache::{
    batch_content_fingerprint, dag_node_fingerprints, dag_prefix_fingerprint,
    prefix_inputs, session_fingerprint, TensorCache, TransformCache,
};
pub use client::Client;
pub use codec::{
    decode_wire, decode_wire_dedup, train_wire_dict, WirePacker, WireUnpacker,
};
pub use master::{
    estimate_worker_seconds, rescale_worker_capacity, AutoscalePolicy,
    Master, MasterCheckpoint, ScaleDecision, ScaleSignals, WorkerHealth,
};
pub use service::{
    run_session, run_session_on, Session, SessionConfig, SessionReport,
};
pub use spec::{PipelineOptions, SessionSpec, WireCompression};
pub use split::{Split, SplitId};
pub use tensor::{DedupTensorBatch, TensorBatch};
pub use worker::{Worker, WorkerCore};
