//! DPP — the **Data PreProcessing Service** (§3.2.1), the paper's system
//! contribution: a disaggregated online-preprocessing service that reads
//! raw training data from storage, preprocesses it into ready-to-load
//! tensors, and serves them to trainers, scaling out to eliminate data
//! stalls.
//!
//! Control plane: [`Master`] — session spec intake, split generation and
//! work distribution, fault tolerance (checkpointing + stateless-worker
//! restart), and the auto-scaling controller.
//!
//! Data plane: [`WorkerCore`]/[`Worker`] — the extract→transform→load
//! loop over real bytes (tectonic I/O → DWRF decode → transform DAGs →
//! tensor batches); [`Client`] — the trainer-side hook with partitioned
//! round-robin routing to a bounded set of workers.
//!
//! Cross-job sharing: a Master built with [`Master::new_shared`]
//! attaches the session to a [`crate::broker::ReadBroker`] so workers
//! fetch stripes through the shared decode-once path
//! (`PipelineOptions::shared_reads`), and the [`TensorCache`] can charge
//! the same [`crate::broker::MemoryBudget`] as the broker's buffers.

pub mod cache;
pub mod client;
pub mod master;
pub mod service;
pub mod spec;
pub mod split;
pub mod tensor;
pub mod transport;
pub mod worker;

pub use cache::{session_fingerprint, TensorCache};
pub use client::Client;
pub use master::{
    estimate_worker_seconds, rescale_worker_capacity, AutoscalePolicy,
    Master, MasterCheckpoint, ScaleDecision, ScaleSignals, WorkerHealth,
};
pub use service::{
    run_session, run_session_on, Session, SessionConfig, SessionReport,
};
pub use spec::{PipelineOptions, SessionSpec};
pub use split::{Split, SplitId};
pub use tensor::{DedupTensorBatch, TensorBatch};
pub use worker::{Worker, WorkerCore};
