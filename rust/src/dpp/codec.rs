//! Wire codec: per-feature zstd framing for the worker→client tensor
//! stream (the "loading tax" lever of Table 9).
//!
//! With compression on, a [`WireBatch`] payload is no longer the plain
//! [`TensorBatch`] serialization; it is a small uncompressed header
//! (kind, row counts, feature table) followed by one framed *section*
//! per feature stream:
//!
//! ```text
//! [varint raw_len][varint enc_len][u8 method][enc_len bytes]
//! ```
//!
//! `method` is 0 = stored, 1 = zstd, 2 = zstd with the session
//! dictionary. Framing each dense column, sparse stream, and label
//! vector independently keeps the columnar layout's redundancy visible
//! to the compressor (RecD: duplication-heavy recommendation payloads
//! compress disproportionately well) and makes every stream
//! independently decodable and checkable. Sections that do not shrink —
//! or are smaller than [`MIN_COMPRESS_SECTION`] — are stored verbatim,
//! so compression can never inflate a frame by more than the framing
//! bytes.
//!
//! The whole payload (header + sections) is encrypted *after* assembly:
//! compression must see plaintext, because AES-CTR output does not
//! compress. The declared `raw_len` (header bytes + Σ section raw
//! lengths) travels in the frame header so the receive side can bound
//! every decompression allocation *before* it happens — a lying frame
//! is rejected from its lengths alone.
//!
//! [`WirePacker`] (worker side) and [`WireUnpacker`] (client side) own
//! the zstd contexts and scratch buffers, so the steady-state encode and
//! decode paths reuse allocations instead of rebuilding them per batch.

use super::spec::{PipelineOptions, WireCompression};
use super::tensor::{DedupTensorBatch, TensorBatch};
use super::transport::{max_raw_bytes, MAX_FRAME_BYTES};
use super::worker::WireBatch;
use crate::dwrf::crypto::StreamCipher;
use crate::schema::FeatureId;
use crate::util::bytes::{put_f32, put_u32, put_varint, ByteReader};
use anyhow::{anyhow, bail, Context, Result};
use zstd::bulk::{Compressor, Decompressor};

/// Payload kind byte: a plain [`TensorBatch`].
const KIND_PLAIN: u8 = 0;
/// Payload kind byte: a [`DedupTensorBatch`] (inverse-keyed uniques).
const KIND_DEDUP: u8 = 1;

/// Section stored verbatim (`enc_len == raw_len`).
const METHOD_STORED: u8 = 0;
/// Section is a zstd frame (no dictionary).
const METHOD_ZSTD: u8 = 1;
/// Section is a zstd frame using the session dictionary.
const METHOD_ZSTD_DICT: u8 = 2;

/// Sections below this size are always stored: zstd's frame overhead
/// (~13 bytes) plus the entropy of a handful of floats makes compressing
/// them a net loss in both bytes and cycles.
const MIN_COMPRESS_SECTION: usize = 64;

/// Train a per-session wire dictionary from sample payload sections
/// (serialized feature streams of representative batches). Falls back to
/// a raw-content dictionary — the concatenated sample bytes, which zstd
/// loads as a content prefix on both sides — when ZDICT declines to
/// train (it does on tiny or too-uniform sample sets), so sessions with
/// little warmup data still get a deterministic dictionary.
pub fn train_wire_dict(samples: &[Vec<u8>], max_bytes: usize) -> Result<Vec<u8>> {
    if let Ok(d) = zstd::dict::from_samples(samples, max_bytes) {
        if !d.is_empty() {
            return Ok(d);
        }
    }
    let mut d = Vec::new();
    for s in samples {
        if d.len() >= max_bytes {
            break;
        }
        let take = (max_bytes - d.len()).min(s.len());
        d.extend_from_slice(&s[..take]);
    }
    if d.is_empty() {
        bail!("no sample bytes to train a wire dictionary from");
    }
    Ok(d)
}

/// Worker-side encoder: serializes tensor batches straight into one
/// output buffer (no intermediate `serialize()` + `to_vec()` copies),
/// compressing each feature stream as its own framed section, then
/// encrypts the assembled payload in place.
pub struct WirePacker {
    /// `None` = compression off: emit the legacy byte-identical wire.
    cctx: Option<Compressor<'static>>,
    has_dict: bool,
    max_frame: usize,
    /// Scratch: the current section's raw bytes.
    sec: Vec<u8>,
    /// Scratch: the current section's compressed bytes.
    comp: Vec<u8>,
}

impl WirePacker {
    /// Build from the session's pipeline options. Errors on options
    /// [`PipelineOptions::validate`] would reject (bad level, broken
    /// dictionary) — real sessions validate at Master intake, so a
    /// failure here means the caller skipped that.
    pub fn new(opts: &PipelineOptions) -> Result<WirePacker> {
        let (cctx, has_dict) = match &opts.wire_compression {
            WireCompression::Off => (None, false),
            WireCompression::Zstd { level, dict } => {
                let c = match dict {
                    Some(d) => Compressor::with_dictionary(*level, d),
                    None => Compressor::new(*level),
                }
                .context("zstd compression context")?;
                (Some(c), dict.is_some())
            }
        };
        Ok(WirePacker {
            cctx,
            has_dict,
            max_frame: opts.max_frame_bytes,
            sec: Vec::new(),
            comp: Vec::new(),
        })
    }

    /// Encode + encrypt one plain tensor batch.
    pub fn encode_tensor(
        &mut self,
        cipher: &StreamCipher,
        seq: u64,
        tb: &TensorBatch,
    ) -> Result<WireBatch> {
        if self.cctx.is_none() {
            // Ablation path: byte-identical to the pre-compression wire.
            let bytes = tb.to_wire(cipher, seq);
            self.check_frame(bytes.len(), bytes.len())?;
            return Ok(WireBatch::plain(seq, tb.rows, false, bytes));
        }
        let mut out = Vec::with_capacity(tb.bytes() / 2 + 64);
        out.push(KIND_PLAIN);
        put_varint(&mut out, tb.rows as u64);
        Self::write_feature_table(&mut out, tb);
        let mut raw = out.len();
        raw += self.pack_tensor_sections(&mut out, tb)?;
        self.check_frame(out.len(), raw)?;
        cipher.apply(seq, &mut out);
        Ok(WireBatch {
            seq,
            rows: tb.rows,
            dedup: false,
            compressed: true,
            raw_len: raw,
            bytes: out,
        })
    }

    /// Encode + encrypt one dedup (inverse-keyed) batch.
    pub fn encode_dedup(
        &mut self,
        cipher: &StreamCipher,
        seq: u64,
        db: &DedupTensorBatch,
    ) -> Result<WireBatch> {
        if self.cctx.is_none() {
            let bytes = db.to_wire(cipher, seq);
            self.check_frame(bytes.len(), bytes.len())?;
            return Ok(WireBatch::plain(seq, db.rows(), true, bytes));
        }
        let rows = db.rows();
        let mut out = Vec::with_capacity(db.bytes() / 2 + 64);
        out.push(KIND_DEDUP);
        put_varint(&mut out, rows as u64);
        put_varint(&mut out, db.unique.rows as u64);
        Self::write_feature_table(&mut out, &db.unique);
        let mut raw = out.len();
        // Inverse index: the stream dedup makes disproportionately
        // compressible (repeated small varints).
        self.sec.clear();
        for &u in &db.inverse {
            put_varint(&mut self.sec, u as u64);
        }
        raw += self.pack_section(&mut out)?;
        // True per-row labels (row identity, never deduplicated).
        self.sec.clear();
        for &l in &db.labels {
            put_f32(&mut self.sec, l);
        }
        raw += self.pack_section(&mut out)?;
        raw += self.pack_tensor_sections(&mut out, &db.unique)?;
        self.check_frame(out.len(), raw)?;
        cipher.apply(seq, &mut out);
        Ok(WireBatch {
            seq,
            rows,
            dedup: true,
            compressed: true,
            raw_len: raw,
            bytes: out,
        })
    }

    fn write_feature_table(out: &mut Vec<u8>, tb: &TensorBatch) {
        put_varint(out, tb.dense_names.len() as u64);
        for f in &tb.dense_names {
            put_u32(out, f.0);
        }
        put_varint(out, tb.sparse.len() as u64);
        for (f, _, _) in &tb.sparse {
            put_u32(out, f.0);
        }
    }

    /// One section per dense column, per sparse stream, then labels.
    /// Returns the summed raw section bytes.
    fn pack_tensor_sections(
        &mut self,
        out: &mut Vec<u8>,
        tb: &TensorBatch,
    ) -> Result<usize> {
        let nd = tb.dense_names.len();
        let mut raw = 0usize;
        for j in 0..nd {
            // Gather the column out of the row-major matrix: columnar
            // sections keep one feature's distribution contiguous.
            self.sec.clear();
            for i in 0..tb.rows {
                put_f32(&mut self.sec, tb.dense[i * nd + j]);
            }
            raw += self.pack_section(out)?;
        }
        for (_, offsets, ids) in &tb.sparse {
            self.sec.clear();
            let mut prev = 0u32;
            for &o in &offsets[1..] {
                put_varint(&mut self.sec, (o - prev) as u64);
                prev = o;
            }
            put_varint(&mut self.sec, ids.len() as u64);
            for &id in ids {
                put_varint(&mut self.sec, id);
            }
            raw += self.pack_section(out)?;
        }
        self.sec.clear();
        for &l in &tb.labels {
            put_f32(&mut self.sec, l);
        }
        raw += self.pack_section(out)?;
        Ok(raw)
    }

    /// Frame `self.sec` into `out`, compressed when that actually
    /// shrinks it. Returns the section's raw length.
    fn pack_section(&mut self, out: &mut Vec<u8>) -> Result<usize> {
        let raw = self.sec.len();
        put_varint(out, raw as u64);
        let mut method = METHOD_STORED;
        let mut payload: &[u8] = &self.sec;
        if raw >= MIN_COMPRESS_SECTION {
            if let Some(c) = self.cctx.as_mut() {
                self.comp.clear();
                // Strictly above ZSTD_compressBound, so the bulk call
                // never fails for capacity.
                self.comp.reserve(raw + (raw >> 7) + 512);
                if let Ok(n) = c.compress_to_buffer(&self.sec, &mut self.comp)
                {
                    if n < raw {
                        method = if self.has_dict {
                            METHOD_ZSTD_DICT
                        } else {
                            METHOD_ZSTD
                        };
                        payload = &self.comp;
                    }
                }
            }
        }
        put_varint(out, payload.len() as u64);
        out.push(method);
        out.extend_from_slice(payload);
        Ok(raw)
    }

    /// Enforce the session frame cap on the post-compression payload and
    /// the declared raw size the receiver will be asked to allocate.
    fn check_frame(&self, enc_len: usize, raw_len: usize) -> Result<()> {
        if enc_len > self.max_frame {
            bail!(
                "encoded wire batch ({enc_len} bytes) exceeds the session \
                 frame cap ({} bytes) — shrink batch_size",
                self.max_frame
            );
        }
        if raw_len > max_raw_bytes(self.max_frame) {
            bail!(
                "wire batch raw size {raw_len} exceeds the decode bound {}",
                max_raw_bytes(self.max_frame)
            );
        }
        Ok(())
    }
}

/// Client-side decoder. Owns the zstd contexts and a reusable raw
/// scratch buffer; decrypts the frame's owned bytes in place (no
/// `to_vec()` copy) and bounds every allocation against the frame's
/// declared raw size before making it.
pub struct WireUnpacker {
    plain_dctx: Decompressor<'static>,
    dict_dctx: Option<Decompressor<'static>>,
    /// Largest declared raw payload this decoder will touch.
    max_raw: usize,
    /// Scratch: the current section's decompressed bytes.
    raw: Vec<u8>,
}

impl WireUnpacker {
    pub fn new(max_raw: usize) -> WireUnpacker {
        WireUnpacker {
            plain_dctx: Decompressor::new().expect("zstd dctx"),
            dict_dctx: None,
            max_raw,
            raw: Vec::new(),
        }
    }

    /// Attach the session dictionary (must be the same bytes the worker
    /// compresses with — it is part of the session fingerprint).
    pub fn with_dict(mut self, dict: &[u8]) -> WireUnpacker {
        self.dict_dctx =
            Some(Decompressor::with_dictionary(dict).expect("zstd dctx"));
        self
    }

    /// Decrypt + decode one frame into a trainer-ready batch, expanding
    /// dedup frames.
    pub fn decode(
        &mut self,
        cipher: &StreamCipher,
        wire: WireBatch,
    ) -> Result<TensorBatch> {
        if wire.dedup {
            Ok(self.decode_dedup(cipher, wire)?.expand())
        } else {
            self.decode_tensor(cipher, wire)
        }
    }

    /// Decrypt + decode a plain frame. Takes the frame by value: the
    /// payload decrypts in place in the buffer that crossed the wire.
    pub fn decode_tensor(
        &mut self,
        cipher: &StreamCipher,
        wire: WireBatch,
    ) -> Result<TensorBatch> {
        if wire.dedup {
            bail!("dedup frame passed to decode_tensor (use decode_dedup)");
        }
        let (hdr_rows, raw_len, compressed) =
            (wire.rows, wire.raw_len, wire.compressed);
        let buf = self.decrypt(cipher, wire)?;
        if !compressed {
            return TensorBatch::deserialize(&buf);
        }
        let mut r = ByteReader::new(&buf);
        let kind = r.bytes(1).context("wire kind")?[0];
        if kind != KIND_PLAIN {
            bail!("payload kind {kind} in a frame not flagged dedup");
        }
        let rows = r.varint().context("rows")? as usize;
        // The labels section alone is rows×4 raw bytes: a row count the
        // declared raw size cannot carry is a lie — reject it before any
        // rows-sized allocation below.
        if (rows as u64).saturating_mul(4) > raw_len as u64 {
            bail!(
                "row count {rows} inconsistent with declared raw size \
                 {raw_len}"
            );
        }
        let (dense_names, sparse_ids) = Self::read_feature_table(&mut r)?;
        let mut budget = raw_len.saturating_sub(r.pos());
        let tb = self.read_tensor_sections(
            &mut r,
            &mut budget,
            rows,
            dense_names,
            sparse_ids,
        )?;
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the last section", r.remaining());
        }
        if tb.rows != hdr_rows {
            bail!(
                "frame header claims {hdr_rows} rows, payload has {}",
                tb.rows
            );
        }
        Ok(tb)
    }

    /// Decrypt + decode a dedup frame (unexpanded).
    pub fn decode_dedup(
        &mut self,
        cipher: &StreamCipher,
        wire: WireBatch,
    ) -> Result<DedupTensorBatch> {
        if !wire.dedup {
            bail!("plain frame passed to decode_dedup (use decode_tensor)");
        }
        let (hdr_rows, raw_len, compressed) =
            (wire.rows, wire.raw_len, wire.compressed);
        let buf = self.decrypt(cipher, wire)?;
        if !compressed {
            return DedupTensorBatch::deserialize(&buf);
        }
        let mut r = ByteReader::new(&buf);
        let kind = r.bytes(1).context("wire kind")?[0];
        if kind != KIND_DEDUP {
            bail!("payload kind {kind} in a dedup-flagged frame");
        }
        let rows = r.varint().context("rows")? as usize;
        let urows = r.varint().context("unique rows")? as usize;
        // Per-row labels are rows×4 raw bytes and unique labels are
        // urows×4: bound both counts by the declared raw size before any
        // allocation sized by them.
        if (rows as u64).saturating_mul(4) > raw_len as u64
            || (urows as u64).saturating_mul(4) > raw_len as u64
        {
            bail!(
                "row counts {rows}/{urows} inconsistent with declared raw \
                 size {raw_len}"
            );
        }
        let (dense_names, sparse_ids) = Self::read_feature_table(&mut r)?;
        let mut budget = raw_len.saturating_sub(r.pos());
        // Inverse index.
        let sec = self.read_section(&mut r, &mut budget)?;
        let mut sr = ByteReader::new(sec);
        let mut inverse = Vec::with_capacity(rows);
        for _ in 0..rows {
            let u = sr.varint().context("inverse")?;
            if u >= urows as u64 {
                bail!("dedup inverse {u} out of range ({urows} uniques)");
            }
            inverse.push(u as u32);
        }
        if sr.remaining() != 0 {
            bail!("trailing bytes in inverse section");
        }
        // True per-row labels.
        let sec = self.read_section(&mut r, &mut budget)?;
        let labels = read_f32_section(sec, rows, "labels")?;
        let unique = self.read_tensor_sections(
            &mut r,
            &mut budget,
            urows,
            dense_names,
            sparse_ids,
        )?;
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the last section", r.remaining());
        }
        let db = DedupTensorBatch {
            inverse,
            labels,
            unique,
        };
        if db.rows() != hdr_rows {
            bail!(
                "frame header claims {hdr_rows} rows, payload has {}",
                db.rows()
            );
        }
        Ok(db)
    }

    /// Consume the frame and decrypt its payload in place — no copy; the
    /// buffer that crossed the wire is the one decoded. The raw-size
    /// bound check precedes *everything*: a frame with a lying raw size
    /// is rejected before any work.
    fn decrypt(&self, cipher: &StreamCipher, wire: WireBatch) -> Result<Vec<u8>> {
        if wire.raw_len > self.max_raw {
            bail!(
                "frame declares {} raw bytes, decode bound is {} — \
                 rejecting before allocation",
                wire.raw_len,
                self.max_raw
            );
        }
        if !wire.compressed && wire.raw_len != wire.bytes.len() {
            bail!(
                "uncompressed frame declares raw {} but carries {} bytes",
                wire.raw_len,
                wire.bytes.len()
            );
        }
        let mut buf = wire.bytes;
        cipher.apply(wire.seq, &mut buf);
        Ok(buf)
    }

    fn read_feature_table(
        r: &mut ByteReader,
    ) -> Result<(Vec<FeatureId>, Vec<FeatureId>)> {
        let nd = r.varint().context("nd")? as usize;
        if nd > r.remaining() / 4 {
            bail!("dense feature table truncated ({nd} declared)");
        }
        let mut dense_names = Vec::with_capacity(nd);
        for _ in 0..nd {
            dense_names.push(FeatureId(r.u32().context("dense id")?));
        }
        let ns = r.varint().context("ns")? as usize;
        if ns > r.remaining() / 4 {
            bail!("sparse feature table truncated ({ns} declared)");
        }
        let mut sparse_ids = Vec::with_capacity(ns);
        for _ in 0..ns {
            sparse_ids.push(FeatureId(r.u32().context("sparse id")?));
        }
        Ok((dense_names, sparse_ids))
    }

    /// Decode the per-feature sections of one tensor batch (shared by
    /// the plain path and the dedup path's embedded unique batch).
    fn read_tensor_sections<'b>(
        &mut self,
        r: &mut ByteReader<'b>,
        budget: &mut usize,
        rows: usize,
        dense_names: Vec<FeatureId>,
        sparse_ids: Vec<FeatureId>,
    ) -> Result<TensorBatch> {
        let nd = dense_names.len();
        if (rows as u64)
            .saturating_mul(nd as u64)
            .saturating_mul(4)
            > *budget as u64
        {
            bail!(
                "dense plane {rows}x{nd} exceeds the remaining raw budget \
                 {budget} — rejecting before allocation"
            );
        }
        let mut dense = vec![0f32; rows * nd];
        for j in 0..nd {
            let sec = self.read_section(r, budget)?;
            if sec.len() != rows * 4 {
                bail!(
                    "dense column {j}: {} bytes for {rows} rows",
                    sec.len()
                );
            }
            for (i, c) in sec.chunks_exact(4).enumerate() {
                dense[i * nd + j] =
                    f32::from_le_bytes(c.try_into().unwrap());
            }
        }
        let mut sparse = Vec::with_capacity(sparse_ids.len());
        for f in sparse_ids {
            let sec = self.read_section(r, budget)?;
            let mut sr = ByteReader::new(sec);
            let mut offsets = Vec::with_capacity(rows + 1);
            offsets.push(0u32);
            let mut acc = 0u32;
            for _ in 0..rows {
                acc += sr.varint().context("sparse offset")? as u32;
                offsets.push(acc);
            }
            let n = sr.varint().context("sparse id count")? as usize;
            if n != acc as usize {
                bail!("sparse length mismatch: {n} vs {acc}");
            }
            if n > sr.remaining() {
                // Every id is at least one varint byte.
                bail!("sparse ids truncated ({n} declared)");
            }
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(sr.varint().context("sparse id")?);
            }
            if sr.remaining() != 0 {
                bail!("trailing bytes in sparse stream");
            }
            sparse.push((f, offsets, ids));
        }
        let sec = self.read_section(r, budget)?;
        let labels = read_f32_section(sec, rows, "labels")?;
        Ok(TensorBatch {
            rows,
            dense,
            dense_names,
            sparse,
            labels,
        })
    }

    /// Read one framed section, returning its raw bytes — zero-copy from
    /// the payload for stored sections, from the reusable scratch buffer
    /// for compressed ones. The declared raw length is charged against
    /// the frame's remaining raw budget *before* any allocation.
    fn read_section<'s, 'b: 's>(
        &'s mut self,
        r: &mut ByteReader<'b>,
        budget: &mut usize,
    ) -> Result<&'s [u8]> {
        let raw_len = r.varint().context("section raw len")? as usize;
        let enc_len = r.varint().context("section enc len")? as usize;
        let method = r.bytes(1).context("section method")?[0];
        if raw_len > *budget {
            bail!(
                "section claims {raw_len} raw bytes with only {budget} left \
                 in the frame's declared budget — rejecting before \
                 allocation"
            );
        }
        *budget -= raw_len;
        let enc = r.bytes(enc_len).with_context(|| {
            format!("section truncated ({enc_len} bytes declared)")
        })?;
        match method {
            METHOD_STORED => {
                if enc_len != raw_len {
                    bail!(
                        "stored section: {enc_len} encoded vs {raw_len} raw"
                    );
                }
                Ok(enc)
            }
            METHOD_ZSTD | METHOD_ZSTD_DICT => {
                let d = if method == METHOD_ZSTD_DICT {
                    self.dict_dctx.as_mut().ok_or_else(|| {
                        anyhow!(
                            "frame uses a session dictionary this decoder \
                             does not have"
                        )
                    })?
                } else {
                    &mut self.plain_dctx
                };
                self.raw.clear();
                self.raw.reserve(raw_len);
                let n = d
                    .decompress_to_buffer(enc, &mut self.raw)
                    .context("zstd decompress")?;
                if n != raw_len {
                    bail!("section decompressed to {n}, declared {raw_len}");
                }
                Ok(&self.raw)
            }
            m => bail!("unknown section method {m}"),
        }
    }
}

fn read_f32_section(sec: &[u8], rows: usize, what: &str) -> Result<Vec<f32>> {
    if sec.len() != rows * 4 {
        bail!("{what} section: {} bytes for {rows} rows", sec.len());
    }
    Ok(sec
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// One-shot decode with a transient decoder at the transport-wide bound
/// (tests/benches; hot paths hold a [`WireUnpacker`]). Expands dedup
/// frames.
pub fn decode_wire(cipher: &StreamCipher, wire: &WireBatch) -> Result<TensorBatch> {
    WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES))
        .decode(cipher, wire.clone())
}

/// One-shot decode of a dedup frame, unexpanded.
pub fn decode_wire_dedup(
    cipher: &StreamCipher,
    wire: &WireBatch,
) -> Result<DedupTensorBatch> {
    WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES))
        .decode_dedup(cipher, wire.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Value;
    use std::sync::Arc;

    fn opts(wc: WireCompression) -> PipelineOptions {
        PipelineOptions {
            wire_compression: wc,
            ..PipelineOptions::default()
        }
    }

    fn batch(rows: usize) -> TensorBatch {
        let dense_a: Vec<f32> = (0..rows).map(|i| (i % 7) as f32).collect();
        let dense_b: Vec<f32> = (0..rows).map(|i| -(i as f32) * 0.5).collect();
        let mut offsets = vec![0u32];
        let mut ids = Vec::new();
        for i in 0..rows {
            for k in 0..(i % 3) {
                ids.push((i * 10 + k) as u64 % 97);
            }
            offsets.push(ids.len() as u32);
        }
        let outputs = vec![
            (FeatureId(1), Value::Dense(dense_a)),
            (FeatureId(2), Value::Dense(dense_b)),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets,
                    ids,
                    scores: None,
                },
            ),
        ];
        let labels: Vec<f32> = (0..rows).map(|i| (i % 2) as f32).collect();
        TensorBatch::from_outputs(&outputs, &labels, 0, rows)
    }

    fn dedup_batch(rows: usize, uniques: usize) -> DedupTensorBatch {
        let u = batch(uniques);
        DedupTensorBatch {
            inverse: (0..rows).map(|i| (i % uniques) as u32).collect(),
            labels: (0..rows).map(|i| (i % 2) as f32).collect(),
            unique: TensorBatch {
                // Placeholder labels, like from_outputs_gather produces.
                labels: vec![0.0; uniques],
                ..u
            },
        }
    }

    #[test]
    fn compressed_plain_roundtrip() {
        let tb = batch(64);
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(WireCompression::zstd(3))).unwrap();
        let wb = p.encode_tensor(&cipher, 7, &tb).unwrap();
        assert!(wb.compressed);
        assert!(!wb.dedup);
        assert_eq!(wb.rows, 64);
        assert!(wb.raw_len > 0);
        let back = decode_wire(&cipher, &wb).unwrap();
        assert_eq!(back, tb);
        // A held unpacker (the client's steady state) agrees.
        let mut u = WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES));
        let back2 = u.decode_tensor(&cipher, wb).unwrap();
        assert_eq!(back2, tb);
    }

    #[test]
    fn compressed_dedup_roundtrip() {
        let db = dedup_batch(96, 8);
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(WireCompression::zstd(3))).unwrap();
        let wb = p.encode_dedup(&cipher, 3, &db).unwrap();
        assert!(wb.compressed);
        assert!(wb.dedup);
        assert_eq!(wb.rows, 96);
        let back = decode_wire_dedup(&cipher, &wb).unwrap();
        assert_eq!(back, db);
        assert_eq!(decode_wire(&cipher, &wb).unwrap(), db.expand());
    }

    #[test]
    fn duplicated_content_compresses() {
        // RecD's observation: dup-heavy payloads shrink a lot. 96 rows
        // over 8 uniques: the raw wire repeats nothing (dedup already
        // collapsed it), but columns and the inverse stream still
        // compress well below raw.
        let db = dedup_batch(96, 8);
        let cipher = StreamCipher::for_table("codec");
        let raw_wire = db.to_wire(&cipher, 0).len();
        let mut p = WirePacker::new(&opts(WireCompression::zstd(3))).unwrap();
        let wb = p.encode_dedup(&cipher, 0, &db).unwrap();
        assert!(
            wb.bytes.len() < raw_wire,
            "{} vs raw {raw_wire}",
            wb.bytes.len()
        );
        // And an *expanded* (duplication-oblivious) batch with repeated
        // rows must compress even more dramatically.
        let tb = db.expand();
        let raw_wire = tb.to_wire(&cipher, 1).len();
        let wb = p.encode_tensor(&cipher, 1, &tb).unwrap();
        assert!(
            wb.bytes.len() * 2 < raw_wire,
            "{} vs raw {raw_wire}",
            wb.bytes.len()
        );
    }

    #[test]
    fn off_mode_is_byte_identical_to_legacy_wire() {
        let tb = batch(32);
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(WireCompression::Off)).unwrap();
        let wb = p.encode_tensor(&cipher, 5, &tb).unwrap();
        assert!(!wb.compressed);
        assert_eq!(wb.raw_len, wb.bytes.len());
        assert_eq!(wb.bytes, tb.to_wire(&cipher, 5), "ablation parity");
        assert_eq!(decode_wire(&cipher, &wb).unwrap(), tb);
        let db = dedup_batch(16, 4);
        let wb = p.encode_dedup(&cipher, 6, &db).unwrap();
        assert!(!wb.compressed);
        assert_eq!(wb.bytes, db.to_wire(&cipher, 6));
        assert_eq!(decode_wire_dedup(&cipher, &wb).unwrap(), db);
    }

    #[test]
    fn truncated_and_corrupt_frames_error_cleanly() {
        let tb = batch(64);
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(WireCompression::zstd(3))).unwrap();
        let wb = p.encode_tensor(&cipher, 2, &tb).unwrap();
        for cut in [0, 1, wb.bytes.len() / 2, wb.bytes.len() - 1] {
            let mut t = wb.clone();
            t.bytes.truncate(cut);
            assert!(
                decode_wire(&cipher, &t).is_err(),
                "truncation at {cut} must error, not panic"
            );
        }
        // Flip bytes all over the frame: every outcome must be a clean
        // error or a decode (a flipped f32 still parses) — never a
        // panic or an unbounded allocation.
        for at in (0..wb.bytes.len()).step_by(7) {
            let mut c = wb.clone();
            c.bytes[at] ^= 0xA5;
            let _ = decode_wire(&cipher, &c);
        }
    }

    #[test]
    fn lying_raw_length_rejected_before_allocation() {
        let cipher = StreamCipher::for_table("codec");
        // Header-level lie: declared raw size above the decode bound.
        let tb = batch(8);
        let mut p = WirePacker::new(&opts(WireCompression::zstd(3))).unwrap();
        let mut wb = p.encode_tensor(&cipher, 0, &tb).unwrap();
        wb.raw_len = max_raw_bytes(MAX_FRAME_BYTES) + 1;
        let err = decode_wire(&cipher, &wb).unwrap_err();
        assert!(err.to_string().contains("before allocation"), "{err}");
        // Section-level lie: a hand-built frame whose section claims a
        // terabyte of raw bytes against a tiny declared budget.
        let mut payload = vec![KIND_PLAIN];
        put_varint(&mut payload, 1); // rows
        put_varint(&mut payload, 0); // nd
        put_varint(&mut payload, 0); // ns
        put_varint(&mut payload, 1 << 40); // lying section raw_len
        put_varint(&mut payload, 4); // enc_len
        payload.push(METHOD_ZSTD);
        payload.extend_from_slice(&[0u8; 4]);
        let mut bytes = payload;
        cipher.apply(9, &mut bytes);
        let wire = WireBatch {
            seq: 9,
            rows: 1,
            dedup: false,
            compressed: true,
            raw_len: 64,
            bytes,
        };
        let err = decode_wire(&cipher, &wire).unwrap_err();
        assert!(err.to_string().contains("before allocation"), "{err}");
    }

    #[test]
    fn session_dictionary_roundtrip_and_mismatch() {
        // Train on representative payload sections, then pack with the
        // dictionary: both sides must hold the same bytes.
        let samples: Vec<Vec<u8>> =
            (0..8).map(|i| batch(32 + i).serialize()).collect();
        let dict = train_wire_dict(&samples, 4 << 10).unwrap();
        assert!(!dict.is_empty());
        let wc = WireCompression::Zstd {
            level: 3,
            dict: Some(Arc::new(dict.clone())),
        };
        let tb = TensorBatch {
            rows: 64,
            dense: vec![1.5; 64],
            dense_names: vec![FeatureId(1)],
            sparse: vec![],
            labels: vec![1.0; 64],
        };
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(wc)).unwrap();
        let wb = p.encode_tensor(&cipher, 11, &tb).unwrap();
        let mut u = WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES))
            .with_dict(&dict);
        assert_eq!(u.decode_tensor(&cipher, wb.clone()).unwrap(), tb);
        // A decoder without the session dictionary must error cleanly
        // (these sections are all-constant, so they provably compressed
        // and carry the dict method byte).
        let err = decode_wire(&cipher, &wb).unwrap_err();
        assert!(err.to_string().contains("dictionary"), "{err}");
    }

    #[test]
    fn dict_training_falls_back_on_tiny_samples() {
        // ZDICT declines sets this small; the raw-content fallback must
        // still produce a usable dictionary.
        let samples = vec![vec![1u8, 2, 3], vec![4u8, 5]];
        let d = train_wire_dict(&samples, 64).unwrap();
        assert!(!d.is_empty());
        assert!(train_wire_dict(&[], 64).is_err());
    }

    #[test]
    fn frame_cap_enforced_at_encode() {
        let mut o = opts(WireCompression::Off);
        o.max_frame_bytes = super::super::spec::MIN_FRAME_BYTES;
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&o).unwrap();
        // ~80 KiB of labels alone exceeds the 64 KiB cap.
        let tb = TensorBatch {
            rows: 20_000,
            dense: vec![],
            dense_names: vec![],
            sparse: vec![],
            labels: (0..20_000).map(|i| i as f32).collect(),
        };
        let err = p.encode_tensor(&cipher, 0, &tb).unwrap_err();
        assert!(err.to_string().contains("frame cap"), "{err}");
    }

    #[test]
    fn wrong_kind_routing_is_an_error() {
        let cipher = StreamCipher::for_table("codec");
        let mut p = WirePacker::new(&opts(WireCompression::zstd(1))).unwrap();
        let plain = p.encode_tensor(&cipher, 0, &batch(16)).unwrap();
        let dedup = p.encode_dedup(&cipher, 1, &dedup_batch(16, 4)).unwrap();
        let mut u = WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES));
        assert!(u.decode_dedup(&cipher, plain).is_err());
        assert!(u.decode_tensor(&cipher, dedup).is_err());
    }
}
