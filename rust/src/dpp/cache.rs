//! Preprocessed-tensor cache (§7.5: "We are also exploring other
//! optimization techniques, such as caching preprocessed tensors").
//!
//! Keyed by (split extent, session fingerprint): two jobs (or epochs)
//! with the same projection + transform pipeline + batching reuse each
//! other's fully-preprocessed wire batches, skipping storage reads,
//! extraction, and transformation entirely — the OneAccess-style sharing
//! the paper cites as related work, applied at the worker.
//!
//! The fingerprint covers the *entire* session semantics — including the
//! full transform DAG structure and every op's parameters — so two specs
//! that merely share node/output counts can never collide into the same
//! cache entry. Entries are evicted least-recently-used under budget
//! pressure.

use super::spec::{SessionSpec, WireCompression};
use super::split::Split;
use super::worker::WireBatch;
use crate::broker::MemoryBudget;
use crate::data::ColumnarBatch;
use crate::dedup::Fnv64;
use crate::filter::RowPredicate;
use crate::metrics::Counter;
use crate::schema::FeatureId;
use crate::sync::{lock_or_recover, Mutex};
use crate::transforms::dag::InputKind;
use crate::transforms::{Node, Op, TransformDag, Value};
use std::collections::HashMap;
use std::sync::Arc;

/// [`crate::dpp::PipelineOptions`] fields deliberately *not* hashed by
/// [`session_fingerprint`]. `dsi-lint` (tools/dsi-lint) fails the build
/// if a `PipelineOptions` field is neither hashed below nor listed here,
/// and requires a justification comment directly above each entry —
/// adding a knob without deciding its cache identity is a CI error, not
/// a latent cache-collision bug.
pub const FINGERPRINT_EXEMPT: &[&str] = &[
    // Span emission is diagnostic-only and never changes the
    // preprocessed output, so a traced session may share cached
    // tensors with an untraced twin.
    "tracing",
    // A transport cap, not an encoding choice: identical sessions with
    // different frame caps produce byte-identical wire batches.
    "max_frame_bytes",
];

/// Fingerprint of everything that affects a split's preprocessed output.
pub fn session_fingerprint(spec: &SessionSpec) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(&spec.table);
    h.write_u32(spec.from_day);
    h.write_u32(spec.to_day);
    // Projection is a set: hash order-independently.
    let mut feats: Vec<u32> = spec.projection.iter().map(|f| f.0).collect();
    feats.sort_unstable();
    h.write_u64(feats.len() as u64);
    for f in feats {
        h.write_u32(f);
    }
    h.write_u64(spec.batch_size as u64);
    h.write_u64(spec.stripes_per_split as u64);
    h.write_u8(spec.pipeline.fast_decode as u8);
    h.write_u8(spec.pipeline.flatmap as u8);
    h.write_u8(spec.pipeline.dedup_aware as u8);
    h.write_u8(spec.pipeline.pushdown as u8);
    h.write_u8(spec.pipeline.row_group_pruning as u8);
    h.write_u8(spec.pipeline.shared_reads as u8);
    h.write_u8(spec.pipeline.column_sharing as u8);
    h.write_u8(spec.pipeline.coalesce.is_some() as u8);
    h.write_u64(spec.pipeline.coalesce.unwrap_or(0));
    // Wire compression changes the cached bytes themselves (cache entries
    // hold *encoded* wire batches): level, codec on/off, and the exact
    // dictionary contents are all part of the entry's identity, so an
    // Off session can never decode a Zstd twin's entries (or vice versa).
    match &spec.pipeline.wire_compression {
        WireCompression::Off => h.write_u8(0),
        WireCompression::Zstd { level, dict } => {
            h.write_u8(1);
            h.write_u64(*level as u64);
            match dict {
                None => h.write_u8(0),
                Some(d) => {
                    h.write_u8(1);
                    h.write_u64(d.len() as u64);
                    h.write(d);
                }
            }
        }
    }
    // `pipeline.max_frame_bytes` is deliberately NOT hashed: it is a
    // transport cap, not an encoding choice — identical sessions with
    // different caps produce byte-identical wire batches.
    // `pipeline.tracing` is deliberately NOT hashed: span emission is
    // diagnostic-only and never changes the preprocessed output, so a
    // traced session may share cached tensors with an untraced twin.
    // Row predicate: filtered and unfiltered sessions (or two different
    // filters) must never share cached tensors.
    match &spec.predicate {
        None => h.write_u8(0),
        Some(p) => {
            h.write_u8(1);
            eat_pred(&mut h, p);
        }
    }
    // Full DAG structure: node kinds, op parameters, wiring, outputs.
    h.write_u64(spec.dag.nodes.len() as u64);
    for node in &spec.dag.nodes {
        match node {
            Node::Input { id, kind } => {
                h.write_u8(0);
                h.write_u32(id.0);
                h.write_u8(match kind {
                    InputKind::Auto => 0,
                    InputKind::Dense => 1,
                    InputKind::Sparse => 2,
                });
            }
            Node::Apply { op, inputs } => {
                h.write_u8(1);
                eat_op(&mut h, op);
                h.write_u64(inputs.len() as u64);
                for &i in inputs {
                    h.write_u64(i as u64);
                }
            }
        }
    }
    h.write_u64(spec.dag.outputs.len() as u64);
    for (fid, node) in &spec.dag.outputs {
        h.write_u32(fid.0);
        h.write_u64(*node as u64);
    }
    h.finish()
}

/// Hash one predicate with all its parameters (exhaustive on purpose,
/// like [`eat_op`]).
fn eat_pred(h: &mut Fnv64, p: &RowPredicate) {
    match p {
        RowPredicate::TimestampRange { min, max } => {
            h.write_u8(0);
            h.write_u64(*min);
            h.write_u64(*max);
        }
        RowPredicate::NegativeDownsample { rate, seed } => {
            h.write_u8(1);
            h.write_u64(rate.to_bits());
            h.write_u64(*seed);
        }
        RowPredicate::FeaturePresent { feature } => {
            h.write_u8(2);
            h.write_u32(feature.0);
        }
        RowPredicate::SampleRate { rate, seed } => {
            h.write_u8(3);
            h.write_u64(rate.to_bits());
            h.write_u64(*seed);
        }
        RowPredicate::And(ps) => {
            h.write_u8(4);
            h.write_u64(ps.len() as u64);
            for q in ps {
                eat_pred(h, q);
            }
        }
    }
}

/// Hash one op with all its parameters (exhaustive on purpose: adding an
/// op without deciding its cache identity is a compile error).
fn eat_op(h: &mut Fnv64, op: &Op) {
    match op {
        Op::Cartesian => h.write_u8(0),
        Op::Bucketize { borders } => {
            h.write_u8(1);
            h.write_u64(borders.len() as u64);
            for &b in borders {
                h.write_f32(b);
            }
        }
        Op::ComputeScore { mul, add } => {
            h.write_u8(2);
            h.write_f32(*mul);
            h.write_f32(*add);
        }
        Op::Enumerate => h.write_u8(3),
        Op::PositiveModulus { modulus } => {
            h.write_u8(4);
            h.write_u64(*modulus);
        }
        Op::IdListTransform => h.write_u8(5),
        Op::BoxCox { lambda } => {
            h.write_u8(6);
            h.write_f32(*lambda);
        }
        Op::Logit { eps } => {
            h.write_u8(7);
            h.write_f32(*eps);
        }
        Op::MapId { mapping, default } => {
            h.write_u8(8);
            let mut entries: Vec<(u64, u64)> =
                mapping.iter().map(|(&k, &v)| (k, v)).collect();
            entries.sort_unstable();
            h.write_u64(entries.len() as u64);
            for (k, v) in entries {
                h.write_u64(k);
                h.write_u64(v);
            }
            h.write_u64(*default);
        }
        Op::FirstX { x } => {
            h.write_u8(9);
            h.write_u64(*x as u64);
        }
        Op::GetLocalHour { tz_offset_secs } => {
            h.write_u8(10);
            h.write_u64(*tz_offset_secs as u64);
        }
        Op::SigridHash { salt, modulus } => {
            h.write_u8(11);
            h.write_u64(*salt);
            h.write_u64(*modulus);
        }
        Op::NGram { n } => {
            h.write_u8(12);
            h.write_u64(*n as u64);
        }
        Op::Onehot { buckets } => {
            h.write_u8(13);
            h.write_u32(*buckets);
        }
        Op::Clamp { lo, hi } => {
            h.write_u8(14);
            h.write_f32(*lo);
            h.write_f32(*hi);
        }
        Op::Sampling { rate, seed } => {
            h.write_u8(15);
            h.write_f32(*rate);
            h.write_u64(*seed);
        }
    }
}

type Key = (u64, u64, usize, usize); // (fingerprint, file, stripe_start, count)

struct Entry {
    batches: Arc<Vec<WireBatch>>,
    bytes: u64,
    last_used: u64,
}

struct Inner {
    map: HashMap<Key, Entry>,
    used: u64,
    tick: u64,
}

/// Bounded shared cache of preprocessed wire batches with LRU eviction.
/// The byte budget may be private ([`TensorCache::new`]) or a
/// [`MemoryBudget`] shared with other consumers — notably the read
/// broker's stripe buffers ([`TensorCache::with_budget`]) — so tensors
/// and shared stripes coexist under one bound.
pub struct TensorCache {
    inner: Mutex<Inner>,
    budget: Arc<MemoryBudget>,
    pub hits: Counter,
    pub misses: Counter,
    pub inserted_bytes: Counter,
    pub evictions: Counter,
    pub evicted_bytes: Counter,
}

impl TensorCache {
    /// A cache with its own private budget of `budget_bytes`.
    pub fn new(budget_bytes: u64) -> Arc<TensorCache> {
        Self::with_budget(MemoryBudget::new(budget_bytes))
    }

    /// A cache charging a (possibly shared) [`MemoryBudget`]. Under
    /// pressure it evicts its *own* entries; bytes held by the other
    /// consumers of the pool can squeeze inserts out entirely (`put`
    /// returns false), never the other way around.
    pub fn with_budget(budget: Arc<MemoryBudget>) -> Arc<TensorCache> {
        Arc::new(TensorCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                used: 0,
                tick: 0,
            }),
            budget,
            hits: Counter::new(),
            misses: Counter::new(),
            inserted_bytes: Counter::new(),
            evictions: Counter::new(),
            evicted_bytes: Counter::new(),
        })
    }

    /// Total bytes of the budget pool this cache charges.
    pub fn budget_total(&self) -> u64 {
        self.budget.total()
    }

    fn key(fingerprint: u64, split: &Split) -> Key {
        (
            fingerprint,
            split.file.0,
            split.stripe_start,
            split.stripe_count,
        )
    }

    pub fn get(&self, fingerprint: u64, split: &Split) -> Option<Arc<Vec<WireBatch>>> {
        let mut inner = lock_or_recover(&self.inner, "tensor cache");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&Self::key(fingerprint, split)) {
            Some(e) => {
                e.last_used = tick;
                self.hits.inc();
                Some(e.batches.clone())
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Insert, evicting least-recently-used entries to fit the budget.
    /// Returns whether it was stored (an item larger than the whole
    /// budget never is).
    pub fn put(
        &self,
        fingerprint: u64,
        split: &Split,
        batches: Arc<Vec<WireBatch>>,
    ) -> bool {
        let bytes: u64 = batches.iter().map(|b| b.bytes.len() as u64).sum();
        if bytes > self.budget.total() {
            return false;
        }
        let key = Self::key(fingerprint, split);
        let mut inner = lock_or_recover(&self.inner, "tensor cache");
        if let Some(old) = inner.map.remove(&key) {
            inner.used -= old.bytes;
            self.budget.release(old.bytes);
        }
        while !self.budget.try_reserve(bytes) {
            // Shed our own LRU entries until the pool fits us; if the
            // shortfall is bytes held elsewhere (shared stripes), give
            // up once we have nothing left to evict.
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { return false };
            let e = inner.map.remove(&victim).expect("victim present");
            inner.used -= e.bytes;
            self.budget.release(e.bytes);
            self.evictions.inc();
            self.evicted_bytes.add(e.bytes);
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(
            key,
            Entry {
                batches,
                bytes,
                last_used: tick,
            },
        );
        inner.used += bytes;
        self.inserted_bytes.add(bytes);
        true
    }

    pub fn used_bytes(&self) -> u64 {
        lock_or_recover(&self.inner, "tensor cache").used
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.inner, "tensor cache").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get() as f64;
        let m = self.misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Canonical per-node fingerprints of a DAG, indexed by node. Unlike the
/// raw node encoding inside [`session_fingerprint`], these are
/// node-index-*independent*: a node's fingerprint folds its own kind and
/// parameters with its inputs' *fingerprints* (not their indices), so
/// structurally identical prefixes built in different construction
/// orders — or embedded in different sessions' DAGs — agree. That is the
/// property the fleet-wide transform cache keys on: two jobs sharing a
/// DAG prefix share the prefix's fingerprint no matter what else their
/// DAGs contain.
pub fn dag_node_fingerprints(dag: &TransformDag) -> Vec<u64> {
    let mut fps: Vec<u64> = Vec::with_capacity(dag.nodes.len());
    for node in &dag.nodes {
        let mut h = Fnv64::new();
        match node {
            Node::Input { id, kind } => {
                h.write_u8(0);
                h.write_u32(id.0);
                h.write_u8(match kind {
                    InputKind::Auto => 0,
                    InputKind::Dense => 1,
                    InputKind::Sparse => 2,
                });
            }
            Node::Apply { op, inputs } => {
                h.write_u8(1);
                eat_op(&mut h, op);
                h.write_u64(inputs.len() as u64);
                // Nodes are topological by construction, so every input's
                // fingerprint is already computed.
                for &i in inputs {
                    h.write_u64(fps[i]);
                }
            }
        }
        fps.push(h.finish());
    }
    fps
}

/// The canonical fingerprint of the sub-DAG rooted at `node` — the
/// DAG-prefix half of the transform-cache key, factored out of
/// [`session_fingerprint`] so reuse works *across* sessions.
pub fn dag_prefix_fingerprint(dag: &TransformDag, node: usize) -> u64 {
    dag_node_fingerprints(dag)[node]
}

/// The raw input features the sub-DAG rooted at `node` reads, sorted and
/// deduplicated — the columns whose bytes form the content half of the
/// cache key (see [`batch_content_fingerprint`]).
pub fn prefix_inputs(dag: &TransformDag, node: usize) -> Vec<FeatureId> {
    let mut seen = vec![false; dag.nodes.len()];
    let mut stack = vec![node];
    let mut feats: Vec<FeatureId> = Vec::new();
    while let Some(i) = stack.pop() {
        if seen[i] {
            continue;
        }
        seen[i] = true;
        match &dag.nodes[i] {
            Node::Input { id, .. } => feats.push(*id),
            Node::Apply { inputs, .. } => {
                stack.extend(inputs.iter().copied());
            }
        }
    }
    feats.sort_unstable();
    feats.dedup();
    feats
}

/// Content fingerprint of the columns `feats` in `batch` — exactly the
/// domain [`TransformDag::execute`] reads for a sub-DAG over those
/// inputs: `num_rows` plus each projected column's presence bitmap and
/// payload bytes (absent columns hash a marker; the executor
/// materializes them as typed defaults, which `num_rows` pins down).
/// Every transform op is deterministic, so equal fingerprints under one
/// DAG-prefix fingerprint mean byte-identical transform outputs.
pub fn batch_content_fingerprint(
    batch: &ColumnarBatch,
    feats: &[FeatureId],
) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(batch.num_rows as u64);
    h.write_u64(feats.len() as u64);
    for &f in feats {
        h.write_u32(f.0);
        if let Some(c) = batch.dense.iter().find(|c| c.id == f) {
            h.write_u8(1);
            for &w in c.present.words() {
                h.write_u64(w);
            }
            for &v in &c.values {
                h.write_f32(v);
            }
        } else if let Some(c) = batch.sparse.iter().find(|c| c.id == f) {
            h.write_u8(2);
            for &o in &c.offsets {
                h.write_u32(o);
            }
            for &i in &c.ids {
                h.write_u64(i);
            }
            match &c.scores {
                None => h.write_u8(0),
                Some(s) => {
                    h.write_u8(1);
                    for &v in s {
                        h.write_f32(v);
                    }
                }
            }
        } else {
            h.write_u8(0);
        }
    }
    h.finish()
}

/// Heap bytes of one transform output column.
fn value_bytes(v: &Value) -> u64 {
    match v {
        Value::Dense(d) => 4 * d.len() as u64,
        Value::Sparse {
            offsets,
            ids,
            scores,
        } => {
            4 * offsets.len() as u64
                + 8 * ids.len() as u64
                + scores.as_ref().map_or(0, |s| 4 * s.len() as u64)
        }
    }
}

struct XEntry {
    value: Arc<Value>,
    bytes: u64,
    last_used: u64,
}

struct XInner {
    map: HashMap<(u64, u64), XEntry>,
    used: u64,
    tick: u64,
}

/// Fleet-wide cache of transform *outputs*, keyed by
/// (content fingerprint of the producing sub-DAG's input columns,
/// canonical DAG-prefix fingerprint). Sessions sharing a DAG prefix —
/// the common case when jobs iterate on a production baseline — run each
/// unique payload through the prefix once, extending the dedup-aware
/// within-session reuse of RecD across jobs. LRU under a byte budget,
/// which may be private or a [`MemoryBudget`] shared with the broker and
/// tensor cache.
pub struct TransformCache {
    inner: Mutex<XInner>,
    budget: Arc<MemoryBudget>,
    pub hits: Counter,
    pub misses: Counter,
    pub inserted_bytes: Counter,
    pub evictions: Counter,
    pub evicted_bytes: Counter,
}

impl TransformCache {
    /// A cache with its own private budget of `budget_bytes`.
    pub fn new(budget_bytes: u64) -> Arc<TransformCache> {
        Self::with_budget(MemoryBudget::new(budget_bytes))
    }

    /// A cache charging a (possibly shared) [`MemoryBudget`]; under
    /// pressure it evicts its own entries only, like [`TensorCache`].
    pub fn with_budget(budget: Arc<MemoryBudget>) -> Arc<TransformCache> {
        Arc::new(TransformCache {
            inner: Mutex::new(XInner {
                map: HashMap::new(),
                used: 0,
                tick: 0,
            }),
            budget,
            hits: Counter::new(),
            misses: Counter::new(),
            inserted_bytes: Counter::new(),
            evictions: Counter::new(),
            evicted_bytes: Counter::new(),
        })
    }

    /// Cached output for (input-content, DAG-prefix), if any.
    pub fn get(&self, content_fp: u64, prefix_fp: u64) -> Option<Arc<Value>> {
        let mut inner = lock_or_recover(&self.inner, "transform cache");
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&(content_fp, prefix_fp)) {
            Some(e) => {
                e.last_used = tick;
                self.hits.inc();
                Some(e.value.clone())
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Insert a freshly computed output, evicting this cache's own LRU
    /// entries to fit the budget. Returns whether it was stored.
    pub fn put(
        &self,
        content_fp: u64,
        prefix_fp: u64,
        value: Arc<Value>,
    ) -> bool {
        let bytes = value_bytes(&value);
        if bytes > self.budget.total() {
            return false;
        }
        let key = (content_fp, prefix_fp);
        let mut inner = lock_or_recover(&self.inner, "transform cache");
        if let Some(old) = inner.map.remove(&key) {
            inner.used -= old.bytes;
            self.budget.release(old.bytes);
        }
        while !self.budget.try_reserve(bytes) {
            let victim = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { return false };
            let e = inner.map.remove(&victim).expect("victim present");
            inner.used -= e.bytes;
            self.budget.release(e.bytes);
            self.evictions.inc();
            self.evicted_bytes.add(e.bytes);
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(
            key,
            XEntry {
                value,
                bytes,
                last_used: tick,
            },
        );
        inner.used += bytes;
        self.inserted_bytes.add(bytes);
        true
    }

    pub fn used_bytes(&self) -> u64 {
        lock_or_recover(&self.inner, "transform cache").used
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.inner, "transform cache").map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get() as f64;
        let m = self.misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dwrf::Projection;
    use crate::schema::FeatureId;
    use crate::tectonic::FileId;
    use crate::transforms::TransformDag;

    fn spec(table: &str, feats: &[u32], batch: usize) -> SessionSpec {
        let mut dag = TransformDag::default();
        for &f in feats {
            let i = dag.input(FeatureId(f));
            dag.output(FeatureId(f), i);
        }
        let mut s = SessionSpec::from_dag(table, 0, 1, dag, batch);
        s.projection = Projection::new(feats.iter().map(|&f| FeatureId(f)));
        s
    }

    fn split(file: u64, start: usize) -> Split {
        Split {
            id: crate::dpp::SplitId(start as u64),
            file: FileId(file),
            day: 0,
            stripe_start: start,
            stripe_count: 2,
            rows: 64,
        }
    }

    fn wire(bytes: Vec<u8>) -> Arc<Vec<WireBatch>> {
        Arc::new(vec![WireBatch::plain(0, 8, false, bytes)])
    }

    #[test]
    fn fingerprint_distinguishes_sessions() {
        let a = session_fingerprint(&spec("t", &[1, 2, 3], 32));
        let b = session_fingerprint(&spec("t", &[1, 2, 3], 32));
        assert_eq!(a, b, "deterministic");
        assert_ne!(a, session_fingerprint(&spec("t", &[1, 2, 4], 32)));
        assert_ne!(a, session_fingerprint(&spec("t", &[1, 2, 3], 64)));
        assert_ne!(a, session_fingerprint(&spec("u", &[1, 2, 3], 32)));
        // Projection order must not matter.
        assert_eq!(a, session_fingerprint(&spec("t", &[3, 2, 1], 32)));
    }

    #[test]
    fn fingerprint_covers_full_dag_not_just_counts() {
        use crate::transforms::Op;
        // Two specs with identical node/output *counts* but different
        // ops/parameters — the old count-based fingerprint collided here.
        let mk = |op: Op| {
            let mut dag = TransformDag::default();
            let i = dag.input(FeatureId(1));
            let x = dag.apply(op, vec![i]);
            dag.output(FeatureId(1), x);
            let mut s = SessionSpec::from_dag("t", 0, 1, dag, 32);
            s.projection = Projection::new([FeatureId(1)]);
            s
        };
        let a = mk(Op::SigridHash {
            salt: 1,
            modulus: 1000,
        });
        let b = mk(Op::SigridHash {
            salt: 2,
            modulus: 1000,
        });
        let c = mk(Op::FirstX { x: 5 });
        assert_ne!(session_fingerprint(&a), session_fingerprint(&b));
        assert_ne!(session_fingerprint(&a), session_fingerprint(&c));
        // Pipeline toggles matter too (they change the produced wire).
        let mut d = mk(Op::FirstX { x: 5 });
        d.pipeline.dedup_aware = !d.pipeline.dedup_aware;
        assert_ne!(session_fingerprint(&c), session_fingerprint(&d));
    }

    #[test]
    fn fingerprint_covers_wire_compression() {
        // Cache entries hold *encoded* wire bytes, so every knob that
        // changes the encoding must split the key space: on/off, level,
        // and the dictionary contents must all be pairwise distinct.
        let mk = |wc: WireCompression| {
            let mut s = spec("t", &[1, 2], 32);
            s.pipeline.wire_compression = wc;
            session_fingerprint(&s)
        };
        let off = mk(WireCompression::Off);
        let z3 = mk(WireCompression::zstd(3));
        let z9 = mk(WireCompression::zstd(9));
        let z3d = mk(WireCompression::Zstd {
            level: 3,
            dict: Some(Arc::new(vec![7u8; 32])),
        });
        let z3d2 = mk(WireCompression::Zstd {
            level: 3,
            dict: Some(Arc::new(vec![9u8; 32])),
        });
        let all = [off, z3, z9, z3d, z3d2];
        for i in 0..all.len() {
            for j in (i + 1)..all.len() {
                assert_ne!(all[i], all[j], "entries {i} and {j} collide");
            }
        }
        assert_eq!(z3, mk(WireCompression::zstd(3)), "deterministic");
        // The frame cap is a transport bound, not an encoding choice:
        // two sessions differing only in cap share cache entries.
        let mut a = spec("t", &[1, 2], 32);
        a.pipeline.max_frame_bytes = crate::dpp::spec::MIN_FRAME_BYTES;
        assert_eq!(session_fingerprint(&a), session_fingerprint(&spec("t", &[1, 2], 32)));
    }

    #[test]
    fn fingerprint_covers_row_predicate() {
        let base = spec("t", &[1, 2], 32);
        let a = base.clone().with_predicate(RowPredicate::SampleRate {
            rate: 0.5,
            seed: 1,
        });
        let b = base.clone().with_predicate(RowPredicate::SampleRate {
            rate: 0.5,
            seed: 2,
        });
        let c = base.clone().with_predicate(RowPredicate::And(vec![
            RowPredicate::TimestampRange { min: 0, max: 9 },
            RowPredicate::FeaturePresent {
                feature: FeatureId(1),
            },
        ]));
        let f0 = session_fingerprint(&base);
        let fa = session_fingerprint(&a);
        let fb = session_fingerprint(&b);
        let fc = session_fingerprint(&c);
        assert_ne!(f0, fa, "predicate must change the fingerprint");
        assert_ne!(fa, fb, "predicate seed matters");
        assert_ne!(fa, fc);
        assert_eq!(fa, session_fingerprint(&a.clone()), "deterministic");
    }

    #[test]
    fn cache_roundtrip_and_isolation() {
        let cache = TensorCache::new(1 << 20);
        let fp = 42u64;
        let batches = wire(vec![1, 2, 3]);
        assert!(cache.get(fp, &split(1, 0)).is_none());
        assert!(cache.put(fp, &split(1, 0), batches.clone()));
        let got = cache.get(fp, &split(1, 0)).unwrap();
        assert_eq!(got[0].bytes, vec![1, 2, 3]);
        // Different split / fingerprint: miss.
        assert!(cache.get(fp, &split(1, 2)).is_none());
        assert!(cache.get(fp + 1, &split(1, 0)).is_none());
        assert!((cache.hit_rate() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn budget_enforced() {
        let cache = TensorCache::new(4);
        let big = wire(vec![0; 8]);
        assert!(!cache.put(1, &split(1, 0), big));
        assert_eq!(cache.used_bytes(), 0);
        let small = wire(vec![0; 3]);
        assert!(cache.put(1, &split(1, 0), small));
        assert_eq!(cache.used_bytes(), 3);
    }

    #[test]
    fn lru_eviction_under_budget_pressure() {
        let cache = TensorCache::new(10);
        assert!(cache.put(1, &split(1, 0), wire(vec![0; 4]))); // A
        assert!(cache.put(1, &split(1, 2), wire(vec![0; 4]))); // B
        assert_eq!(cache.used_bytes(), 8);
        // Touch A so B becomes the LRU victim.
        assert!(cache.get(1, &split(1, 0)).is_some());
        assert!(cache.put(1, &split(1, 4), wire(vec![0; 4]))); // C evicts B
        assert_eq!(cache.evictions.get(), 1);
        assert_eq!(cache.evicted_bytes.get(), 4);
        assert_eq!(cache.used_bytes(), 8);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(1, &split(1, 0)).is_some(), "A survives");
        assert!(cache.get(1, &split(1, 4)).is_some(), "C present");
        assert!(cache.get(1, &split(1, 2)).is_none(), "B evicted");
    }

    #[test]
    fn eviction_frees_enough_for_large_insert() {
        let cache = TensorCache::new(10);
        assert!(cache.put(1, &split(1, 0), wire(vec![0; 3])));
        assert!(cache.put(1, &split(1, 2), wire(vec![0; 3])));
        assert!(cache.put(1, &split(1, 4), wire(vec![0; 3])));
        // 9 used; a 10-byte insert must evict everything, then fit.
        assert!(cache.put(1, &split(1, 6), wire(vec![0; 10])));
        assert_eq!(cache.used_bytes(), 10);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions.get(), 3);
    }

    #[test]
    fn reinsert_replaces_without_double_counting() {
        let cache = TensorCache::new(10);
        assert!(cache.put(1, &split(1, 0), wire(vec![0; 4])));
        assert!(cache.put(1, &split(1, 0), wire(vec![0; 6])));
        assert_eq!(cache.used_bytes(), 6);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn shared_budget_with_external_consumer() {
        // Broker stripe buffers and the tensor cache charge one pool:
        // the cache sheds its own entries under pressure, and external
        // reservations can squeeze it out entirely — the sum of both
        // consumers never exceeds the budget.
        let budget = MemoryBudget::new(10);
        let cache = TensorCache::with_budget(budget.clone());
        assert_eq!(cache.budget_total(), 10);
        assert!(cache.put(1, &split(1, 0), wire(vec![0; 4])));
        // An external consumer (a shared stripe) takes the rest.
        assert!(budget.try_reserve(6));
        assert_eq!(budget.used(), 10);
        // The cache evicts its own entry to fit a new one...
        assert!(cache.put(1, &split(1, 2), wire(vec![0; 4])));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions.get(), 1);
        // ...but cannot fit 5 bytes next to the external 6: it ends up
        // empty and the insert fails rather than over-committing.
        assert!(!cache.put(1, &split(1, 4), wire(vec![0; 5])));
        assert_eq!(cache.used_bytes(), 0);
        assert_eq!(budget.used(), 6);
        // Once the external consumer releases, inserts fit again.
        budget.release(6);
        assert!(cache.put(1, &split(1, 4), wire(vec![0; 5])));
        assert_eq!(budget.used(), 5);
    }

    #[test]
    fn hit_rate_accounting() {
        let cache = TensorCache::new(1 << 10);
        assert_eq!(cache.hit_rate(), 0.0);
        assert!(cache.put(7, &split(2, 0), wire(vec![1])));
        for _ in 0..3 {
            assert!(cache.get(7, &split(2, 0)).is_some());
        }
        assert!(cache.get(7, &split(2, 2)).is_none());
        assert_eq!(cache.hits.get(), 3);
        assert_eq!(cache.misses.get(), 1);
        assert!((cache.hit_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn fingerprint_covers_column_sharing() {
        // The toggle changes which cached transform outputs a session may
        // share, so twins differing only in it must not collide.
        let a = spec("t", &[1, 2], 32);
        let mut b = spec("t", &[1, 2], 32);
        b.pipeline.column_sharing = !b.pipeline.column_sharing;
        assert_ne!(session_fingerprint(&a), session_fingerprint(&b));
    }

    #[test]
    fn dag_prefix_fingerprint_is_construction_order_independent() {
        use crate::transforms::Op;
        // Same logical prefix (FirstX over feature 5) embedded at
        // different node indices in two different DAGs.
        let mut a = TransformDag::default();
        let ai = a.input(FeatureId(5));
        let ax = a.apply(Op::FirstX { x: 3 }, vec![ai]);
        a.output(FeatureId(5), ax);

        let mut b = TransformDag::default();
        let noise = b.input(FeatureId(9)); // shifts every later index
        b.output(FeatureId(9), noise);
        let bi = b.input(FeatureId(5));
        let bx = b.apply(Op::FirstX { x: 3 }, vec![bi]);
        b.output(FeatureId(5), bx);

        assert_eq!(
            dag_prefix_fingerprint(&a, ax),
            dag_prefix_fingerprint(&b, bx),
            "shared prefix must agree across sessions"
        );
        // Parameter change breaks the match.
        let mut c = TransformDag::default();
        let ci = c.input(FeatureId(5));
        let cx = c.apply(Op::FirstX { x: 4 }, vec![ci]);
        c.output(FeatureId(5), cx);
        assert_ne!(
            dag_prefix_fingerprint(&a, ax),
            dag_prefix_fingerprint(&c, cx)
        );
        // A bare input differs from an op over it.
        assert_ne!(
            dag_prefix_fingerprint(&a, ai),
            dag_prefix_fingerprint(&a, ax)
        );
    }

    #[test]
    fn prefix_inputs_walks_only_the_subdag() {
        use crate::transforms::Op;
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(1));
        let b = dag.input(FeatureId(2));
        let other = dag.input(FeatureId(7));
        let x = dag.apply(Op::Cartesian, vec![a, b]);
        dag.output(FeatureId(100), x);
        dag.output(FeatureId(7), other);
        assert_eq!(prefix_inputs(&dag, x), vec![FeatureId(1), FeatureId(2)]);
        assert_eq!(prefix_inputs(&dag, other), vec![FeatureId(7)]);
    }

    #[test]
    fn content_fingerprint_tracks_projected_columns_only() {
        use crate::data::{Bitmap, DenseColumn, SparseColumn};
        let mk = |val: f32, unrelated: u64| {
            let mut present = Bitmap::new(4);
            for i in 0..4 {
                present.set(i);
            }
            ColumnarBatch {
                num_rows: 4,
                dense: vec![DenseColumn {
                    id: FeatureId(1),
                    present,
                    values: vec![val; 4],
                }],
                sparse: vec![SparseColumn {
                    id: FeatureId(2),
                    offsets: vec![0, 1, 2, 3, 4],
                    ids: vec![unrelated; 4],
                    scores: None,
                }],
                labels: vec![0.0; 4],
                timestamps: vec![0; 4],
                selection: None,
            }
        };
        let feats = [FeatureId(1)];
        let a = batch_content_fingerprint(&mk(1.0, 10), &feats);
        assert_eq!(a, batch_content_fingerprint(&mk(1.0, 99), &feats),
            "columns outside the prefix's inputs must not matter");
        assert_ne!(a, batch_content_fingerprint(&mk(2.0, 10), &feats),
            "payload bytes must matter");
        // Absent column hashes differently from any present one.
        let both = [FeatureId(1), FeatureId(3)];
        let c = batch_content_fingerprint(&mk(1.0, 10), &both);
        assert_ne!(a, c);
    }

    #[test]
    fn transform_cache_roundtrip_and_eviction() {
        let cache = TransformCache::new(40);
        assert!(cache.get(1, 1).is_none());
        let v = Arc::new(Value::Dense(vec![1.0; 5])); // 20 bytes
        assert!(cache.put(1, 1, v.clone()));
        assert_eq!(cache.used_bytes(), 20);
        assert_eq!(cache.get(1, 1).unwrap(), v);
        // Same content under a different prefix is a different entry.
        assert!(cache.get(1, 2).is_none());
        assert!(cache.put(1, 2, Arc::new(Value::Dense(vec![2.0; 5]))));
        assert_eq!(cache.len(), 2);
        // Touch (1,1) so (1,2) is the LRU victim for the next insert.
        assert!(cache.get(1, 1).is_some());
        assert!(cache.put(3, 3, Arc::new(Value::Dense(vec![3.0; 5]))));
        assert_eq!(cache.evictions.get(), 1);
        assert!(cache.get(1, 2).is_none(), "LRU entry evicted");
        assert!(cache.get(1, 1).is_some(), "hot entry survives");
        // Oversized values are refused outright.
        assert!(!cache.put(9, 9, Arc::new(Value::Dense(vec![0.0; 100]))));
    }

    #[test]
    fn transform_cache_shares_budget() {
        let budget = MemoryBudget::new(40);
        let cache = TransformCache::with_budget(budget.clone());
        assert!(budget.try_reserve(20)); // external consumer
        assert!(cache.put(
            1,
            1,
            Arc::new(Value::Sparse {
                offsets: vec![0, 1], // 2×4 bytes
                ids: vec![7],        // 1×8 bytes
                scores: None,
            })
        ));
        assert_eq!(budget.used(), 36, "20 external + 16 cached");
        // A 24-byte value cannot fit next to the external 20 even after
        // evicting every own entry: the insert fails, the cache empties,
        // and the external reservation is untouched.
        assert!(!cache.put(2, 2, Arc::new(Value::Dense(vec![0.0; 6]))));
        assert_eq!(cache.used_bytes(), 0);
        assert_eq!(budget.used(), 20);
    }
}
