//! Preprocessed-tensor cache (§7.5: "We are also exploring other
//! optimization techniques, such as caching preprocessed tensors").
//!
//! Keyed by (split extent, session fingerprint): two jobs (or epochs)
//! with the same projection + transform pipeline + batching reuse each
//! other's fully-preprocessed wire batches, skipping storage reads,
//! extraction, and transformation entirely — the OneAccess-style sharing
//! the paper cites as related work, applied at the worker.

use super::spec::SessionSpec;
use super::split::Split;
use super::worker::WireBatch;
use crate::metrics::Counter;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

/// Fingerprint of everything that affects a split's preprocessed output.
pub fn session_fingerprint(spec: &SessionSpec) -> u64 {
    // FNV-1a over the semantically-relevant session fields.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    eat(spec.table.as_bytes());
    let mut feats: Vec<u32> = spec.projection.iter().map(|f| f.0).collect();
    feats.sort_unstable();
    for f in feats {
        eat(&f.to_le_bytes());
    }
    eat(&(spec.batch_size as u64).to_le_bytes());
    eat(&[
        spec.pipeline.fast_decode as u8,
        spec.pipeline.flatmap as u8,
    ]);
    eat(&spec.pipeline.coalesce.unwrap_or(0).to_le_bytes());
    eat(&(spec.dag.nodes.len() as u64).to_le_bytes());
    eat(&(spec.dag.outputs.len() as u64).to_le_bytes());
    h
}

type Key = (u64, u64, usize, usize); // (fingerprint, file, stripe_start, count)

/// Bounded shared cache of preprocessed wire batches.
pub struct TensorCache {
    map: RwLock<HashMap<Key, Arc<Vec<WireBatch>>>>,
    pub budget_bytes: u64,
    used: RwLock<u64>,
    pub hits: Counter,
    pub misses: Counter,
    pub inserted_bytes: Counter,
}

impl TensorCache {
    pub fn new(budget_bytes: u64) -> Arc<TensorCache> {
        Arc::new(TensorCache {
            map: RwLock::new(HashMap::new()),
            budget_bytes,
            used: RwLock::new(0),
            hits: Counter::new(),
            misses: Counter::new(),
            inserted_bytes: Counter::new(),
        })
    }

    fn key(fingerprint: u64, split: &Split) -> Key {
        (
            fingerprint,
            split.file.0,
            split.stripe_start,
            split.stripe_count,
        )
    }

    pub fn get(&self, fingerprint: u64, split: &Split) -> Option<Arc<Vec<WireBatch>>> {
        let got = self
            .map
            .read()
            .unwrap()
            .get(&Self::key(fingerprint, split))
            .cloned();
        match &got {
            Some(_) => self.hits.inc(),
            None => self.misses.inc(),
        }
        got
    }

    /// Insert if within budget. Returns whether it was stored.
    pub fn put(
        &self,
        fingerprint: u64,
        split: &Split,
        batches: Arc<Vec<WireBatch>>,
    ) -> bool {
        let bytes: u64 = batches.iter().map(|b| b.bytes.len() as u64).sum();
        {
            let mut used = self.used.write().unwrap();
            if *used + bytes > self.budget_bytes {
                return false;
            }
            *used += bytes;
        }
        self.inserted_bytes.add(bytes);
        self.map
            .write()
            .unwrap()
            .insert(Self::key(fingerprint, split), batches);
        true
    }

    pub fn used_bytes(&self) -> u64 {
        *self.used.read().unwrap()
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get() as f64;
        let m = self.misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dwrf::Projection;
    use crate::schema::FeatureId;
    use crate::tectonic::FileId;
    use crate::transforms::TransformDag;

    fn spec(table: &str, feats: &[u32], batch: usize) -> SessionSpec {
        let mut dag = TransformDag::default();
        for &f in feats {
            let i = dag.input(FeatureId(f));
            dag.output(FeatureId(f), i);
        }
        let mut s = SessionSpec::from_dag(table, 0, 1, dag, batch);
        s.projection = Projection::new(feats.iter().map(|&f| FeatureId(f)));
        s
    }

    fn split(file: u64, start: usize) -> Split {
        Split {
            id: crate::dpp::SplitId(start as u64),
            file: FileId(file),
            day: 0,
            stripe_start: start,
            stripe_count: 2,
            rows: 64,
        }
    }

    #[test]
    fn fingerprint_distinguishes_sessions() {
        let a = session_fingerprint(&spec("t", &[1, 2, 3], 32));
        let b = session_fingerprint(&spec("t", &[1, 2, 3], 32));
        assert_eq!(a, b, "deterministic");
        assert_ne!(a, session_fingerprint(&spec("t", &[1, 2, 4], 32)));
        assert_ne!(a, session_fingerprint(&spec("t", &[1, 2, 3], 64)));
        assert_ne!(a, session_fingerprint(&spec("u", &[1, 2, 3], 32)));
        // Projection order must not matter.
        assert_eq!(a, session_fingerprint(&spec("t", &[3, 2, 1], 32)));
    }

    #[test]
    fn cache_roundtrip_and_isolation() {
        let cache = TensorCache::new(1 << 20);
        let fp = 42u64;
        let batches = Arc::new(vec![WireBatch {
            seq: 0,
            rows: 8,
            bytes: vec![1, 2, 3],
        }]);
        assert!(cache.get(fp, &split(1, 0)).is_none());
        assert!(cache.put(fp, &split(1, 0), batches.clone()));
        let got = cache.get(fp, &split(1, 0)).unwrap();
        assert_eq!(got[0].bytes, vec![1, 2, 3]);
        // Different split / fingerprint: miss.
        assert!(cache.get(fp, &split(1, 2)).is_none());
        assert!(cache.get(fp + 1, &split(1, 0)).is_none());
        assert!((cache.hit_rate() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn budget_enforced() {
        let cache = TensorCache::new(4);
        let big = Arc::new(vec![WireBatch {
            seq: 0,
            rows: 8,
            bytes: vec![0; 8],
        }]);
        assert!(!cache.put(1, &split(1, 0), big));
        assert_eq!(cache.used_bytes(), 0);
        let small = Arc::new(vec![WireBatch {
            seq: 0,
            rows: 8,
            bytes: vec![0; 3],
        }]);
        assert!(cache.put(1, &split(1, 0), small));
        assert_eq!(cache.used_bytes(), 3);
    }
}
