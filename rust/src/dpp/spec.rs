//! The session specification a training job hands to the DPP Master
//! (§3.2.1): "the dataset table, specific partitions, required features,
//! and transformation operations for each feature" — the PyTorch DataSet
//! analogue — plus the pipeline-optimization toggles characterized in
//! Table 12.

use crate::dwrf::plan::COALESCE_WINDOW;
use crate::dwrf::Projection;
use crate::schema::FeatureId;
use crate::transforms::TransformDag;

/// Worker-side pipeline toggles (the read/decode/format levers of
/// Table 12; the write-side levers FF/FR/LS are fixed at dataset-build
/// time in [`crate::dwrf::WriterOptions`]).
#[derive(Clone, Debug)]
pub struct PipelineOptions {
    /// Coalesced reads window (CR). `None` = one I/O per stream.
    pub coalesce: Option<u64>,
    /// Branch-lean decode inner loops (LO).
    pub fast_decode: bool,
    /// Keep batches columnar end-to-end (FM, "in-memory flatmap");
    /// `false` = reconstruct row maps and convert back (the baseline's
    /// extra format changes and copies).
    pub flatmap: bool,
    /// RecD-style dedup-aware preprocessing: on Dedup-encoded files,
    /// transform each unique payload once and ship inverse-keyed wire
    /// batches that the Client expands. No effect on Map/Flattened
    /// files. Requires row-index-independent transforms; the worker
    /// checks `TransformDag::row_index_sensitive` (true for `Sampling`)
    /// and falls back to the oblivious path when it would be unsound.
    pub dedup_aware: bool,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        // Production configuration: everything on.
        PipelineOptions {
            coalesce: Some(COALESCE_WINDOW),
            fast_decode: true,
            flatmap: true,
            dedup_aware: true,
        }
    }
}

impl PipelineOptions {
    /// The pre-optimization worker (for ablations).
    pub fn baseline() -> PipelineOptions {
        PipelineOptions {
            coalesce: None,
            fast_decode: false,
            flatmap: false,
            dedup_aware: false,
        }
    }
}

/// A training job's preprocessing workload.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub table: String,
    /// Row filter: day partitions `[from_day, to_day]`.
    pub from_day: u32,
    pub to_day: u32,
    /// Column filter: raw features to read.
    pub projection: Projection,
    /// Per-feature transformation program.
    pub dag: TransformDag,
    /// Rows per output tensor batch.
    pub batch_size: usize,
    /// Stripes per split (work-item granularity).
    pub stripes_per_split: usize,
    pub pipeline: PipelineOptions,
}

impl SessionSpec {
    /// Build a spec whose projection is exactly the DAG's required inputs
    /// (plus any extra features the caller wants materialized raw).
    pub fn from_dag(
        table: &str,
        from_day: u32,
        to_day: u32,
        dag: TransformDag,
        batch_size: usize,
    ) -> SessionSpec {
        let inputs: Vec<FeatureId> = dag.required_inputs();
        SessionSpec {
            table: table.to_string(),
            from_day,
            to_day,
            projection: Projection::new(inputs),
            dag,
            batch_size,
            stripes_per_split: 2,
            pipeline: PipelineOptions::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Op;

    #[test]
    fn spec_projection_tracks_dag_inputs() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(3));
        let b = dag.input(FeatureId(9));
        let x = dag.apply(Op::Cartesian, vec![a, b]);
        dag.output(FeatureId(100), x);
        let spec = SessionSpec::from_dag("t", 0, 1, dag, 32);
        assert_eq!(spec.projection.len(), 2);
        assert!(spec.projection.contains(FeatureId(3)));
        assert!(spec.projection.contains(FeatureId(9)));
        assert!(!spec.projection.contains(FeatureId(100)));
    }

    #[test]
    fn default_pipeline_is_fully_optimized() {
        let p = PipelineOptions::default();
        assert!(p.coalesce.is_some());
        assert!(p.fast_decode);
        assert!(p.flatmap);
        assert!(p.dedup_aware);
        let b = PipelineOptions::baseline();
        assert!(b.coalesce.is_none());
        assert!(!b.fast_decode);
        assert!(!b.flatmap);
        assert!(!b.dedup_aware);
    }
}
