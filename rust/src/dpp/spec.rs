//! The session specification a training job hands to the DPP Master
//! (§3.2.1): "the dataset table, specific partitions, required features,
//! and transformation operations for each feature" — the PyTorch DataSet
//! analogue — plus the pipeline-optimization toggles characterized in
//! Table 12.

use super::transport::MAX_FRAME_BYTES;
use crate::dwrf::plan::COALESCE_WINDOW;
use crate::dwrf::Projection;
use crate::filter::RowPredicate;
use crate::schema::FeatureId;
use crate::transforms::TransformDag;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Default zstd level for the worker→client wire: level 3 is zstd's own
/// default — a good ratio at a compression speed far above the wire
/// rates a single worker produces.
pub const DEFAULT_WIRE_ZSTD_LEVEL: i32 = 3;

/// Smallest frame cap a session may configure (64 KiB). Below this even
/// a single small tensor batch could exceed the cap and wedge the
/// session; the floor keeps `max_frame_bytes` a throttle, not a foot-gun.
pub const MIN_FRAME_BYTES: usize = 64 << 10;

/// Transport compression for `WireBatch::bytes` (the tentpole knob of
/// the leaner wire path). Compression runs *before* encryption — the
/// AES-CTR pass turns the payload into noise, so the order is load-
/// bearing, not a preference.
#[derive(Clone, Debug)]
pub enum WireCompression {
    /// Ship raw serialized bytes (the ablation; byte-identical to the
    /// pre-compression wire format).
    Off,
    /// Per-feature-stream zstd framing: each feature's column/stream is
    /// an independently-framed zstd section, so the columnar layout
    /// compresses well and a corrupt section is detected per stream.
    Zstd {
        /// zstd compression level (1..=19).
        level: i32,
        /// Optional per-session trained dictionary (see
        /// [`crate::dpp::codec::train_wire_dict`]): small per-feature
        /// sections share one sample-trained context. Both sides must
        /// hold the same bytes — it is part of the session fingerprint.
        dict: Option<Arc<Vec<u8>>>,
    },
}

impl WireCompression {
    /// Dictionary-less zstd at `level`.
    pub fn zstd(level: i32) -> WireCompression {
        WireCompression::Zstd { level, dict: None }
    }

    pub fn is_on(&self) -> bool {
        !matches!(self, WireCompression::Off)
    }

    /// The session dictionary bytes, if any.
    pub fn dict(&self) -> Option<&[u8]> {
        match self {
            WireCompression::Off => None,
            WireCompression::Zstd { dict, .. } => {
                dict.as_ref().map(|d| d.as_slice())
            }
        }
    }
}

/// Worker-side pipeline toggles (the read/decode/format levers of
/// Table 12; the write-side levers FF/FR/LS are fixed at dataset-build
/// time in [`crate::dwrf::WriterOptions`]).
#[derive(Clone, Debug)]
pub struct PipelineOptions {
    /// Coalesced reads window (CR). `None` = one I/O per stream.
    pub coalesce: Option<u64>,
    /// Branch-lean decode inner loops (LO).
    pub fast_decode: bool,
    /// Keep batches columnar end-to-end (FM, "in-memory flatmap");
    /// `false` = reconstruct row maps and convert back (the baseline's
    /// extra format changes and copies).
    pub flatmap: bool,
    /// RecD-style dedup-aware preprocessing: on Dedup-encoded files,
    /// transform each unique payload once and ship inverse-keyed wire
    /// batches that the Client expands. No effect on Map/Flattened
    /// files. Requires row-index-independent transforms; the worker
    /// checks `TransformDag::row_index_sensitive` (true for `Sampling`)
    /// and falls back to the oblivious path when it would be unsound.
    pub dedup_aware: bool,
    /// Predicate pushdown: prune provably-empty stripes from read plans
    /// and splits via footer stats, and filter surviving stripes through
    /// selection vectors right after decode. `false` = the
    /// decode-then-filter baseline: every stripe is fetched and decoded,
    /// and the predicate only applies at the tensor boundary.
    pub pushdown: bool,
    /// Sub-stripe zone-map pruning (requires `pushdown`): evaluate the
    /// predicate against footer v3 row-group stats too, pre-seed the
    /// stripe plan with a group survival mask, and — on
    /// row-group-split flattened files — drop pruned groups' byte
    /// ranges from the I/O plan. `false` limits pushdown to stripe
    /// granularity (the pre-zone-map behavior, kept for ablation
    /// benches). Lossless either way.
    pub row_group_pruning: bool,
    /// Cross-job shared reads: when the session's Master is attached to
    /// a [`crate::broker::ReadBroker`], workers fetch stripes through it
    /// so concurrent sessions over overlapping partitions pay each
    /// storage fetch + stripe decode once. Per-session predicates,
    /// selection vectors, and transforms apply after the shared decode —
    /// outputs are byte-identical either way. No effect without an
    /// attached broker.
    pub shared_reads: bool,
    /// Column-grain sharing (requires `shared_reads` + a broker): workers
    /// fetch per-(file, stripe, column) payloads through the broker's
    /// popularity-aware column cache, so sessions with overlapping — but
    /// different — projections serve their columns from any wider cached
    /// decode instead of holding whole private stripes. `false` falls
    /// back to stripe-grain sharing (the PR 3 behavior, kept as the
    /// ablation). Outputs are byte-identical either way, but the toggle
    /// changes which cached transform outputs a session may legally
    /// share, so it *is* part of the session fingerprint.
    pub column_sharing: bool,
    /// Emit observability spans ([`crate::obs`]): when on, `run_session`
    /// allocates an `Obs` sink (unless the caller supplied one) and
    /// Master/workers/broker/clients record per-stage spans + latency
    /// histograms, exportable as Chrome-trace JSON. Diagnostic only — it
    /// never changes pipeline output, so it is deliberately *excluded*
    /// from the tensor-cache session fingerprint.
    pub tracing: bool,
    /// Worker→client transport compression (zstd per-feature framing,
    /// applied before encryption). Changes the wire bytes, so it *is*
    /// part of the tensor-cache session fingerprint — compressed and
    /// uncompressed sessions must never share cached wire batches.
    pub wire_compression: WireCompression,
    /// Frame cap both sides of the wire enforce (post-compression
    /// payload size; the declared decompressed size is bounded against
    /// it too). Validated into `[MIN_FRAME_BYTES, MAX_FRAME_BYTES]` at
    /// spec build time so worker and client always agree. A cap, not an
    /// encoding choice — it never changes the bytes produced, so it is
    /// excluded from the session fingerprint.
    pub max_frame_bytes: usize,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        // Production configuration: everything on.
        PipelineOptions {
            coalesce: Some(COALESCE_WINDOW),
            fast_decode: true,
            flatmap: true,
            dedup_aware: true,
            pushdown: true,
            row_group_pruning: true,
            shared_reads: true,
            column_sharing: true,
            // Off by default: tracing is opt-in (CLI `--trace`, benches,
            // tests) so the hot path stays span-free out of the box.
            tracing: false,
            wire_compression: WireCompression::zstd(DEFAULT_WIRE_ZSTD_LEVEL),
            max_frame_bytes: MAX_FRAME_BYTES,
        }
    }
}

impl PipelineOptions {
    /// The pre-optimization worker (for ablations).
    pub fn baseline() -> PipelineOptions {
        PipelineOptions {
            coalesce: None,
            fast_decode: false,
            flatmap: false,
            dedup_aware: false,
            pushdown: false,
            row_group_pruning: false,
            shared_reads: false,
            column_sharing: false,
            tracing: false,
            wire_compression: WireCompression::Off,
            max_frame_bytes: MAX_FRAME_BYTES,
        }
    }

    /// Reject configurations the wire path cannot honor. Called by
    /// `Master::build` so a bad spec fails at session intake — before a
    /// worker panics mid-split or a client silently disagrees with the
    /// worker about the frame cap.
    pub fn validate(&self) -> Result<()> {
        if let WireCompression::Zstd { level, dict } = &self.wire_compression
        {
            if !(1..=19).contains(level) {
                bail!(
                    "wire_compression zstd level {level} outside 1..=19"
                );
            }
            if let Some(d) = dict {
                if d.is_empty() {
                    bail!("wire_compression dictionary is empty");
                }
            }
        }
        if self.max_frame_bytes < MIN_FRAME_BYTES {
            bail!(
                "max_frame_bytes {} below floor {MIN_FRAME_BYTES}",
                self.max_frame_bytes
            );
        }
        if self.max_frame_bytes > MAX_FRAME_BYTES {
            bail!(
                "max_frame_bytes {} above transport cap {MAX_FRAME_BYTES}",
                self.max_frame_bytes
            );
        }
        Ok(())
    }
}

/// A training job's preprocessing workload.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub table: String,
    /// Row filter: day partitions `[from_day, to_day]`.
    pub from_day: u32,
    pub to_day: u32,
    /// Column filter: raw features to read.
    pub projection: Projection,
    /// Row filter: the predicate pushed down the read path (stripe
    /// pruning + selection vectors). Applied losslessly whether or not
    /// `pipeline.pushdown` is on — pushdown only moves *where* the rows
    /// are dropped. Decisions are content-keyed (label / timestamp /
    /// feature presence), never row-position-keyed, so filtered
    /// sessions stay dedup-compatible — unlike the legacy `Sampling`
    /// transform op, whose position-hash mask forces the oblivious path.
    pub predicate: Option<RowPredicate>,
    /// Per-feature transformation program.
    pub dag: TransformDag,
    /// Rows per output tensor batch.
    pub batch_size: usize,
    /// Stripes per split (work-item granularity).
    pub stripes_per_split: usize,
    pub pipeline: PipelineOptions,
}

impl SessionSpec {
    /// Build a spec whose projection is exactly the DAG's required inputs
    /// (plus any extra features the caller wants materialized raw).
    pub fn from_dag(
        table: &str,
        from_day: u32,
        to_day: u32,
        dag: TransformDag,
        batch_size: usize,
    ) -> SessionSpec {
        let inputs: Vec<FeatureId> = dag.required_inputs();
        SessionSpec {
            table: table.to_string(),
            from_day,
            to_day,
            projection: Projection::new(inputs),
            predicate: None,
            dag,
            batch_size,
            stripes_per_split: 2,
            pipeline: PipelineOptions::default(),
        }
    }

    /// Stats-free prior for the fraction of rows this session's
    /// predicate keeps (1.0 when unfiltered) — the autoscaler's
    /// feed-forward selectivity signal before any stripe stats or
    /// decoded-row observations exist.
    pub fn estimated_selectivity(&self) -> f64 {
        self.predicate.as_ref().map_or(1.0, |p| p.selectivity())
    }

    /// Attach a row predicate (builder style). Features the predicate
    /// inspects (`FeaturePresent`) are pulled into the projection:
    /// presence is evaluated over *decoded* columns, so filtering on an
    /// undecoded feature would silently drop every row — while the
    /// writer's stripe stats (computed over all features) would never
    /// prune, quietly decoding everything just to discard it.
    pub fn with_predicate(mut self, predicate: RowPredicate) -> SessionSpec {
        let extra = predicate.features();
        if !extra.is_empty() {
            self.projection = Projection::new(
                self.projection.iter().copied().chain(extra),
            );
        }
        self.predicate = Some(predicate);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transforms::Op;

    #[test]
    fn spec_projection_tracks_dag_inputs() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(3));
        let b = dag.input(FeatureId(9));
        let x = dag.apply(Op::Cartesian, vec![a, b]);
        dag.output(FeatureId(100), x);
        let spec = SessionSpec::from_dag("t", 0, 1, dag, 32);
        assert_eq!(spec.projection.len(), 2);
        assert!(spec.projection.contains(FeatureId(3)));
        assert!(spec.projection.contains(FeatureId(9)));
        assert!(!spec.projection.contains(FeatureId(100)));
    }

    #[test]
    fn default_pipeline_is_fully_optimized() {
        let p = PipelineOptions::default();
        assert!(p.coalesce.is_some());
        assert!(p.fast_decode);
        assert!(p.flatmap);
        assert!(p.dedup_aware);
        assert!(p.pushdown);
        assert!(p.row_group_pruning);
        assert!(p.shared_reads);
        assert!(p.column_sharing);
        assert!(!p.tracing, "tracing is opt-in, not a default");
        assert!(p.wire_compression.is_on());
        assert!(matches!(
            p.wire_compression,
            WireCompression::Zstd {
                level: DEFAULT_WIRE_ZSTD_LEVEL,
                dict: None
            }
        ));
        assert_eq!(p.max_frame_bytes, MAX_FRAME_BYTES);
        let b = PipelineOptions::baseline();
        assert!(b.coalesce.is_none());
        assert!(!b.fast_decode);
        assert!(!b.flatmap);
        assert!(!b.dedup_aware);
        assert!(!b.pushdown);
        assert!(!b.row_group_pruning);
        assert!(!b.shared_reads);
        assert!(!b.column_sharing);
        assert!(!b.tracing);
        assert!(!b.wire_compression.is_on());
        assert_eq!(b.max_frame_bytes, MAX_FRAME_BYTES);
    }

    #[test]
    fn validate_accepts_default_and_baseline() {
        assert!(PipelineOptions::default().validate().is_ok());
        assert!(PipelineOptions::baseline().validate().is_ok());
        let p = PipelineOptions {
            wire_compression: WireCompression::Zstd {
                level: 19,
                dict: Some(Arc::new(vec![1, 2, 3])),
            },
            max_frame_bytes: MIN_FRAME_BYTES,
            ..PipelineOptions::default()
        };
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_wire_options() {
        let mut p = PipelineOptions {
            wire_compression: WireCompression::zstd(0),
            ..PipelineOptions::default()
        };
        assert!(p.validate().is_err(), "level 0 is out of range");
        p.wire_compression = WireCompression::zstd(99);
        assert!(p.validate().is_err(), "level 99 is out of range");
        p.wire_compression = WireCompression::Zstd {
            level: 3,
            dict: Some(Arc::new(Vec::new())),
        };
        assert!(p.validate().is_err(), "empty dictionary");
        p = PipelineOptions::default();
        p.max_frame_bytes = MIN_FRAME_BYTES - 1;
        assert!(p.validate().is_err(), "cap below floor");
        p.max_frame_bytes = MAX_FRAME_BYTES + 1;
        assert!(p.validate().is_err(), "cap above transport ceiling");
    }

    #[test]
    fn with_predicate_attaches_row_filter() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(1));
        dag.output(FeatureId(1), a);
        let spec = SessionSpec::from_dag("t", 0, 1, dag, 8);
        assert!(spec.predicate.is_none());
        let spec = spec.with_predicate(RowPredicate::SampleRate {
            rate: 0.5,
            seed: 3,
        });
        assert!(spec.predicate.is_some());
    }

    #[test]
    fn estimated_selectivity_follows_predicate() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(1));
        dag.output(FeatureId(1), a);
        let spec = SessionSpec::from_dag("t", 0, 1, dag, 8);
        assert_eq!(spec.estimated_selectivity(), 1.0, "unfiltered");
        let spec = spec.with_predicate(RowPredicate::SampleRate {
            rate: 0.2,
            seed: 11,
        });
        assert!((spec.estimated_selectivity() - 0.2).abs() < 1e-9);
    }

    #[test]
    fn with_predicate_projects_presence_features() {
        let mut dag = TransformDag::default();
        let a = dag.input(FeatureId(1));
        dag.output(FeatureId(1), a);
        let spec = SessionSpec::from_dag("t", 0, 1, dag, 8);
        assert!(!spec.projection.contains(FeatureId(7)));
        // A presence filter on a feature outside the DAG's inputs must
        // force that feature into the read projection, or the decoded
        // batch could never answer it.
        let spec = spec.with_predicate(RowPredicate::And(vec![
            RowPredicate::FeaturePresent {
                feature: FeatureId(7),
            },
            RowPredicate::SampleRate { rate: 0.9, seed: 0 },
        ]));
        assert!(spec.projection.contains(FeatureId(7)));
        assert!(spec.projection.contains(FeatureId(1)));
    }
}
