//! Preprocessed tensor batches and their wire format.
//!
//! Workers batch transformed features into tensors (§3.2.1) and serve
//! them to Clients over RPC. The wire path models the paper's
//! "datacenter tax" (§6.2): a Thrift-like compact binary serialization
//! plus TLS-style encryption — both real byte passes whose CPU/memory
//! cost shows up in the Fig 8 loading experiment.

use crate::dwrf::crypto::StreamCipher;
use crate::schema::FeatureId;
use crate::transforms::Value;
use crate::util::bytes::{put_f32, put_u32, put_varint, ByteReader};
use anyhow::{bail, Context, Result};

/// A ready-to-load mini-batch: dense matrix + CSR sparse features +
/// labels. This layout mirrors what the PyTorch runtime hands the GPU
/// (and what our PJRT DLRM artifact consumes).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorBatch {
    pub rows: usize,
    /// Row-major `[rows, dense_names.len()]`.
    pub dense: Vec<f32>,
    pub dense_names: Vec<FeatureId>,
    /// Per sparse feature: (id, offsets `[rows+1]`, ids).
    pub sparse: Vec<(FeatureId, Vec<u32>, Vec<u64>)>,
    pub labels: Vec<f32>,
}

impl TensorBatch {
    /// Assemble from transform-DAG outputs for rows `[row_start, row_end)`.
    pub fn from_outputs(
        outputs: &[(FeatureId, Value)],
        labels: &[f32],
        row_start: usize,
        row_end: usize,
    ) -> TensorBatch {
        let rows = row_end - row_start;
        let mut dense_names = Vec::new();
        let mut dense_cols: Vec<&[f32]> = Vec::new();
        let mut sparse = Vec::new();
        for (id, v) in outputs {
            match v {
                Value::Dense(d) => {
                    dense_names.push(*id);
                    dense_cols.push(&d[row_start..row_end]);
                }
                Value::Sparse { offsets, ids, .. } => {
                    let base = offsets[row_start];
                    let o: Vec<u32> = offsets[row_start..=row_end]
                        .iter()
                        .map(|x| x - base)
                        .collect();
                    let idv = ids
                        [offsets[row_start] as usize..offsets[row_end] as usize]
                        .to_vec();
                    sparse.push((*id, o, idv));
                }
            }
        }
        // Interleave dense columns into a row-major matrix.
        let d = dense_names.len();
        let mut dense = vec![0f32; rows * d];
        for (j, col) in dense_cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                dense[i * d + j] = v;
            }
        }
        TensorBatch {
            rows,
            dense,
            dense_names,
            sparse,
            labels: labels[row_start..row_end].to_vec(),
        }
    }

    /// In-memory footprint (for buffer accounting / autoscaler).
    pub fn bytes(&self) -> usize {
        self.dense.len() * 4
            + self.labels.len() * 4
            + self
                .sparse
                .iter()
                .map(|(_, o, i)| o.len() * 4 + i.len() * 8)
                .sum::<usize>()
    }

    /// Gather arbitrary (possibly repeated) rows of the DAG outputs into
    /// a batch — the dedup-aware load stage: `rows` indexes *unique*
    /// payload rows. Labels are placeholders; the real per-row labels
    /// travel in the enclosing [`DedupTensorBatch`].
    pub fn from_outputs_gather(
        outputs: &[(FeatureId, Value)],
        rows: &[u32],
    ) -> TensorBatch {
        let k = rows.len();
        let mut dense_names = Vec::new();
        let mut dense_cols: Vec<Vec<f32>> = Vec::new();
        let mut sparse = Vec::new();
        for (id, v) in outputs {
            match v {
                Value::Dense(d) => {
                    dense_names.push(*id);
                    dense_cols
                        .push(rows.iter().map(|&u| d[u as usize]).collect());
                }
                Value::Sparse { offsets, ids, .. } => {
                    let mut o = Vec::with_capacity(k + 1);
                    o.push(0u32);
                    let mut idv = Vec::new();
                    for &u in rows {
                        let u = u as usize;
                        idv.extend_from_slice(
                            &ids[offsets[u] as usize..offsets[u + 1] as usize],
                        );
                        o.push(idv.len() as u32);
                    }
                    sparse.push((*id, o, idv));
                }
            }
        }
        let d = dense_names.len();
        let mut dense = vec![0f32; k * d];
        for (j, col) in dense_cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                dense[i * d + j] = v;
            }
        }
        TensorBatch {
            rows: k,
            dense,
            dense_names,
            sparse,
            labels: vec![0.0; k],
        }
    }

    // ---- Wire format (Thrift-compact-like: field markers + varints) ----

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.bytes() + 64);
        self.write_into(&mut out);
        out
    }

    /// Append the wire form to `out` (composable: the dedup wire frame
    /// embeds a unique-row batch after its own header).
    pub fn write_into(&self, out: &mut Vec<u8>) {
        put_varint(out, self.rows as u64);
        put_varint(out, self.dense_names.len() as u64);
        for f in &self.dense_names {
            put_u32(out, f.0);
        }
        for &v in &self.dense {
            put_f32(out, v);
        }
        put_varint(out, self.sparse.len() as u64);
        for (f, offsets, ids) in &self.sparse {
            put_u32(out, f.0);
            let mut prev = 0u32;
            for &o in &offsets[1..] {
                put_varint(out, (o - prev) as u64);
                prev = o;
            }
            put_varint(out, ids.len() as u64);
            for &id in ids {
                put_varint(out, id);
            }
        }
        for &l in &self.labels {
            put_f32(out, l);
        }
    }

    pub fn deserialize(buf: &[u8]) -> Result<TensorBatch> {
        let mut r = ByteReader::new(buf);
        Self::read_from(&mut r)
    }

    /// Decode one batch from a reader, leaving the cursor after it.
    pub fn read_from(r: &mut ByteReader) -> Result<TensorBatch> {
        let rows = r.varint().context("rows")? as usize;
        let nd = r.varint().context("nd")? as usize;
        let mut dense_names = Vec::with_capacity(nd);
        for _ in 0..nd {
            dense_names.push(FeatureId(r.u32().context("dense name")?));
        }
        let mut dense = Vec::with_capacity(rows * nd);
        for _ in 0..rows * nd {
            dense.push(r.f32().context("dense value")?);
        }
        let ns = r.varint().context("ns")? as usize;
        let mut sparse = Vec::with_capacity(ns);
        for _ in 0..ns {
            let f = FeatureId(r.u32().context("sparse name")?);
            let mut offsets = Vec::with_capacity(rows + 1);
            offsets.push(0u32);
            let mut acc = 0u32;
            for _ in 0..rows {
                acc += r.varint().context("offset")? as u32;
                offsets.push(acc);
            }
            let n = r.varint().context("n ids")? as usize;
            if n != acc as usize {
                bail!("sparse length mismatch: {n} vs {acc}");
            }
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(r.varint().context("id")?);
            }
            sparse.push((f, offsets, ids));
        }
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            labels.push(r.f32().context("label")?);
        }
        Ok(TensorBatch {
            rows,
            dense,
            dense_names,
            sparse,
            labels,
        })
    }

    /// Serialize + encrypt — the full worker→client wire cost.
    pub fn to_wire(&self, cipher: &StreamCipher, seq: u64) -> Vec<u8> {
        let mut buf = self.serialize();
        cipher.apply(seq, &mut buf);
        buf
    }

    pub fn from_wire(cipher: &StreamCipher, seq: u64, data: &[u8]) -> Result<TensorBatch> {
        let mut buf = data.to_vec();
        cipher.apply(seq, &mut buf);
        Self::deserialize(&buf)
    }
}

/// The dedup-aware wire extension (RecD): a worker that preprocessed
/// only *unique* payloads ships them once, plus the row→unique inverse
/// index and the true per-row labels. The Client [`expand`]s this back
/// into an ordinary [`TensorBatch`] before handing it to the trainer —
/// duplicate rows cost wire bytes and transform cycles exactly once.
///
/// [`expand`]: DedupTensorBatch::expand
#[derive(Clone, Debug, PartialEq)]
pub struct DedupTensorBatch {
    /// Per output row: index into `unique`'s rows.
    pub inverse: Vec<u32>,
    /// Per output row: the true label (labels are row identity, never
    /// deduplicated).
    pub labels: Vec<f32>,
    /// Preprocessed tensors over unique payload rows (placeholder
    /// labels).
    pub unique: TensorBatch,
}

impl DedupTensorBatch {
    /// Full (expanded) row count.
    pub fn rows(&self) -> usize {
        self.inverse.len()
    }

    pub fn bytes(&self) -> usize {
        self.inverse.len() * 4 + self.labels.len() * 4 + self.unique.bytes()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.bytes() + 64);
        put_varint(&mut out, self.inverse.len() as u64);
        for &u in &self.inverse {
            put_varint(&mut out, u as u64);
        }
        for &l in &self.labels {
            put_f32(&mut out, l);
        }
        self.unique.write_into(&mut out);
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<DedupTensorBatch> {
        let mut r = ByteReader::new(buf);
        let rows = r.varint().context("dedup rows")? as usize;
        let mut inverse = Vec::with_capacity(rows);
        for _ in 0..rows {
            inverse.push(r.varint().context("inverse")? as u32);
        }
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            labels.push(r.f32().context("label")?);
        }
        let unique = TensorBatch::read_from(&mut r)?;
        for &u in &inverse {
            if u as usize >= unique.rows {
                bail!(
                    "dedup inverse {u} out of range ({} uniques)",
                    unique.rows
                );
            }
        }
        Ok(DedupTensorBatch {
            inverse,
            labels,
            unique,
        })
    }

    /// Reconstruct the full batch: gather unique rows through the
    /// inverse index and restore per-row labels.
    pub fn expand(&self) -> TensorBatch {
        let rows = self.inverse.len();
        let u = &self.unique;
        let d = u.dense_names.len();
        let mut dense = vec![0f32; rows * d];
        for (i, &src) in self.inverse.iter().enumerate() {
            let src = src as usize;
            dense[i * d..(i + 1) * d]
                .copy_from_slice(&u.dense[src * d..(src + 1) * d]);
        }
        let sparse = u
            .sparse
            .iter()
            .map(|(id, offsets, ids)| {
                let mut o = Vec::with_capacity(rows + 1);
                o.push(0u32);
                let mut idv = Vec::new();
                for &src in &self.inverse {
                    let src = src as usize;
                    idv.extend_from_slice(
                        &ids[offsets[src] as usize..offsets[src + 1] as usize],
                    );
                    o.push(idv.len() as u32);
                }
                (*id, o, idv)
            })
            .collect();
        TensorBatch {
            rows,
            dense,
            dense_names: u.dense_names.clone(),
            sparse,
            labels: self.labels.clone(),
        }
    }

    /// Serialize + encrypt (same datacenter-tax path as the plain wire).
    pub fn to_wire(&self, cipher: &StreamCipher, seq: u64) -> Vec<u8> {
        let mut buf = self.serialize();
        cipher.apply(seq, &mut buf);
        buf
    }

    pub fn from_wire(
        cipher: &StreamCipher,
        seq: u64,
        data: &[u8],
    ) -> Result<DedupTensorBatch> {
        let mut buf = data.to_vec();
        cipher.apply(seq, &mut buf);
        Self::deserialize(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> TensorBatch {
        let outputs = vec![
            (FeatureId(1), Value::Dense(vec![1.0, 2.0, 3.0, 4.0])),
            (FeatureId(2), Value::Dense(vec![-1.0, -2.0, -3.0, -4.0])),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets: vec![0, 2, 2, 5, 6],
                    ids: vec![7, 8, 1, 2, 3, 9],
                    scores: None,
                },
            ),
        ];
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        TensorBatch::from_outputs(&outputs, &labels, 0, 4)
    }

    #[test]
    fn from_outputs_interleaves_dense() {
        let b = batch();
        assert_eq!(b.rows, 4);
        assert_eq!(b.dense_names.len(), 2);
        // Row-major [4,2]: row 0 = [1, -1].
        assert_eq!(&b.dense[..2], &[1.0, -1.0]);
        assert_eq!(&b.dense[6..], &[4.0, -4.0]);
        assert_eq!(b.sparse[0].1, vec![0, 2, 2, 5, 6]);
    }

    #[test]
    fn from_outputs_slices_rows() {
        let outputs = vec![
            (FeatureId(1), Value::Dense(vec![1.0, 2.0, 3.0, 4.0])),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets: vec![0, 2, 2, 5, 6],
                    ids: vec![7, 8, 1, 2, 3, 9],
                    scores: None,
                },
            ),
        ];
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        let b = TensorBatch::from_outputs(&outputs, &labels, 2, 4);
        assert_eq!(b.rows, 2);
        assert_eq!(b.dense, vec![3.0, 4.0]);
        assert_eq!(b.sparse[0].1, vec![0, 3, 4]); // rebased offsets
        assert_eq!(b.sparse[0].2, vec![1, 2, 3, 9]);
        assert_eq!(b.labels, vec![1.0, 0.0]);
    }

    #[test]
    fn wire_roundtrip() {
        let b = batch();
        let buf = b.serialize();
        let back = TensorBatch::deserialize(&buf).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn encrypted_wire_roundtrip() {
        let b = batch();
        let cipher = StreamCipher::for_table("session-1");
        let wire = b.to_wire(&cipher, 42);
        assert_ne!(wire, b.serialize());
        let back = TensorBatch::from_wire(&cipher, 42, &wire).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn wrong_seq_fails_or_garbles() {
        let b = batch();
        let cipher = StreamCipher::for_table("s");
        let wire = b.to_wire(&cipher, 1);
        match TensorBatch::from_wire(&cipher, 2, &wire) {
            Err(_) => {}
            Ok(garbled) => assert_ne!(garbled, b),
        }
    }

    #[test]
    fn truncated_wire_errors() {
        let b = batch();
        let buf = b.serialize();
        assert!(TensorBatch::deserialize(&buf[..buf.len() / 2]).is_err());
    }

    #[test]
    fn bytes_accounting_positive() {
        let b = batch();
        assert!(b.bytes() > 0);
        assert!(b.bytes() >= b.dense.len() * 4);
    }

    fn outputs() -> Vec<(FeatureId, Value)> {
        vec![
            (FeatureId(1), Value::Dense(vec![1.0, 2.0, 3.0, 4.0])),
            (FeatureId(2), Value::Dense(vec![-1.0, -2.0, -3.0, -4.0])),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets: vec![0, 2, 2, 5, 6],
                    ids: vec![7, 8, 1, 2, 3, 9],
                    scores: None,
                },
            ),
        ]
    }

    #[test]
    fn gather_identity_matches_from_outputs() {
        let outs = outputs();
        let labels = vec![0.0f32; 4];
        let direct = TensorBatch::from_outputs(&outs, &labels, 0, 4);
        let gathered =
            TensorBatch::from_outputs_gather(&outs, &[0, 1, 2, 3]);
        assert_eq!(gathered, direct);
    }

    /// Expand a Value column by an inverse index (test oracle).
    fn expand_value(v: &Value, inv: &[u32]) -> Value {
        match v {
            Value::Dense(d) => Value::Dense(
                inv.iter().map(|&u| d[u as usize]).collect(),
            ),
            Value::Sparse { offsets, ids, .. } => {
                let mut o = vec![0u32];
                let mut out_ids = Vec::new();
                for &u in inv {
                    let u = u as usize;
                    out_ids.extend_from_slice(
                        &ids[offsets[u] as usize..offsets[u + 1] as usize],
                    );
                    o.push(out_ids.len() as u32);
                }
                Value::Sparse {
                    offsets: o,
                    ids: out_ids,
                    scores: None,
                }
            }
        }
    }

    #[test]
    fn dedup_batch_expand_equals_duplication_oblivious_path() {
        let outs = outputs();
        let inverse = vec![2u32, 0, 2, 3, 1, 1, 0];
        let labels: Vec<f32> =
            (0..inverse.len()).map(|i| (i % 2) as f32).collect();
        // Dedup path: gather uniques actually referenced, ship inverse.
        let uniques = vec![0u32, 1, 2, 3];
        let db = DedupTensorBatch {
            inverse: inverse.clone(),
            labels: labels.clone(),
            unique: TensorBatch::from_outputs_gather(&outs, &uniques),
        };
        let expanded = db.expand();
        // Oracle: expand the raw outputs first, batch second.
        let full: Vec<(FeatureId, Value)> = outs
            .iter()
            .map(|(id, v)| (*id, expand_value(v, &inverse)))
            .collect();
        let direct =
            TensorBatch::from_outputs(&full, &labels, 0, inverse.len());
        assert_eq!(expanded, direct);
    }

    #[test]
    fn dedup_batch_wire_roundtrip() {
        let outs = outputs();
        let db = DedupTensorBatch {
            inverse: vec![1, 1, 0, 3, 2, 0],
            labels: vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0],
            unique: TensorBatch::from_outputs_gather(&outs, &[0, 1, 2, 3]),
        };
        let back = DedupTensorBatch::deserialize(&db.serialize()).unwrap();
        assert_eq!(back, db);
        let cipher = StreamCipher::for_table("dedup");
        let wire = db.to_wire(&cipher, 9);
        assert_ne!(wire, db.serialize());
        let back = DedupTensorBatch::from_wire(&cipher, 9, &wire).unwrap();
        assert_eq!(back, db);
        assert_eq!(back.rows(), 6);
        assert_eq!(back.expand().labels, db.labels);
    }

    #[test]
    fn dedup_batch_rejects_out_of_range_inverse() {
        let outs = outputs();
        let db = DedupTensorBatch {
            inverse: vec![0, 9],
            labels: vec![0.0, 1.0],
            unique: TensorBatch::from_outputs_gather(&outs, &[0, 1]),
        };
        assert!(DedupTensorBatch::deserialize(&db.serialize()).is_err());
    }

    #[test]
    fn dedup_wire_is_smaller_than_expanded_wire() {
        let outs = outputs();
        // Heavy duplication: 32 rows over 4 uniques.
        let inverse: Vec<u32> = (0..32).map(|i| i % 4).collect();
        let labels = vec![0.0f32; 32];
        let db = DedupTensorBatch {
            inverse: inverse.clone(),
            labels,
            unique: TensorBatch::from_outputs_gather(&outs, &[0, 1, 2, 3]),
        };
        assert!(db.serialize().len() < db.expand().serialize().len());
    }
}
