//! Preprocessed tensor batches and their wire format.
//!
//! Workers batch transformed features into tensors (§3.2.1) and serve
//! them to Clients over RPC. The wire path models the paper's
//! "datacenter tax" (§6.2): a Thrift-like compact binary serialization
//! plus TLS-style encryption — both real byte passes whose CPU/memory
//! cost shows up in the Fig 8 loading experiment.

use crate::dwrf::crypto::StreamCipher;
use crate::schema::FeatureId;
use crate::transforms::Value;
use crate::util::bytes::{put_f32, put_u32, put_varint, ByteReader};
use anyhow::{bail, Context, Result};

/// A ready-to-load mini-batch: dense matrix + CSR sparse features +
/// labels. This layout mirrors what the PyTorch runtime hands the GPU
/// (and what our PJRT DLRM artifact consumes).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorBatch {
    pub rows: usize,
    /// Row-major `[rows, dense_names.len()]`.
    pub dense: Vec<f32>,
    pub dense_names: Vec<FeatureId>,
    /// Per sparse feature: (id, offsets `[rows+1]`, ids).
    pub sparse: Vec<(FeatureId, Vec<u32>, Vec<u64>)>,
    pub labels: Vec<f32>,
}

impl TensorBatch {
    /// Assemble from transform-DAG outputs for rows `[row_start, row_end)`.
    pub fn from_outputs(
        outputs: &[(FeatureId, Value)],
        labels: &[f32],
        row_start: usize,
        row_end: usize,
    ) -> TensorBatch {
        let rows = row_end - row_start;
        let mut dense_names = Vec::new();
        let mut dense_cols: Vec<&[f32]> = Vec::new();
        let mut sparse = Vec::new();
        for (id, v) in outputs {
            match v {
                Value::Dense(d) => {
                    dense_names.push(*id);
                    dense_cols.push(&d[row_start..row_end]);
                }
                Value::Sparse { offsets, ids, .. } => {
                    let base = offsets[row_start];
                    let o: Vec<u32> = offsets[row_start..=row_end]
                        .iter()
                        .map(|x| x - base)
                        .collect();
                    let idv = ids
                        [offsets[row_start] as usize..offsets[row_end] as usize]
                        .to_vec();
                    sparse.push((*id, o, idv));
                }
            }
        }
        // Interleave dense columns into a row-major matrix.
        let d = dense_names.len();
        let mut dense = vec![0f32; rows * d];
        for (j, col) in dense_cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                dense[i * d + j] = v;
            }
        }
        TensorBatch {
            rows,
            dense,
            dense_names,
            sparse,
            labels: labels[row_start..row_end].to_vec(),
        }
    }

    /// In-memory footprint (for buffer accounting / autoscaler).
    pub fn bytes(&self) -> usize {
        self.dense.len() * 4
            + self.labels.len() * 4
            + self
                .sparse
                .iter()
                .map(|(_, o, i)| o.len() * 4 + i.len() * 8)
                .sum::<usize>()
    }

    // ---- Wire format (Thrift-compact-like: field markers + varints) ----

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.bytes() + 64);
        put_varint(&mut out, self.rows as u64);
        put_varint(&mut out, self.dense_names.len() as u64);
        for f in &self.dense_names {
            put_u32(&mut out, f.0);
        }
        for &v in &self.dense {
            put_f32(&mut out, v);
        }
        put_varint(&mut out, self.sparse.len() as u64);
        for (f, offsets, ids) in &self.sparse {
            put_u32(&mut out, f.0);
            let mut prev = 0u32;
            for &o in &offsets[1..] {
                put_varint(&mut out, (o - prev) as u64);
                prev = o;
            }
            put_varint(&mut out, ids.len() as u64);
            for &id in ids {
                put_varint(&mut out, id);
            }
        }
        for &l in &self.labels {
            put_f32(&mut out, l);
        }
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<TensorBatch> {
        let mut r = ByteReader::new(buf);
        let rows = r.varint().context("rows")? as usize;
        let nd = r.varint().context("nd")? as usize;
        let mut dense_names = Vec::with_capacity(nd);
        for _ in 0..nd {
            dense_names.push(FeatureId(r.u32().context("dense name")?));
        }
        let mut dense = Vec::with_capacity(rows * nd);
        for _ in 0..rows * nd {
            dense.push(r.f32().context("dense value")?);
        }
        let ns = r.varint().context("ns")? as usize;
        let mut sparse = Vec::with_capacity(ns);
        for _ in 0..ns {
            let f = FeatureId(r.u32().context("sparse name")?);
            let mut offsets = Vec::with_capacity(rows + 1);
            offsets.push(0u32);
            let mut acc = 0u32;
            for _ in 0..rows {
                acc += r.varint().context("offset")? as u32;
                offsets.push(acc);
            }
            let n = r.varint().context("n ids")? as usize;
            if n != acc as usize {
                bail!("sparse length mismatch: {n} vs {acc}");
            }
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(r.varint().context("id")?);
            }
            sparse.push((f, offsets, ids));
        }
        let mut labels = Vec::with_capacity(rows);
        for _ in 0..rows {
            labels.push(r.f32().context("label")?);
        }
        Ok(TensorBatch {
            rows,
            dense,
            dense_names,
            sparse,
            labels,
        })
    }

    /// Serialize + encrypt — the full worker→client wire cost.
    pub fn to_wire(&self, cipher: &StreamCipher, seq: u64) -> Vec<u8> {
        let mut buf = self.serialize();
        cipher.apply(seq, &mut buf);
        buf
    }

    pub fn from_wire(cipher: &StreamCipher, seq: u64, data: &[u8]) -> Result<TensorBatch> {
        let mut buf = data.to_vec();
        cipher.apply(seq, &mut buf);
        Self::deserialize(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> TensorBatch {
        let outputs = vec![
            (FeatureId(1), Value::Dense(vec![1.0, 2.0, 3.0, 4.0])),
            (FeatureId(2), Value::Dense(vec![-1.0, -2.0, -3.0, -4.0])),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets: vec![0, 2, 2, 5, 6],
                    ids: vec![7, 8, 1, 2, 3, 9],
                    scores: None,
                },
            ),
        ];
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        TensorBatch::from_outputs(&outputs, &labels, 0, 4)
    }

    #[test]
    fn from_outputs_interleaves_dense() {
        let b = batch();
        assert_eq!(b.rows, 4);
        assert_eq!(b.dense_names.len(), 2);
        // Row-major [4,2]: row 0 = [1, -1].
        assert_eq!(&b.dense[..2], &[1.0, -1.0]);
        assert_eq!(&b.dense[6..], &[4.0, -4.0]);
        assert_eq!(b.sparse[0].1, vec![0, 2, 2, 5, 6]);
    }

    #[test]
    fn from_outputs_slices_rows() {
        let outputs = vec![
            (FeatureId(1), Value::Dense(vec![1.0, 2.0, 3.0, 4.0])),
            (
                FeatureId(10),
                Value::Sparse {
                    offsets: vec![0, 2, 2, 5, 6],
                    ids: vec![7, 8, 1, 2, 3, 9],
                    scores: None,
                },
            ),
        ];
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        let b = TensorBatch::from_outputs(&outputs, &labels, 2, 4);
        assert_eq!(b.rows, 2);
        assert_eq!(b.dense, vec![3.0, 4.0]);
        assert_eq!(b.sparse[0].1, vec![0, 3, 4]); // rebased offsets
        assert_eq!(b.sparse[0].2, vec![1, 2, 3, 9]);
        assert_eq!(b.labels, vec![1.0, 0.0]);
    }

    #[test]
    fn wire_roundtrip() {
        let b = batch();
        let buf = b.serialize();
        let back = TensorBatch::deserialize(&buf).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn encrypted_wire_roundtrip() {
        let b = batch();
        let cipher = StreamCipher::for_table("session-1");
        let wire = b.to_wire(&cipher, 42);
        assert_ne!(wire, b.serialize());
        let back = TensorBatch::from_wire(&cipher, 42, &wire).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn wrong_seq_fails_or_garbles() {
        let b = batch();
        let cipher = StreamCipher::for_table("s");
        let wire = b.to_wire(&cipher, 1);
        match TensorBatch::from_wire(&cipher, 2, &wire) {
            Err(_) => {}
            Ok(garbled) => assert_ne!(garbled, b),
        }
    }

    #[test]
    fn truncated_wire_errors() {
        let b = batch();
        let buf = b.serialize();
        assert!(TensorBatch::deserialize(&buf[..buf.len() / 2]).is_err());
    }

    #[test]
    fn bytes_accounting_positive() {
        let b = batch();
        assert!(b.bytes() > 0);
        assert!(b.bytes() >= b.dense.len() * 4);
    }
}
