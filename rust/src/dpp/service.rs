//! Session orchestration: wires a Master, a scalable pool of Workers, and
//! the trainer-side Clients into a running DPP session, with the
//! auto-scaling loop and fault injection used by the experiments.

use super::client::{partition_round_robin, Client};
use super::master::{Master, ScaleSignals};
use super::spec::SessionSpec;
use super::worker::{WireBatch, Worker};
use crate::metrics::{EtlMetrics, StageClock};
use crate::obs::{
    Obs, ObsHandle, SessionTelemetry, StallAttribution, StallAttributor,
    StallSnapshot, TelemetrySample,
};
use crate::tectonic::Cluster;
use crate::warehouse::Catalog;
use anyhow::Result;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Trace lane base for clients (workers use their pool ids, which stay
/// far below this).
const CLIENT_TID_BASE: u32 = 1000;

/// Session runtime knobs.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub initial_workers: usize,
    pub max_workers: usize,
    pub clients: usize,
    /// Bounded tensor buffer per worker (batches).
    pub buffer_per_worker: usize,
    /// Run the Master's auto-scaling controller at this cadence.
    pub autoscale_every: Option<Duration>,
    /// Trainer demand pacing: max rows/s each client consumes
    /// (`None` = consume as fast as possible).
    pub client_rows_per_sec: Option<f64>,
    /// Fault injection: kill one worker after this many batches have been
    /// delivered (session must still complete).
    pub kill_worker_after_batches: Option<u64>,
    /// Observability sink to record into. `None` + `pipeline.tracing`
    /// on ⇒ the session allocates a private one (returned in the
    /// report); supplying a shared sink puts several concurrent
    /// sessions on one trace timeline.
    pub obs: Option<Arc<Obs>>,
    /// Sample [`SessionTelemetry`] at this cadence (`None` = off).
    pub telemetry_every: Option<Duration>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            initial_workers: 2,
            max_workers: 8,
            clients: 1,
            buffer_per_worker: 16,
            autoscale_every: None,
            client_rows_per_sec: None,
            kill_worker_after_batches: None,
            obs: None,
            telemetry_every: None,
        }
    }
}

/// What a finished session reports (feeds Tables 7/9 and Fig 8/9).
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub rows_delivered: u64,
    pub batches_delivered: u64,
    pub wall_secs: f64,
    pub rows_per_sec: f64,
    /// Total client wire bytes (loading throughput).
    pub client_rx_bytes: u64,
    /// Pre-compression size of those wire bytes — what the clients would
    /// have pulled with `wire_compression: Off`.
    pub client_raw_rx_bytes: u64,
    /// Seconds clients spent in the wire codec (decrypt + decompress +
    /// tensor rebuild) — the trainer-side cost of transport compression.
    pub client_decode_secs: f64,
    /// Seconds clients spent stalled waiting on tensors.
    pub client_stall_secs: f64,
    pub peak_workers: usize,
    /// ∫ pool-size dt over the session (live + still-draining workers)
    /// — the provisioning cost the autoscaler minimizes. A fixed pool
    /// pays `workers × wall_secs`.
    pub worker_pool_secs: f64,
    /// Scale-down retirements the control loop executed.
    pub workers_retired: u64,
    /// Splits the reaper requeued during the session — a retirement
    /// that lost its lease (it must not) would show up here.
    pub splits_requeued: u64,
    /// Live workers when the last split settled.
    pub final_workers: usize,
    /// This session's broker-buffer hit rate (0.0 without a broker).
    pub broker_hit_rate: f64,
    /// Merged worker pipeline metrics snapshot.
    pub storage_rx_bytes: u64,
    pub tensor_tx_bytes: u64,
    /// Pre-compression size of the workers' tensor output (matches
    /// `tensor_tx_bytes` exactly when compression is off).
    pub wire_raw_bytes: u64,
    /// Worker-side seconds inside the wire codec (subset of busy time).
    pub worker_compress_secs: f64,
    pub worker_busy_secs: f64,
    /// Wall-clock delivery rate (rows / wall second) — worker-pool
    /// parallelism included, unlike the per-busy-second efficiency in
    /// [`EtlMetrics::rows_per_busy_sec`].
    pub worker_qps: f64,
    /// Storage-device accounting for the session's reads.
    pub storage_device_secs: f64,
    pub storage_reads: u64,
    pub storage_seeks: u64,
    pub storage_bytes_read: u64,
    /// Where `client_stall_secs` went (buckets sum to it).
    pub stall_attribution: StallAttribution,
    /// Sampled time-series (present iff `telemetry_every` was set).
    pub telemetry: Option<SessionTelemetry>,
    /// The observability sink this session recorded into (present iff
    /// traced) — export via [`Obs::chrome_trace`] /
    /// [`Obs::histograms_json`].
    pub obs: Option<Arc<Obs>>,
}

impl SessionReport {
    /// Effective storage throughput: useful bytes fetched per device-sec.
    pub fn storage_mbps(&self) -> f64 {
        if self.storage_device_secs == 0.0 {
            0.0
        } else {
            self.storage_bytes_read as f64 / 1e6 / self.storage_device_secs
        }
    }

    /// Wire compression ratio achieved this session (1.0 when off or
    /// when nothing shipped).
    pub fn wire_compression_ratio(&self) -> f64 {
        if self.tensor_tx_bytes == 0 {
            1.0
        } else {
            self.wire_raw_bytes as f64 / self.tensor_tx_bytes as f64
        }
    }
}

/// Run a DPP session to completion.
pub fn run_session(
    catalog: &Catalog,
    cluster: &Arc<Cluster>,
    spec: SessionSpec,
    cfg: &SessionConfig,
) -> Result<SessionReport> {
    let master = Arc::new(Master::new(catalog, cluster, spec)?);
    run_session_on(master, cluster, cfg)
}

/// [`run_session`] on a pre-built Master — the entry point for sessions
/// attached to a [`crate::broker::ReadBroker`] via
/// [`Master::new_shared`], or with a customized
/// [`crate::dpp::AutoscalePolicy`].
pub fn run_session_on(
    master: Arc<Master>,
    cluster: &Arc<Cluster>,
    cfg: &SessionConfig,
) -> Result<SessionReport> {
    assert!(cfg.initial_workers >= 1);
    assert!(cfg.max_workers >= cfg.initial_workers);
    let spec = Arc::new(master.spec.clone());
    let metrics = Arc::new(EtlMetrics::default());
    cluster.reset_stats();

    // Observability: a caller-supplied sink (shared trace timeline
    // across sessions) or a private one when the spec asks for tracing.
    let obs = cfg.obs.clone().or_else(|| {
        if spec.pipeline.tracing {
            Some(Obs::new())
        } else {
            None
        }
    });
    let oh = obs
        .as_ref()
        .map(|o| ObsHandle::for_session(o.clone(), &spec.table));
    if let Some(h) = &oh {
        master.attach_obs(h.clone());
        if let Some(bh) = master.broker_handle() {
            bh.broker.attach_obs(h.clone());
        }
    }

    // One channel per pool slot, created up front so clients' connection
    // sets are fixed while workers scale dynamically. The loop keeps a
    // sender clone per slot, so a slot whose worker retired can host a
    // later spawn on the same still-open channel.
    let mut txs: Vec<SyncSender<WireBatch>> = Vec::new();
    let mut rxs = Vec::new();
    for _ in 0..cfg.max_workers {
        let (tx, rx) = sync_channel(cfg.buffer_per_worker);
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let parts = partition_round_robin(cfg.max_workers, cfg.clients);

    // Spawn clients. The loop keeps each client's stall clock so stall
    // attribution and the autoscaler read stalls live, mid-drain.
    let table = spec.table.clone();
    let mut client_handles = Vec::new();
    let mut stall_clocks: Vec<Arc<StageClock>> = Vec::new();
    for (ci, part) in parts.into_iter().enumerate() {
        let client_rxs: Vec<_> =
            part.iter().map(|&w| rxs[w].take().unwrap()).collect();
        let table = table.clone();
        let pipeline = spec.pipeline.clone();
        let pace = cfg.client_rows_per_sec;
        let drained = metrics.clone();
        let stall = Arc::new(StageClock::default());
        stall_clocks.push(stall.clone());
        let c_obs = oh.clone();
        client_handles.push(std::thread::spawn(move || {
            let mut client = Client::new(&table, client_rxs)
                .with_wire(&pipeline)
                .with_stall_clock(stall);
            if let Some(h) = c_obs {
                client = client.with_obs(h, CLIENT_TID_BASE + ci as u32);
            }
            let mut rows = 0u64;
            let mut batches = 0u64;
            let start = Instant::now();
            while let Ok(Some(tb)) = client.next_batch(Duration::from_secs(30))
            {
                rows += tb.rows as u64;
                batches += 1;
                // Demand signal for the autoscaler's throughput model.
                drained.drained_rows.add(tb.rows as u64);
                if let Some(rate) = pace {
                    // Trainer demand model: don't consume faster than the
                    // GPUs would.
                    let target = rows as f64 / rate;
                    let elapsed = start.elapsed().as_secs_f64();
                    if target > elapsed {
                        std::thread::sleep(Duration::from_secs_f64(
                            target - elapsed,
                        ));
                    }
                }
            }
            (
                rows,
                batches,
                client.rx_bytes.get(),
                client.raw_rx_bytes.get(),
                client.decode_clock.secs(),
                client.stalled(),
            )
        }));
    }

    // Spawn initial workers. `workers` holds the live pool as
    // (worker, slot); `draining` holds retired or killed workers until
    // their threads exit (a retiring worker still drains its lease).
    let start = Instant::now();
    let mut free_slots: Vec<usize> = (0..cfg.max_workers).rev().collect();
    let mut workers: Vec<(Worker, usize)> = Vec::new();
    let mut draining: Vec<(Worker, usize)> = Vec::new();
    for _ in 0..cfg.initial_workers {
        let slot = free_slots.pop().expect("initial <= max");
        workers.push((
            Worker::spawn(
                master.clone(),
                cluster.clone(),
                spec.clone(),
                metrics.clone(),
                txs[slot].clone(),
            ),
            slot,
        ));
    }
    let mut peak_workers = workers.len();
    let mut killed = false;
    let mut workers_retired = 0u64;
    let mut splits_requeued = 0u64;
    let mut worker_pool_secs = 0.0f64;
    let mut last_tick = start;
    let mut last_scale = start;
    let mut attributor = StallAttributor::default();
    let mut telemetry = cfg.telemetry_every.map(|_| SessionTelemetry::new());
    let mut last_telemetry = start;
    let stall_snapshot = |stall_now: f64, live: usize| StallSnapshot {
        t_secs: start.elapsed().as_secs_f64(),
        stall_secs: stall_now,
        read_secs: metrics.t_read.secs(),
        decode_secs: metrics.t_extract.secs(),
        transform_secs: metrics.t_transform.secs() + metrics.t_load.secs(),
        live_workers: live,
    };

    // Control loop: autoscale (both directions) + fault injection +
    // stall attribution + telemetry + completion watch.
    loop {
        if master.is_done() {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
        let now = Instant::now();
        worker_pool_secs += (workers.len() + draining.len()) as f64
            * now.duration_since(last_tick).as_secs_f64();
        last_tick = now;
        splits_requeued +=
            master.reap_expired(Duration::from_secs(5)) as u64;
        // Attribute this tick's fresh client-stall time to whatever the
        // worker pool was concurrently doing (or failing to do).
        let stall_now: f64 = stall_clocks.iter().map(|c| c.secs()).sum();
        attributor.observe(stall_snapshot(stall_now, workers.len()));
        if let (Some(tel), Some(every)) =
            (telemetry.as_mut(), cfg.telemetry_every)
        {
            if now.duration_since(last_telemetry) >= every {
                last_telemetry = now;
                let (live, avg_buf) = master.pool_snapshot();
                tel.observe(TelemetrySample {
                    t_secs: start.elapsed().as_secs_f64(),
                    live_workers: live,
                    avg_buffered: avg_buf,
                    broker_hit_rate: master.broker_hit_rate(),
                    broker_mem_bytes: master.broker_mem_bytes(),
                    // The session loop owns no tensor cache; sessions
                    // running under a cache-sharing driver overwrite
                    // this gauge there.
                    cache_bytes: 0,
                    drained_rows: metrics.drained_rows.get(),
                    stall_secs: stall_now,
                });
            }
        }
        // Collect threads that exited on their own (crash, disconnect,
        // finished drain): their slots return to the free pool.
        for pool in [&mut workers, &mut draining] {
            let mut i = 0;
            while i < pool.len() {
                if pool[i].0.is_finished() {
                    let (w, slot) = pool.remove(i);
                    w.join();
                    free_slots.push(slot);
                } else {
                    i += 1;
                }
            }
        }
        if let Some(n) = cfg.kill_worker_after_batches {
            if !killed && metrics.batches.get() >= n && workers.len() > 1 {
                // Fault injection: the killed worker leaves the live
                // pool immediately — the controller must not count it.
                let (w, slot) = workers.remove(0);
                w.kill();
                master.worker_failed(w.id);
                draining.push((w, slot));
                killed = true;
            }
        }
        if let Some(every) = cfg.autoscale_every {
            if now.duration_since(last_scale) >= every {
                last_scale = now;
                let sig = ScaleSignals {
                    wall_secs: start.elapsed().as_secs_f64(),
                    drained_rows: metrics.drained_rows.get(),
                    produced_rows: metrics.samples.get(),
                    decoded_rows: metrics.decoded_rows.get(),
                    filtered_rows: metrics.filtered_rows.get(),
                    busy_secs: metrics.total_secs(),
                    fetch_decode_secs: metrics.fetch_decode_secs(),
                    stall_secs: stall_now,
                    stall_starved_secs: attributor.so_far().starved_secs,
                };
                let desired =
                    master.autoscale(&sig).desired.min(cfg.max_workers);
                while workers.len() < desired {
                    let Some(slot) = free_slots.pop() else { break };
                    workers.push((
                        Worker::spawn(
                            master.clone(),
                            cluster.clone(),
                            spec.clone(),
                            metrics.clone(),
                            txs[slot].clone(),
                        ),
                        slot,
                    ));
                }
                while workers.len() > desired {
                    // Scale-down executes: retire the most recently
                    // spawned worker — it stops leasing new splits,
                    // drains its current one, and exits (joined by the
                    // sweep above once finished).
                    let (w, slot) = workers.pop().expect("len > desired");
                    if master.retire_worker(w.id) {
                        workers_retired += 1;
                    } else {
                        // The master presumes it dead (reaped mid-split,
                        // its work already requeued) so it can't drain
                        // gracefully — stop it outright, or a later
                        // heartbeat would revive an untracked worker
                        // that keeps leasing splits.
                        w.kill();
                    }
                    draining.push((w, slot));
                }
                peak_workers = peak_workers.max(workers.len());
            }
        }
    }
    let final_workers = workers.len();
    let broker_hit_rate = master.broker_hit_rate();

    // Drain: drop the loop's sender clones so clients observe
    // end-of-stream once workers exit, then join workers (dropping
    // their senders).
    drop(txs);
    for (w, _) in workers.into_iter().chain(draining) {
        w.join();
    }
    let mut rows = 0u64;
    let mut batches = 0u64;
    let mut rx_bytes = 0u64;
    let mut raw_rx_bytes = 0u64;
    let mut decode_secs = 0.0f64;
    let mut stalls = 0.0f64;
    for h in client_handles {
        let (r, b, bytes, raw, dec, stall) =
            h.join().expect("client thread");
        rows += r;
        batches += b;
        rx_bytes += bytes;
        raw_rx_bytes += raw;
        decode_secs += dec;
        stalls += stall;
    }
    let wall = start.elapsed().as_secs_f64();
    // Final attribution interval (covers stall accrued since the last
    // control-loop tick, with the pool now gone), then rescale so the
    // buckets sum exactly to the clients' measured stall time.
    let final_stall: f64 = stall_clocks.iter().map(|c| c.secs()).sum();
    attributor.observe(stall_snapshot(final_stall, 0));
    let stall_attribution = attributor.finish(stalls);
    let st = cluster.stats();
    Ok(SessionReport {
        rows_delivered: rows,
        batches_delivered: batches,
        wall_secs: wall,
        rows_per_sec: rows as f64 / wall.max(1e-9),
        client_rx_bytes: rx_bytes,
        client_raw_rx_bytes: raw_rx_bytes,
        client_decode_secs: decode_secs,
        client_stall_secs: stalls,
        peak_workers,
        worker_pool_secs,
        workers_retired,
        splits_requeued,
        final_workers,
        broker_hit_rate,
        storage_rx_bytes: metrics.storage_rx_bytes.get(),
        tensor_tx_bytes: metrics.tensor_tx_bytes.get(),
        wire_raw_bytes: metrics.wire_raw_bytes.get(),
        worker_compress_secs: metrics.t_compress.secs(),
        worker_busy_secs: metrics.total_secs(),
        worker_qps: metrics.qps_wall(wall),
        storage_device_secs: st.device_secs,
        storage_reads: st.reads,
        storage_seeks: st.seeks,
        storage_bytes_read: st.bytes_read,
        stall_attribution,
        telemetry,
        obs,
    })
}

/// A full standard session over an RM-shaped dataset (shared by tests,
/// benches, and the paper drivers).
pub struct Session;

impl Session {
    pub fn run(
        catalog: &Catalog,
        cluster: &Arc<Cluster>,
        spec: SessionSpec,
        cfg: &SessionConfig,
    ) -> Result<SessionReport> {
        run_session(catalog, cluster, spec, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dwrf::WriterOptions;
    use crate::schema::FeatureKind;
    use crate::tectonic::ClusterConfig;
    use crate::transforms::{Op, TransformDag};

    fn setup() -> (Arc<Cluster>, Catalog, SessionSpec) {
        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        }));
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            21,
        )
        .unwrap();
        let dense = h
            .schema
            .features
            .iter()
            .find(|f| matches!(f.kind, FeatureKind::Dense))
            .unwrap()
            .id;
        let sparse = h
            .schema
            .features
            .iter()
            .find(|f| !matches!(f.kind, FeatureKind::Dense))
            .unwrap()
            .id;
        let mut dag = TransformDag::default();
        let d = dag.input_dense(dense);
        let l = dag.apply(Op::Logit { eps: 1e-3 }, vec![d]);
        dag.output(dense, l);
        let s = dag.input_sparse(sparse);
        let hh = dag.apply(
            Op::SigridHash {
                salt: 3,
                modulus: 4096,
            },
            vec![s],
        );
        dag.output(sparse, hh);
        let spec = SessionSpec::from_dag(&h.table_name, 0, 10, dag, 16);
        (cluster, catalog, spec)
    }

    #[test]
    fn session_delivers_every_row_once() {
        let (cluster, catalog, spec) = setup();
        let report = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                initial_workers: 2,
                max_workers: 4,
                clients: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.rows_delivered, 128);
        assert!(report.batches_delivered >= 8);
        assert!(report.rows_per_sec > 0.0);
        assert!(report.client_rx_bytes > 0);
        assert!(report.storage_bytes_read > 0);
    }

    #[test]
    fn session_survives_worker_failure() {
        let (cluster, catalog, spec) = setup();
        let report = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                initial_workers: 2,
                max_workers: 4,
                clients: 1,
                kill_worker_after_batches: Some(1),
                ..Default::default()
            },
        )
        .unwrap();
        // All rows still delivered (the killed worker's split re-runs; it
        // may double-deliver a split's already-buffered batches, so >=).
        assert!(report.rows_delivered >= 128, "{}", report.rows_delivered);
    }

    #[test]
    fn autoscaler_spawns_more_workers_under_demand() {
        let (cluster, catalog, spec) = setup();
        let report = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                initial_workers: 1,
                max_workers: 4,
                clients: 1,
                buffer_per_worker: 1,
                autoscale_every: Some(Duration::from_millis(1)),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(report.peak_workers >= 1);
        assert_eq!(report.rows_delivered, 128);
    }

    #[test]
    fn control_loop_retires_overprovisioned_workers() {
        // Regression: the old loop only grew the pool
        // (`while workers.len() < desired`), so an over-provisioned
        // session never released workers. A slow paced trainer against
        // six workers must now shrink the live pool, with every retired
        // lease drained (no rows lost) and no reaper requeues.
        let (cluster, catalog, spec) = setup();
        let report = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                initial_workers: 6,
                max_workers: 6,
                clients: 1,
                buffer_per_worker: 1,
                autoscale_every: Some(Duration::from_millis(1)),
                client_rows_per_sec: Some(200.0),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            report.rows_delivered, 128,
            "retired leases drain — no rows lost"
        );
        assert!(
            report.workers_retired >= 1,
            "scale-down must actually execute: {report:?}"
        );
        assert!(
            report.final_workers < 6,
            "live pool shrinks: {}",
            report.final_workers
        );
        assert_eq!(
            report.splits_requeued, 0,
            "retirement must not look like worker death to the reaper"
        );
        assert!(
            report.worker_pool_secs < 6.0 * report.wall_secs,
            "pool cost under a fixed six-worker pool: {:.3} vs {:.3}",
            report.worker_pool_secs,
            6.0 * report.wall_secs
        );
    }

    #[test]
    fn traced_session_attributes_stalls_and_exports_spans() {
        let (cluster, catalog, mut spec) = setup();
        spec.pipeline.tracing = true;
        let report = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                telemetry_every: Some(Duration::from_millis(2)),
                ..Default::default()
            },
        )
        .unwrap();
        let obs = report.obs.as_ref().expect("traced session keeps its sink");
        assert!(!obs.trace.is_empty(), "spans were recorded");
        assert!(obs.hist(crate::obs::Stage::Drain).count() > 0);
        // Acceptance: buckets sum to the measured client stall (±1%).
        let att = report.stall_attribution;
        assert!(
            (att.total() - report.client_stall_secs).abs()
                <= 0.01 * report.client_stall_secs + 1e-6,
            "{att:?} vs stall {}",
            report.client_stall_secs
        );
        let tel = report.telemetry.as_ref().expect("telemetry sampled");
        assert!(tel.samples() > 0);
    }

    #[test]
    fn untraced_session_carries_no_obs() {
        let (cluster, catalog, spec) = setup();
        let report =
            Session::run(&catalog, &cluster, spec, &SessionConfig::default())
                .unwrap();
        assert!(report.obs.is_none());
        assert!(report.telemetry.is_none());
        // Attribution runs even untraced (it costs a few atomic reads
        // per 2ms tick) and always reconciles with the measured stall.
        assert!(
            (report.stall_attribution.total() - report.client_stall_secs)
                .abs()
                <= 0.01 * report.client_stall_secs + 1e-6,
            "{:?} vs stall {}",
            report.stall_attribution,
            report.client_stall_secs
        );
    }

    #[test]
    fn session_reports_wire_compression_accounting() {
        let (cluster, catalog, spec) = setup();
        let mut off = spec.clone();
        off.pipeline.wire_compression =
            crate::dpp::spec::WireCompression::Off;
        let r_off =
            Session::run(&catalog, &cluster, off, &SessionConfig::default())
                .unwrap();
        assert_eq!(r_off.rows_delivered, 128);
        assert_eq!(
            r_off.tensor_tx_bytes, r_off.wire_raw_bytes,
            "off: wire bytes are the raw bytes"
        );
        assert!((r_off.wire_compression_ratio() - 1.0).abs() < 1e-12);
        let r_on =
            Session::run(&catalog, &cluster, spec, &SessionConfig::default())
                .unwrap();
        assert_eq!(
            r_on.rows_delivered, 128,
            "compression changes bytes, never rows"
        );
        assert!(r_on.wire_raw_bytes > 0);
        assert_eq!(
            r_on.client_raw_rx_bytes, r_on.wire_raw_bytes,
            "every produced batch drained exactly once"
        );
        assert_eq!(r_on.client_rx_bytes, r_on.tensor_tx_bytes);
    }

    #[test]
    fn paced_client_throttles_throughput() {
        let (cluster, catalog, spec) = setup();
        let fast = Session::run(
            &catalog,
            &cluster,
            spec.clone(),
            &SessionConfig::default(),
        )
        .unwrap();
        let slow = Session::run(
            &catalog,
            &cluster,
            spec,
            &SessionConfig {
                client_rows_per_sec: Some(400.0),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(slow.wall_secs > fast.wall_secs);
        assert!(slow.rows_per_sec <= 500.0);
    }
}
