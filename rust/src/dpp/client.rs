//! DPP Clients — the trainer-side data plane half (§3.2.1): one Client
//! runs on each training node, exposing the hook the PyTorch runtime
//! calls to obtain preprocessed tensors. Requests become RPCs against a
//! bounded set of Workers via **partitioned round-robin routing**,
//! "capping the number of connections that Clients and Workers need to
//! maintain".

use super::codec::WireUnpacker;
use super::spec::PipelineOptions;
use super::tensor::TensorBatch;
use super::transport::{max_raw_bytes, MAX_FRAME_BYTES};
use super::worker::WireBatch;
use crate::dwrf::crypto::StreamCipher;
use crate::metrics::{Counter, StageClock};
use crate::obs::{ObsHandle, Stage};
use anyhow::Result;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Assign `workers` across `clients` in contiguous partitions, then
/// round-robin within each partition. Every worker lands on exactly one
/// client; partition sizes differ by at most one (caps fan-in/fan-out).
pub fn partition_round_robin(workers: usize, clients: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); clients.max(1)];
    if workers == 0 {
        return out;
    }
    let base = workers / clients.max(1);
    let extra = workers % clients.max(1);
    let mut w = 0;
    for (c, slot) in out.iter_mut().enumerate() {
        let take = base + usize::from(c < extra);
        for _ in 0..take {
            slot.push(w);
            w += 1;
        }
    }
    out
}

/// The trainer-side tensor source.
pub struct Client {
    /// Receiving ends of this client's partition of workers.
    rxs: Vec<Receiver<WireBatch>>,
    cipher: StreamCipher,
    /// Wire decoder (zstd contexts + scratch, reused across batches);
    /// decrypts each frame's owned bytes in place — no receive copy.
    unpacker: WireUnpacker,
    next: usize,
    /// Datacenter-tax accounting: wire bytes received and deserialized.
    pub rx_bytes: Counter,
    /// Declared pre-compression bytes of the received frames (equals
    /// `rx_bytes` for uncompressed sessions, modulo section framing).
    pub raw_rx_bytes: Counter,
    pub batches: Counter,
    /// Dedup wire batches expanded on this client.
    pub dedup_expanded: Counter,
    /// Time spent decrypting + decompressing + deserializing frames
    /// (the trainer-side share of the wire tax).
    pub decode_clock: StageClock,
    /// Time spent blocked waiting for a batch (data-stall signal).
    /// An atomic nanosecond accumulator — this sits on the hot recv
    /// path, bumped on every poll sweep, so no mutex. Shared (`Arc`) so
    /// the session control loop reads stall *while* the client drains;
    /// mid-run reads are relaxed lower bounds (see `StageClock`'s
    /// ordering notes in `crate::metrics`).
    pub stall: Arc<StageClock>,
    /// Span sink + this client's trace lane (`tid`), when tracing.
    obs: Option<(ObsHandle, u32)>,
}

impl Client {
    pub fn new(table: &str, rxs: Vec<Receiver<WireBatch>>) -> Client {
        Client {
            rxs,
            cipher: StreamCipher::for_table(table),
            unpacker: WireUnpacker::new(max_raw_bytes(MAX_FRAME_BYTES)),
            next: 0,
            rx_bytes: Counter::new(),
            raw_rx_bytes: Counter::new(),
            batches: Counter::new(),
            dedup_expanded: Counter::new(),
            decode_clock: StageClock::default(),
            stall: Arc::new(StageClock::default()),
            obs: None,
        }
    }

    /// Adopt the session's wire options (builder style): the decode
    /// bound follows `max_frame_bytes` and the session dictionary — the
    /// same bytes the workers compress with — is attached, so worker and
    /// client always agree.
    pub fn with_wire(mut self, pipeline: &PipelineOptions) -> Client {
        let mut u = WireUnpacker::new(max_raw_bytes(pipeline.max_frame_bytes));
        if let Some(d) = pipeline.wire_compression.dict() {
            u = u.with_dict(d);
        }
        self.unpacker = u;
        self
    }

    /// Share the stall accumulator (builder style): the session control
    /// loop keeps a clone to attribute stalls live, mid-run.
    pub fn with_stall_clock(mut self, clock: Arc<StageClock>) -> Client {
        self.stall = clock;
        self
    }

    /// Emit `WireRecv`/`Drain` spans on `handle`, lane `tid`.
    pub fn with_obs(mut self, handle: ObsHandle, tid: u32) -> Client {
        self.obs = Some((handle, tid));
        self
    }

    pub fn num_connections(&self) -> usize {
        self.rxs.len()
    }

    /// The PyTorch-runtime hook: next preprocessed tensor batch.
    /// Round-robins across this client's workers; blocks (recording stall
    /// time) until a batch arrives or all workers disconnect.
    pub fn next_batch(&mut self, timeout: Duration) -> Result<Option<TensorBatch>> {
        if self.rxs.is_empty() {
            return Ok(None);
        }
        let start = Instant::now();
        let mut disconnected = vec![false; self.rxs.len()];
        // Bounded parked wait between polling sweeps: an idle trainer
        // client must not burn a full core spinning on `yield_now`. The
        // park slice doubles from 10µs up to 1ms (staying responsive to
        // bursts while capping wake-ups at ~1k/s when drained) and never
        // overshoots the caller's timeout.
        let mut park = Duration::from_micros(10);
        const PARK_MAX: Duration = Duration::from_millis(1);
        loop {
            let mut all_dead = true;
            for k in 0..self.rxs.len() {
                let i = (self.next + k) % self.rxs.len();
                if disconnected[i] {
                    continue;
                }
                all_dead = false;
                match self.rxs[i].try_recv() {
                    Ok(wire) => {
                        self.next = (i + 1) % self.rxs.len();
                        self.rx_bytes.add(wire.bytes.len() as u64);
                        self.raw_rx_bytes.add(wire.raw_len as u64);
                        self.batches.inc();
                        self.stall.add(start.elapsed());
                        let seq = wire.seq;
                        if let Some((h, tid)) = &self.obs {
                            h.span(*tid, seq, Stage::WireRecv, start);
                        }
                        let t_drain = Instant::now();
                        // TLS decrypt + zstd + deserialize: the
                        // trainer-side datacenter tax (§6.2). The frame
                        // is consumed — its payload decrypts in place.
                        // Dedup wire batches additionally expand (gather
                        // unique rows through the inverse index) so the
                        // trainer only ever sees ordinary full batches.
                        let tb = if wire.dedup {
                            self.dedup_expanded.inc();
                            self.unpacker
                                .decode_dedup(&self.cipher, wire)?
                                .expand()
                        } else {
                            self.unpacker.decode_tensor(&self.cipher, wire)?
                        };
                        self.decode_clock.add(t_drain.elapsed());
                        if let Some((h, tid)) = &self.obs {
                            h.span(*tid, seq, Stage::Drain, t_drain);
                        }
                        return Ok(Some(tb));
                    }
                    Err(std::sync::mpsc::TryRecvError::Empty) => {}
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                        disconnected[i] = true;
                    }
                }
            }
            if all_dead {
                return Ok(None);
            }
            let elapsed = start.elapsed();
            if elapsed > timeout {
                self.stall.add(elapsed);
                return Ok(None);
            }
            let remaining = timeout - elapsed;
            // Under the loom model this hands the scheduler token to a
            // peer (mpsc channels are not instrumented, so the poll
            // loop would otherwise spin without ever letting a sender
            // run); on normal builds it is a no-op before the park.
            crate::sync::model_yield();
            std::thread::park_timeout(park.min(remaining));
            park = (park * 2).min(PARK_MAX);
        }
    }

    pub fn stalled(&self) -> f64 {
        self.stall.secs()
    }
}

/// Shared handle bundle when one process hosts several clients.
pub type Clients = Vec<Arc<crate::sync::Mutex<Client>>>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn partition_rr_covers_all_workers_once() {
        for (w, c) in [(10, 3), (3, 3), (2, 5), (0, 2), (7, 1)] {
            let parts = partition_round_robin(w, c);
            assert_eq!(parts.len(), c.max(1));
            let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..w).collect::<Vec<_>>());
            // Balanced within one.
            let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (mn, mx) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
        }
    }

    #[test]
    fn client_round_robins_and_decodes() {
        let (tx1, rx1) = sync_channel(4);
        let (tx2, rx2) = sync_channel(4);
        let cipher = StreamCipher::for_table("t");
        let tb = TensorBatch {
            rows: 2,
            dense: vec![1.0, 2.0],
            dense_names: vec![crate::schema::FeatureId(0)],
            sparse: vec![],
            labels: vec![0.0, 1.0],
        };
        for (seq, tx) in [(0u64, &tx1), (1u64, &tx2)] {
            tx.send(WireBatch::plain(seq, 2, false, tb.to_wire(&cipher, seq)))
                .unwrap();
        }
        drop(tx1);
        drop(tx2);
        let mut client = Client::new("t", vec![rx1, rx2]);
        assert_eq!(client.num_connections(), 2);
        let a = client.next_batch(Duration::from_secs(1)).unwrap().unwrap();
        let b = client.next_batch(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(a, tb);
        assert_eq!(b, tb);
        assert!(client
            .next_batch(Duration::from_millis(50))
            .unwrap()
            .is_none());
        assert_eq!(client.batches.get(), 2);
        assert!(client.rx_bytes.get() > 0);
    }

    #[test]
    fn client_with_no_workers_returns_none() {
        let mut c = Client::new("t", vec![]);
        assert!(c.next_batch(Duration::from_millis(1)).unwrap().is_none());
    }

    #[test]
    fn client_decodes_compressed_frames() {
        use crate::dpp::codec::WirePacker;
        let (tx, rx) = sync_channel(4);
        let cipher = StreamCipher::for_table("t");
        let tb = TensorBatch {
            rows: 64,
            dense: (0..64).map(|i| (i % 5) as f32).collect(),
            dense_names: vec![crate::schema::FeatureId(0)],
            sparse: vec![],
            labels: (0..64).map(|i| (i % 2) as f32).collect(),
        };
        let pipeline = PipelineOptions::default();
        let mut packer = WirePacker::new(&pipeline).unwrap();
        let wb = packer.encode_tensor(&cipher, 0, &tb).unwrap();
        assert!(wb.compressed);
        tx.send(wb).unwrap();
        drop(tx);
        let mut client =
            Client::new("t", vec![rx]).with_wire(&pipeline);
        let got = client.next_batch(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(got, tb);
        assert!(client.raw_rx_bytes.get() >= client.rx_bytes.get());
        assert!(client.decode_clock.secs() >= 0.0);
    }

    #[test]
    fn client_expands_dedup_wire_batches() {
        use crate::dpp::tensor::DedupTensorBatch;
        let (tx, rx) = sync_channel(4);
        let cipher = StreamCipher::for_table("t");
        let unique = TensorBatch {
            rows: 2,
            dense: vec![10.0, 20.0],
            dense_names: vec![crate::schema::FeatureId(0)],
            sparse: vec![(
                crate::schema::FeatureId(9),
                vec![0, 1, 3],
                vec![5, 6, 7],
            )],
            labels: vec![0.0, 0.0],
        };
        let db = DedupTensorBatch {
            inverse: vec![1, 0, 1, 1],
            labels: vec![1.0, 0.0, 0.0, 1.0],
            unique,
        };
        tx.send(WireBatch::plain(0, 4, true, db.to_wire(&cipher, 0)))
            .unwrap();
        drop(tx);
        let mut client = Client::new("t", vec![rx]);
        let got = client.next_batch(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(got.rows, 4);
        assert_eq!(got.dense, vec![20.0, 10.0, 20.0, 20.0]);
        assert_eq!(got.labels, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(got.sparse[0].1, vec![0, 2, 3, 5, 7]);
        assert_eq!(got.sparse[0].2, vec![6, 7, 5, 6, 7, 6, 7]);
        assert_eq!(client.dedup_expanded.get(), 1);
    }

    #[test]
    fn parked_wait_still_receives_late_batches() {
        let (tx, rx) = sync_channel(1);
        let cipher = StreamCipher::for_table("t");
        let tb = TensorBatch {
            rows: 1,
            dense: vec![7.0],
            dense_names: vec![crate::schema::FeatureId(0)],
            sparse: vec![],
            labels: vec![1.0],
        };
        let bytes = tb.to_wire(&cipher, 0);
        let sender = std::thread::spawn(move || {
            // Arrive mid-wait, after the client has started parking.
            std::thread::sleep(Duration::from_millis(30));
            tx.send(WireBatch::plain(0, 1, false, bytes)).unwrap();
        });
        let mut client = Client::new("t", vec![rx]);
        let got = client
            .next_batch(Duration::from_secs(5))
            .unwrap()
            .expect("late batch delivered");
        assert_eq!(got, tb);
        sender.join().unwrap();
        // The wait was recorded as stall, and we did not sleep anywhere
        // near the full timeout.
        assert!(client.stalled() >= 0.02);
        assert!(client.stalled() < 2.0);
    }

    #[test]
    fn stall_time_recorded_on_timeout() {
        let (_tx, rx) = sync_channel::<WireBatch>(1);
        let mut c = Client::new("t", vec![rx]);
        let got = c.next_batch(Duration::from_millis(20)).unwrap();
        assert!(got.is_none());
        assert!(c.stalled() >= 0.02);
    }

    #[test]
    fn shared_stall_clock_is_readable_mid_drain() {
        let (_tx, rx) = sync_channel::<WireBatch>(1);
        let clock = Arc::new(StageClock::default());
        let mut c =
            Client::new("t", vec![rx]).with_stall_clock(clock.clone());
        c.next_batch(Duration::from_millis(20)).unwrap();
        // The external handle sees the same accumulator.
        assert!((clock.secs() - c.stalled()).abs() < 1e-12);
        assert!(clock.secs() >= 0.02);
    }

    #[test]
    fn client_emits_recv_and_drain_spans() {
        use crate::obs::Obs;
        let (tx, rx) = sync_channel(1);
        let cipher = StreamCipher::for_table("t");
        let tb = TensorBatch {
            rows: 1,
            dense: vec![3.0],
            dense_names: vec![crate::schema::FeatureId(0)],
            sparse: vec![],
            labels: vec![1.0],
        };
        tx.send(WireBatch::plain(5, 1, false, tb.to_wire(&cipher, 5)))
            .unwrap();
        drop(tx);
        let obs = Obs::with_capacity(8);
        let h = ObsHandle::for_session(obs.clone(), "t");
        let mut client = Client::new("t", vec![rx]).with_obs(h, 1000);
        client.next_batch(Duration::from_secs(1)).unwrap().unwrap();
        let evs = obs.trace.events();
        assert!(evs
            .iter()
            .any(|e| e.stage == Stage::WireRecv && e.split == 5));
        assert!(evs
            .iter()
            .any(|e| e.stage == Stage::Drain && e.tid == 1000));
        assert_eq!(obs.hist(Stage::WireRecv).count(), 1);
    }
}
