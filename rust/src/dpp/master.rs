//! The DPP Master — control plane (§3.2.1): breaks the preprocessing
//! workload into splits, serves them to Workers on request, tracks
//! progress, checkpoints reader state, monitors Worker health (restarting
//! failed Workers without checkpoint restore, thanks to their stateless
//! design), and runs the auto-scaling controller.

use super::spec::SessionSpec;
use super::split::{splits_for_partition, Split, SplitId};
use crate::broker::{BrokerHandle, ReadBroker};
use crate::dwrf::{DwrfReader, FileMeta, IoRange, StripeInfo, StripeStats};
use crate::filter::RowPredicate;
use crate::obs::{ObsHandle, SpanEvent, Stage};
use crate::tectonic::{Cluster, FileId};
use crate::warehouse::Catalog;
use crate::sync::{lock_or_recover, Mutex};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub type WorkerId = usize;

/// Health/utilization report a Worker heartbeats to the Master — the
/// signals the auto-scaling controller consumes (§3.2.1: "utilization
/// (CPU, memory, and network) statistics and the number of buffered
/// tensors from each DPP Worker").
#[derive(Clone, Debug)]
pub struct WorkerHealth {
    pub last_heartbeat: Instant,
    pub buffered_tensors: usize,
    pub cpu_util: f64,
    pub mem_util: f64,
    pub net_util: f64,
    pub alive: bool,
    /// Retired by the autoscaler: still alive and draining its current
    /// lease, but never handed a new split, and excluded from the
    /// controller's live-pool base.
    pub draining: bool,
}

impl Default for WorkerHealth {
    fn default() -> Self {
        WorkerHealth {
            last_heartbeat: Instant::now(),
            buffered_tensors: 0,
            cpu_util: 0.0,
            mem_util: 0.0,
            net_util: 0.0,
            alive: true,
            draining: false,
        }
    }
}

/// Serializable master progress (the periodic checkpoint used to restore
/// reader state on failure).
#[derive(Clone, Debug, PartialEq)]
pub struct MasterCheckpoint {
    pub completed: Vec<u64>,
    /// Splits pruned by stripe-stat pushdown: never queued, recorded
    /// explicitly (not silently absent) so a restore with different
    /// stats or predicate still treats them as settled — restore stays
    /// idempotent.
    pub skipped: Vec<u64>,
}

struct MasterState {
    queue: VecDeque<SplitId>,
    all: HashMap<SplitId, Split>,
    in_flight: HashMap<SplitId, (WorkerId, Instant)>,
    completed: BTreeSet<SplitId>,
    /// Splits whose every stripe the footer stats prove row-free under
    /// the session predicate — skipped without any worker touching them.
    skipped: BTreeSet<SplitId>,
    workers: HashMap<WorkerId, WorkerHealth>,
    next_worker: WorkerId,
}

impl MasterState {
    /// Requeue every split leased to `worker` (at the queue front —
    /// they were already being worked). Returns how many requeued.
    fn requeue_leases(&mut self, worker: WorkerId) -> usize {
        let orphaned: Vec<SplitId> = self
            .in_flight
            .iter()
            .filter(|(_, (w, _))| *w == worker)
            .map(|(id, _)| *id)
            .collect();
        let n = orphaned.len();
        for id in orphaned {
            self.in_flight.remove(&id);
            self.queue.push_front(id);
        }
        self.check_invariants();
        n
    }

    /// Lease/queue/completion disjointness — the state-machine
    /// invariant the loom models drive: settled work is never leased or
    /// queued, and a split is never both queued and leased.
    #[cfg(any(debug_assertions, loom))]
    fn check_invariants(&self) {
        for id in self.in_flight.keys() {
            assert!(
                !self.completed.contains(id),
                "split {id:?} both leased and completed"
            );
        }
        for id in &self.queue {
            assert!(
                !self.completed.contains(id),
                "split {id:?} both queued and completed"
            );
            assert!(
                !self.in_flight.contains_key(id),
                "split {id:?} both queued and leased"
            );
        }
    }

    #[cfg(not(any(debug_assertions, loom)))]
    fn check_invariants(&self) {}
}

/// Auto-scaler targets and controller knobs.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// Below this average buffered-tensor depth the pool counts as
    /// starved (trainers are at risk of stalling).
    pub min_buffered: f64,
    /// Above this depth — with CPUs also underutilized — the pool
    /// counts as glutted (wasted preprocessing capacity).
    pub max_buffered: f64,
    /// Provisioning assumes a worker sustains at most this busy share.
    pub target_cpu: f64,
    pub min_workers: usize,
    pub max_workers: usize,
    /// Demand headroom: provision for `headroom ×` the smoothed drain
    /// rate so transient bursts don't immediately starve trainers.
    pub headroom: f64,
    /// Controller decisions to hold after a scaling action before the
    /// next one (hysteresis in time: the pipeline's response to a
    /// change is observed before acting again, so the controller
    /// converges instead of flapping).
    pub cooldown_ticks: u32,
    /// Workers added per decision at most. Growth is bounded — the old
    /// controller grew proportionally to `current`, which doubled an
    /// empty-buffered pool on every tick.
    pub max_step_up: usize,
    /// Workers retired per decision at most.
    pub max_step_down: usize,
    /// EMA weight of each new rate observation (0..1).
    pub alpha: f64,
    /// When more than this fraction of the tick's new client-stall time
    /// is attributed to the worker-starved bucket, the pool counts as
    /// starved even if buffer depths look healthy on average — stall
    /// attribution sees the stalls buffer averages hide (one empty
    /// worker behind several deep ones).
    pub max_starved_stall_frac: f64,
    /// Dead workers older than this are pruned from the health map: the
    /// controller's base is the live pool, and the map must not grow
    /// with every crash. The grace window keeps the reaped-but-
    /// actually-alive revival path (heartbeat after a false reap)
    /// working.
    pub dead_grace: Duration,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_buffered: 1.0,
            max_buffered: 8.0,
            target_cpu: 0.85,
            min_workers: 1,
            max_workers: 64,
            headroom: 1.25,
            cooldown_ticks: 4,
            max_step_up: 2,
            max_step_down: 1,
            alpha: 0.35,
            max_starved_stall_frac: 0.2,
            dead_grace: Duration::from_secs(30),
        }
    }
}

/// Cumulative pipeline observations the session loop feeds the
/// controller each tick (deltas between successive snapshots drive the
/// rate estimates; cumulative form keeps the call side trivial — hand
/// over the current counter values and wall clock).
#[derive(Clone, Debug, Default)]
pub struct ScaleSignals {
    /// Wall seconds since the session started.
    pub wall_secs: f64,
    /// Rows trainer-side clients have drained (demand).
    pub drained_rows: u64,
    /// Rows workers have delivered into buffers (supply).
    pub produced_rows: u64,
    /// Rows decoded out of storage (selectivity correction, numerator
    /// base).
    pub decoded_rows: u64,
    /// Rows the session predicate dropped after decode.
    pub filtered_rows: u64,
    /// Total worker busy seconds across all pipeline stages.
    pub busy_secs: f64,
    /// Busy seconds spent in fetch + decode (the share a broker buffer
    /// hit skips).
    pub fetch_decode_secs: f64,
    /// Client data-stall seconds so far (cumulative, summed over
    /// trainer-side clients).
    pub stall_secs: f64,
    /// Share of `stall_secs` the attributor has assigned to the
    /// worker-starved bucket so far (cumulative). Both default to 0 —
    /// sessions without stall attribution feed the pre-existing
    /// buffer-depth signal only.
    pub stall_starved_secs: f64,
}

/// What one controller evaluation decided, with the fused signals that
/// produced it (reported by benches and asserted by tests).
#[derive(Clone, Debug)]
pub struct ScaleDecision {
    pub desired: usize,
    /// Live (alive, non-draining) workers the decision was based on.
    pub alive: usize,
    /// Smoothed trainer drain rate, rows/s.
    pub demand_rows_per_sec: f64,
    /// Effective per-worker capacity (delivered rows per busy second)
    /// after the hit-rate / selectivity drift rescale.
    pub capacity_rows_per_busy_sec: f64,
    /// Online-corrected predicate selectivity estimate.
    pub selectivity: f64,
    /// This session's broker-buffer hit rate (0.0 without a broker).
    pub broker_hit_rate: f64,
    pub reason: &'static str,
}

/// Controller memory between ticks.
#[derive(Debug)]
struct ControllerState {
    prev: Option<ScaleSignals>,
    /// EMA trainer drain rate (rows/s).
    demand: f64,
    /// EMA per-worker capacity: delivered rows per busy second.
    capacity: f64,
    /// Broker hit rate, selectivity, and fetch+decode busy-share under
    /// which `capacity` was learned (the rescale basis).
    basis_hit: f64,
    basis_sel: f64,
    basis_fetch_share: f64,
    /// Selectivity estimate: seeded from stripe-stat priors, corrected
    /// online from `filtered_rows / decoded_rows`.
    selectivity: f64,
    cooldown: u32,
}

impl ControllerState {
    fn new(prior_selectivity: f64) -> ControllerState {
        ControllerState {
            prev: None,
            demand: 0.0,
            capacity: 0.0,
            basis_hit: 0.0,
            basis_sel: prior_selectivity,
            basis_fetch_share: 0.0,
            selectivity: prior_selectivity,
            cooldown: 0,
        }
    }
}

/// Rescale a per-worker capacity (delivered rows per busy second)
/// learned at broker hit rate `basis_hit` and decoded-survival fraction
/// `basis_sel` — with `fetch_share` of busy time then spent in
/// fetch+decode — to the current estimates: a stripe served from the
/// shared buffer skips fetch+decode entirely, and a narrower surviving
/// fraction decodes more rows per delivered row. Model: busy cost per
/// delivered row is `D·(1−hit)/sel + P`; at the basis the fetch+decode
/// term is the observed `fetch_share` of the total, so capacity scales
/// by `1 / (o·(s₀/s₁)·(1−h₁)/(1−h₀) + (1−o))`. No drift from the
/// basis ⇒ ratio 1 (no double counting of what the EMA absorbed).
pub fn rescale_worker_capacity(
    capacity: f64,
    fetch_share: f64,
    basis_hit: f64,
    basis_sel: f64,
    hit_now: f64,
    sel_now: f64,
) -> f64 {
    let o = fetch_share.clamp(0.0, 0.99);
    let h0 = basis_hit.clamp(0.0, 0.99);
    let h1 = hit_now.clamp(0.0, 1.0);
    let s0 = basis_sel.clamp(1e-3, 1.0);
    let s1 = sel_now.clamp(1e-3, 1.0);
    let fetch = o * (s0 / s1) * ((1.0 - h1) / (1.0 - h0));
    capacity / (fetch + (1.0 - o)).max(1e-9)
}

/// Feed-forward planning estimate: worker busy-seconds to preprocess
/// `rows` rows when the predicate keeps a `selectivity` fraction and
/// stripe-stat pushdown proves a `pruned_frac` fraction row-free
/// without decoding it. Decode cost is paid per decoded row,
/// transform+load cost per delivered row — so the estimate is monotone
/// non-increasing as selectivity drops (a narrower predicate can only
/// prune more and deliver less).
pub fn estimate_worker_seconds(
    rows: u64,
    selectivity: f64,
    pruned_frac: f64,
    decode_secs_per_row: f64,
    process_secs_per_row: f64,
) -> f64 {
    let sel = selectivity.clamp(0.0, 1.0);
    let pruned = pruned_frac.clamp(0.0, 1.0);
    rows as f64 * (1.0 - pruned) * decode_secs_per_row.max(0.0)
        + rows as f64 * sel * process_secs_per_row.max(0.0)
}

pub struct Master {
    pub spec: SessionSpec,
    state: Mutex<MasterState>,
    pub policy: AutoscalePolicy,
    /// Present when this session's reads flow through a shared
    /// [`ReadBroker`] (see [`Master::new_shared`]).
    broker: Option<BrokerHandle>,
    /// Row-weighted predicate selectivity over planned stripe stats
    /// (1.0 unfiltered) — the controller's feed-forward prior.
    prior_selectivity: f64,
    controller: Mutex<ControllerState>,
    /// Observability sink for traced sessions (set by
    /// [`Master::attach_obs`]); workers pick it up via
    /// [`Master::obs_handle`] when they spawn.
    obs: Mutex<Option<ObsHandle>>,
    /// How long split enumeration (footer fetch + planning) took — the
    /// session's control-plane `plan` span.
    build_dur: Duration,
}

impl Master {
    /// Create a session: resolve the table, fetch partition footers
    /// (control-plane I/O through the same storage path), and enumerate
    /// splits.
    pub fn new(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
    ) -> Result<Master> {
        Self::build(catalog, cluster, spec, None)
    }

    /// [`Master::new`] with this session attached to a shared
    /// [`ReadBroker`]: footers come from the broker's cross-session
    /// cache (one fetch per file no matter how many sessions), and the
    /// session's planned (file, stripe) interest is registered so
    /// overlapping sessions fetch and decode each popular stripe once.
    /// Workers pick the shared path up via [`Master::broker_handle`].
    pub fn new_shared(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        broker: &Arc<ReadBroker>,
    ) -> Result<Master> {
        Self::build(catalog, cluster, spec, Some(broker))
    }

    fn build(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        broker: Option<&Arc<ReadBroker>>,
    ) -> Result<Master> {
        let t_build = Instant::now();
        spec.pipeline
            .validate()
            .context("invalid pipeline options")?;
        let table = catalog
            .get(&spec.table)
            .with_context(|| format!("unknown table {}", spec.table))?;
        let parts = table.select_partitions(spec.from_day, spec.to_day);
        if parts.is_empty() {
            bail!(
                "no partitions in [{}, {}] for {}",
                spec.from_day,
                spec.to_day,
                spec.table
            );
        }
        let mut next_id = 0u64;
        let mut all = HashMap::new();
        let mut queue = VecDeque::new();
        let mut skipped = BTreeSet::new();
        // Stats-aware split pruning: with pushdown on, a split whose
        // every stripe the footer stats prove row-free never reaches the
        // queue — fully-filtered files contribute zero live splits.
        let predicate = if spec.pipeline.pushdown {
            spec.predicate.as_ref()
        } else {
            None
        };
        // Stripe-level prune decision — the same `StripeInfo::pruned_at`
        // the worker's planner evaluates, so split enumeration and
        // broker interest registration cannot drift from the plans
        // workers actually execute.
        let use_groups = spec.pipeline.row_group_pruning;
        let stripe_pruned = |pr: &RowPredicate, st: &StripeInfo| -> bool {
            st.pruned_at(pr, use_groups)
        };
        // Planned (file, stripe) interest for broker registration: only
        // stripes a worker will actually fetch — whole-split prunes and
        // per-stripe prunes (the worker's plan applies the same
        // predicate to the same stats) are both excluded, so shared
        // buffers are never pinned waiting for a consumer that the
        // pushdown already proved will never come.
        let mut interest: HashMap<FileId, Vec<usize>> = HashMap::new();
        // Stripes that will actually decode (the pushdown prunes the
        // rest without I/O) — the population the controller's
        // selectivity prior must describe, because the online
        // correction it converges to is `filtered / decoded`.
        let mut decoded_pairs: Vec<(StripeStats, u32)> = Vec::new();
        for p in parts {
            let meta: Arc<FileMeta> = match broker {
                // One cached footer per file across *all* sessions.
                Some(b) => b.footer(p.file)?,
                None => Arc::new(Self::fetch_meta(cluster, p.file)?),
            };
            let stripe_rows: Vec<u32> =
                meta.stripes.iter().map(|s| s.rows).collect();
            // The population the controller's selectivity prior must
            // describe is what will actually *decode*: with row-group
            // stats present, that's the surviving groups of surviving
            // stripes — a sharper prior than stripe-level stats,
            // because pruned groups neither decode nor deliver.
            for s in meta.stripes.iter() {
                if predicate.is_some_and(|pr| stripe_pruned(pr, s)) {
                    continue;
                }
                if use_groups && !s.groups.is_empty() {
                    for g in &s.groups {
                        let g_pruned = predicate.is_some_and(|pr| {
                            pr.prunes_stripe(&g.stats, g.rows)
                        });
                        if !g_pruned {
                            decoded_pairs.push((g.stats, g.rows));
                        }
                    }
                } else {
                    decoded_pairs.push((s.stats, s.rows));
                }
            }
            for split in splits_for_partition(
                &mut next_id,
                p.file,
                p.day,
                &stripe_rows,
                spec.stripes_per_split,
            ) {
                let s = split.stripe_start;
                let e = s + split.stripe_count;
                let pruned = match predicate {
                    Some(pr) => meta.stripes[s..e]
                        .iter()
                        .all(|st| stripe_pruned(pr, st)),
                    None => false,
                };
                if pruned {
                    skipped.insert(split.id);
                } else {
                    queue.push_back(split.id);
                    if broker.is_some() {
                        let live = interest.entry(p.file).or_default();
                        for (si, st) in
                            meta.stripes[s..e].iter().enumerate()
                        {
                            let dead = predicate
                                .is_some_and(|pr| stripe_pruned(pr, st));
                            if !dead {
                                live.push(s + si);
                            }
                        }
                    }
                }
                all.insert(split.id, split);
            }
        }
        let broker = broker.map(|b| BrokerHandle {
            broker: b.clone(),
            session: b.register(&spec.table, &spec.projection, interest),
        });
        // Feed-forward selectivity prior for the autoscaler, over
        // exactly the stripes that will decode — the same quantity the
        // online `filtered / decoded` correction converges to.
        let prior_selectivity = match spec.predicate.as_ref() {
            Some(p) if !decoded_pairs.is_empty() => p.dataset_selectivity(
                decoded_pairs.iter().map(|(s, r)| (s, *r)),
            ),
            // Everything pruned: nothing will be decoded or delivered.
            Some(_) => 0.0,
            // Unfiltered: the spec-level prior (1.0).
            None => spec.estimated_selectivity(),
        };
        Ok(Master {
            spec,
            state: Mutex::new(MasterState {
                queue,
                all,
                in_flight: HashMap::new(),
                completed: BTreeSet::new(),
                skipped,
                workers: HashMap::new(),
                next_worker: 0,
            }),
            policy: AutoscalePolicy::default(),
            broker,
            prior_selectivity,
            controller: Mutex::new(ControllerState::new(prior_selectivity)),
            obs: Mutex::new(None),
            build_dur: t_build.elapsed(),
        })
    }

    /// The shared-read handle workers attach to their cores (present
    /// only for [`Master::new_shared`] sessions).
    pub fn broker_handle(&self) -> Option<BrokerHandle> {
        self.broker.clone()
    }

    /// A minimal in-memory session — `n` queued two-stripe splits, no
    /// storage, no broker — so the loom models and concurrency stress
    /// tests can drive the lease state machine in isolation.
    #[doc(hidden)]
    pub fn synthetic(n: usize) -> Master {
        let mut all = HashMap::new();
        let mut queue = VecDeque::new();
        for i in 0..n {
            let id = SplitId(i as u64);
            all.insert(
                id,
                Split {
                    id,
                    file: FileId(1),
                    day: 0,
                    stripe_start: i * 2,
                    stripe_count: 2,
                    rows: 64,
                },
            );
            queue.push_back(id);
        }
        Master {
            spec: SessionSpec::from_dag(
                "synthetic",
                0,
                1,
                crate::transforms::TransformDag::default(),
                16,
            ),
            state: Mutex::new(MasterState {
                queue,
                all,
                in_flight: HashMap::new(),
                completed: BTreeSet::new(),
                skipped: BTreeSet::new(),
                workers: HashMap::new(),
                next_worker: 0,
            }),
            policy: AutoscalePolicy::default(),
            broker: None,
            prior_selectivity: 1.0,
            controller: Mutex::new(ControllerState::new(1.0)),
            obs: Mutex::new(None),
            build_dur: Duration::ZERO,
        }
    }

    /// Attach an observability sink to this session. Retroactively
    /// records the split-enumeration time as the session's `plan` span
    /// (sentinel lane `u32::MAX` / split `u64::MAX` — control-plane
    /// work, not tied to any split), anchored at the trace epoch since
    /// enumeration predates the sink.
    pub fn attach_obs(&self, h: ObsHandle) {
        h.obs.trace.record(SpanEvent {
            session: h.session,
            tid: u32::MAX,
            split: u64::MAX,
            stage: Stage::Plan,
            t0_ns: 0,
            dur_ns: self.build_dur.as_nanos() as u64,
        });
        h.obs.hist(Stage::Plan).record(self.build_dur);
        *lock_or_recover(&self.obs, "master obs") = Some(h);
    }

    /// The observability handle workers and clients attach to (present
    /// only after [`Master::attach_obs`] — i.e. for traced sessions).
    pub fn obs_handle(&self) -> Option<ObsHandle> {
        lock_or_recover(&self.obs, "master obs").clone()
    }

    /// (live workers, average buffered-tensor depth) — the telemetry
    /// sampler's pool view, one lock hold for a consistent pair.
    pub fn pool_snapshot(&self) -> (usize, f64) {
        let st = lock_or_recover(&self.state, "master state");
        let live: Vec<&WorkerHealth> = st
            .workers
            .values()
            .filter(|h| h.alive && !h.draining)
            .collect();
        let n = live.len();
        let avg = if n == 0 {
            0.0
        } else {
            live.iter().map(|h| h.buffered_tensors as f64).sum::<f64>()
                / n as f64
        };
        (n, avg)
    }

    /// Bytes currently held by the shared broker buffer (0 without a
    /// broker) — a telemetry gauge; the buffer is cross-session, so
    /// concurrent traced sessions each report the same pool.
    pub fn broker_mem_bytes(&self) -> u64 {
        self.broker
            .as_ref()
            .map_or(0, |h| h.broker.budget().used())
    }

    /// Fetch and parse a file's footer via ranged tail reads: the
    /// initial probe is [`DwrfReader::footer_ios`]'s tail estimate, then
    /// the read doubles until the whole footer fits — v3 footers grow
    /// with stripes × row groups, so the re-read path is load-bearing,
    /// not theoretical.
    pub fn fetch_meta(cluster: &Cluster, file: FileId) -> Result<FileMeta> {
        let flen = cluster.file_len(file).context("file length")?;
        let mut tail = DwrfReader::footer_ios(flen).len;
        loop {
            let io = IoRange {
                offset: flen - tail,
                len: tail,
            };
            let bytes = cluster.read_range(file, io)?;
            let n = bytes.len();
            if n < 12 {
                bail!("file too short");
            }
            let magic = u32::from_le_bytes(bytes[n - 4..].try_into().unwrap());
            if magic != crate::dwrf::MAGIC {
                bail!("bad DWRF magic");
            }
            let footer_len =
                u64::from_le_bytes(bytes[n - 12..n - 4].try_into().unwrap());
            // `footer_len` comes straight off storage: a corrupt value
            // near u64::MAX wraps `footer_len + 12` past this guard and
            // then underflows the start offset below.
            let Some(total) = footer_len.checked_add(12) else {
                bail!("corrupt footer length {footer_len}");
            };
            if total <= tail {
                let start = n - 12 - footer_len as usize;
                return FileMeta::decode_footer(
                    &bytes[start..n - 12],
                    flen,
                );
            }
            if tail == flen {
                bail!("footer larger than file");
            }
            tail = (tail * 2).min(flen);
        }
    }

    /// Register a new Worker; returns its id.
    pub fn register_worker(&self) -> WorkerId {
        let mut st = lock_or_recover(&self.state, "master state");
        let id = st.next_worker;
        st.next_worker += 1;
        st.workers.insert(id, WorkerHealth::default());
        id
    }

    /// Worker requests the next split. `None` ⇒ no work remains *right
    /// now* (the session is done once `is_done`), or the caller is not a
    /// live registered worker — a worker already marked dead must never
    /// lease a split, or a requeued split can bounce straight back to
    /// the crashed worker id. Draining (retired) workers are likewise
    /// refused: they finish their current lease and exit.
    pub fn fetch_split(&self, worker: WorkerId) -> Option<Split> {
        let mut st = lock_or_recover(&self.state, "master state");
        if !st
            .workers
            .get(&worker)
            .is_some_and(|h| h.alive && !h.draining)
        {
            return None;
        }
        let id = st.queue.pop_front()?;
        st.in_flight.insert(id, (worker, Instant::now()));
        st.check_invariants();
        Some(st.all[&id].clone())
    }

    /// Record a split completion. The first completion wins and is
    /// final, no matter who reports it: the lease (if any) is cleared —
    /// so a stale completion from a presumed-dead worker makes the
    /// current leaseholder's later report an idempotent no-op — and a
    /// pending requeue of the same split is cancelled, so settled work
    /// is never served twice.
    pub fn complete_split(&self, _worker: WorkerId, id: SplitId) {
        let mut st = lock_or_recover(&self.state, "master state");
        let had_lease = st.in_flight.remove(&id).is_some();
        // A stale completion can race the requeue that assumed its
        // worker died; the split is settled now, don't re-serve it. A
        // split with a live lease cannot also sit in the queue (leases
        // pop it; requeues drop the lease first), so the O(queue) scan
        // only runs on lease-less, non-idempotent completions.
        if st.completed.insert(id) && !had_lease {
            st.queue.retain(|&q| q != id);
        }
        st.check_invariants();
    }

    pub fn heartbeat(&self, worker: WorkerId, buffered: usize, cpu: f64, mem: f64, net: f64) {
        let mut st = lock_or_recover(&self.state, "master state");
        if let Some(h) = st.workers.get_mut(&worker) {
            h.last_heartbeat = Instant::now();
            h.buffered_tensors = buffered;
            h.cpu_util = cpu;
            h.mem_util = mem;
            h.net_util = net;
            h.alive = true;
        }
    }

    /// Gracefully retire a worker (the autoscaler's scale-down path):
    /// it is never handed another split, drains its current lease to
    /// completion, and exits — unlike [`Master::worker_failed`], nothing
    /// is requeued, so retirement costs zero duplicated work. Returns
    /// `false` for unknown or already-dead workers.
    pub fn retire_worker(&self, worker: WorkerId) -> bool {
        let mut st = lock_or_recover(&self.state, "master state");
        match st.workers.get_mut(&worker) {
            Some(h) if h.alive => {
                h.draining = true;
                true
            }
            _ => false,
        }
    }

    /// Has this worker been asked to retire?
    pub fn is_draining(&self, worker: WorkerId) -> bool {
        let st = lock_or_recover(&self.state, "master state");
        st.workers.get(&worker).is_some_and(|h| h.draining)
    }

    /// A retiring worker finished (its lease completed) and exited: drop
    /// it from the health map. Defensive: anything still leased to it —
    /// which a clean drain never leaves behind — goes back on the queue.
    pub fn worker_drained(&self, worker: WorkerId) {
        let mut st = lock_or_recover(&self.state, "master state");
        st.workers.remove(&worker);
        st.requeue_leases(worker);
    }

    /// Alive, non-draining workers — the controller's base.
    pub fn live_workers(&self) -> usize {
        let st = lock_or_recover(&self.state, "master state");
        st.workers
            .values()
            .filter(|h| h.alive && !h.draining)
            .count()
    }

    /// Worker entries still tracked in the health map (live, draining,
    /// and dead-within-grace).
    pub fn tracked_workers(&self) -> usize {
        lock_or_recover(&self.state, "master state").workers.len()
    }

    /// Splits not yet settled (queued or leased) — the controller never
    /// provisions more workers than there is work left to hand out.
    pub fn pending_splits(&self) -> usize {
        let st = lock_or_recover(&self.state, "master state");
        st.queue.len() + st.in_flight.len()
    }

    /// This session's broker-buffer hit rate (0.0 when the session is
    /// not broker-attached or nothing has been served yet).
    pub fn broker_hit_rate(&self) -> f64 {
        self.broker.as_ref().map_or(0.0, |h| h.hit_rate())
    }

    /// The plan-time selectivity prior the controller was seeded with.
    pub fn prior_selectivity(&self) -> f64 {
        self.prior_selectivity
    }

    /// Feed-forward plan cost: estimated worker busy-seconds for this
    /// session given per-row stage costs — prune fraction from the
    /// enumerated plan, survival from the stripe-stat prior
    /// (`bench_autoscale` reports this next to the measured pool cost).
    pub fn planned_worker_seconds(
        &self,
        decode_secs_per_row: f64,
        process_secs_per_row: f64,
    ) -> f64 {
        let total = self.total_rows();
        let pruned = if total == 0 {
            0.0
        } else {
            1.0 - self.scheduled_rows() as f64 / total as f64
        };
        // `estimate_worker_seconds` takes delivered fraction of *all*
        // rows; the prior is survival among decoded rows.
        let overall_sel = self.prior_selectivity * (1.0 - pruned);
        estimate_worker_seconds(
            total,
            overall_sel,
            pruned,
            decode_secs_per_row,
            process_secs_per_row,
        )
    }

    /// Mark a worker dead (crash detected / drained); its in-flight splits
    /// go back on the queue — no checkpoint restore needed because
    /// Workers are stateless.
    pub fn worker_failed(&self, worker: WorkerId) {
        let mut st = lock_or_recover(&self.state, "master state");
        if let Some(h) = st.workers.get_mut(&worker) {
            h.alive = false;
        }
        st.requeue_leases(worker);
    }

    /// Requeue splits whose worker missed heartbeats past `timeout`.
    pub fn reap_expired(&self, timeout: Duration) -> usize {
        let mut st = lock_or_recover(&self.state, "master state");
        let now = Instant::now();
        let dead: Vec<WorkerId> = st
            .workers
            .iter()
            .filter(|(_, h)| h.alive && now.duration_since(h.last_heartbeat) > timeout)
            .map(|(&w, _)| w)
            .collect();
        let mut requeued = 0;
        for w in dead {
            st.workers.get_mut(&w).unwrap().alive = false;
            requeued += st.requeue_leases(w);
        }
        requeued
    }

    pub fn is_done(&self) -> bool {
        let st = lock_or_recover(&self.state, "master state");
        st.queue.is_empty() && st.in_flight.is_empty()
    }

    /// (settled, total) splits — settled counts completed *and* splits
    /// pruned by stripe stats (they are work that will never be queued,
    /// not silently-missing work).
    pub fn progress(&self) -> (usize, usize) {
        let st = lock_or_recover(&self.state, "master state");
        (st.completed.len() + st.skipped.len(), st.all.len())
    }

    /// Splits pruned at enumeration time by stripe-stat pushdown.
    pub fn skipped_splits(&self) -> usize {
        lock_or_recover(&self.state, "master state").skipped.len()
    }

    /// Stripes contained in those pruned splits (exact — the tail split
    /// of a file may hold fewer than `stripes_per_split`).
    pub fn skipped_split_stripes(&self) -> usize {
        let st = lock_or_recover(&self.state, "master state");
        st.all
            .values()
            .filter(|s| st.skipped.contains(&s.id))
            .map(|s| s.stripe_count)
            .sum()
    }

    pub fn total_rows(&self) -> u64 {
        let st = lock_or_recover(&self.state, "master state");
        st.all.values().map(|s| s.rows).sum()
    }

    /// Rows in splits that will actually be served (skipped splits'
    /// rows excluded).
    pub fn scheduled_rows(&self) -> u64 {
        let st = lock_or_recover(&self.state, "master state");
        st.all
            .values()
            .filter(|s| !st.skipped.contains(&s.id))
            .map(|s| s.rows)
            .sum()
    }

    // ---- Fault tolerance: checkpoint / restore ----

    pub fn checkpoint(&self) -> MasterCheckpoint {
        let st = lock_or_recover(&self.state, "master state");
        MasterCheckpoint {
            completed: st.completed.iter().map(|s| s.0).collect(),
            skipped: st.skipped.iter().map(|s| s.0).collect(),
        }
    }

    /// Rebuild a Master from a checkpoint: completed splits are not
    /// re-queued, and splits the checkpoint recorded as skipped stay
    /// skipped even if stats or the predicate since changed — restoring
    /// twice (or from a stale checkpoint) never re-serves settled work.
    pub fn restore(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        ckpt: &MasterCheckpoint,
    ) -> Result<Master> {
        let m = Master::new(catalog, cluster, spec)?;
        {
            let mut st = lock_or_recover(&m.state, "master state");
            let done: BTreeSet<SplitId> =
                ckpt.completed.iter().map(|&i| SplitId(i)).collect();
            let skipped: BTreeSet<SplitId> =
                ckpt.skipped.iter().map(|&i| SplitId(i)).collect();
            st.queue
                .retain(|id| !done.contains(id) && !skipped.contains(id));
            st.completed = done;
            st.skipped.extend(skipped);
            st.check_invariants();
        }
        Ok(m)
    }

    // ---- Auto-scaling controller ----

    /// Evaluate one scaling decision from the live pool and this tick's
    /// cumulative pipeline signals. Goal (§3.2.1): "maintain a non-zero
    /// number of buffered tensors with maximum utilization" — at the
    /// *smallest* pool that does so.
    ///
    /// The controller is a throughput model with buffer-depth safety
    /// nets: the smoothed trainer drain rate (demand, with headroom) is
    /// divided by the effective per-worker capacity — delivered rows
    /// per busy second, learned online, rescaled when the broker hit
    /// rate drifts from its learning basis (a mostly-hitting session
    /// skips fetch+decode, so each worker goes further), with the
    /// predicate-selectivity estimate seeded from stripe stats and
    /// corrected from `filtered_rows / decoded_rows`. Hysteresis: steps
    /// are bounded (`max_step_up` / `max_step_down`), a cooldown holds
    /// after every action, growth never exceeds the remaining work, and
    /// the pool never shrinks while buffers are starved.
    pub fn autoscale(&self, sig: &ScaleSignals) -> ScaleDecision {
        let p = self.policy.clone();
        let (alive, avg_buf, avg_cpu, pending) = {
            let mut st = lock_or_recover(&self.state, "master state");
            // Prune long-dead entries: the controller's base is the
            // live pool (a killed worker must not inflate proportional
            // sizing), and the map must not grow with every crash.
            let now = Instant::now();
            st.workers.retain(|_, h| {
                h.alive || now.duration_since(h.last_heartbeat) <= p.dead_grace
            });
            let live: Vec<&WorkerHealth> = st
                .workers
                .values()
                .filter(|h| h.alive && !h.draining)
                .collect();
            let n = live.len();
            let (avg_buf, avg_cpu) = if n == 0 {
                (0.0, 0.0)
            } else {
                (
                    live.iter()
                        .map(|h| h.buffered_tensors as f64)
                        .sum::<f64>()
                        / n as f64,
                    live.iter().map(|h| h.cpu_util).sum::<f64>() / n as f64,
                )
            };
            let pending = st.queue.len() + st.in_flight.len();
            (n, avg_buf, avg_cpu, pending)
        };
        let hit = self.broker_hit_rate();

        let mut c = lock_or_recover(&self.controller, "master controller");
        // Fraction of this tick's fresh client-stall time the attributor
        // blamed on worker starvation (0 when nothing stalled, or when
        // the caller doesn't feed attribution).
        let mut starved_stall_frac = 0.0;
        // ---- update estimates from cumulative signal deltas ----
        if let Some(prev) = c.prev.clone() {
            let dt = sig.wall_secs - prev.wall_secs;
            if dt > 1e-6 {
                let dstall = sig.stall_secs - prev.stall_secs;
                if dstall > 1e-6 {
                    let dstarved = (sig.stall_starved_secs
                        - prev.stall_starved_secs)
                        .max(0.0);
                    starved_stall_frac =
                        (dstarved / dstall).clamp(0.0, 1.0);
                }
                let drained =
                    sig.drained_rows.saturating_sub(prev.drained_rows);
                let rate = drained as f64 / dt;
                c.demand = if c.demand <= 0.0 {
                    rate
                } else {
                    p.alpha * rate + (1.0 - p.alpha) * c.demand
                };
                let ddec = sig.decoded_rows.saturating_sub(prev.decoded_rows);
                if ddec > 0 {
                    let dfil = sig
                        .filtered_rows
                        .saturating_sub(prev.filtered_rows)
                        .min(ddec);
                    let observed = (ddec - dfil) as f64 / ddec as f64;
                    c.selectivity = p.alpha * observed
                        + (1.0 - p.alpha) * c.selectivity;
                }
                let dbusy = sig.busy_secs - prev.busy_secs;
                let dprod =
                    sig.produced_rows.saturating_sub(prev.produced_rows);
                if dbusy > 1e-6 && dprod > 0 {
                    let cap = dprod as f64 / dbusy;
                    let share = ((sig.fetch_decode_secs
                        - prev.fetch_decode_secs)
                        / dbusy)
                        .clamp(0.0, 1.0);
                    let sel = c.selectivity;
                    if c.capacity <= 0.0 {
                        c.capacity = cap;
                        c.basis_hit = hit;
                        c.basis_sel = sel;
                        c.basis_fetch_share = share;
                    } else {
                        c.capacity =
                            p.alpha * cap + (1.0 - p.alpha) * c.capacity;
                        c.basis_hit =
                            p.alpha * hit + (1.0 - p.alpha) * c.basis_hit;
                        c.basis_sel =
                            p.alpha * sel + (1.0 - p.alpha) * c.basis_sel;
                        c.basis_fetch_share = p.alpha * share
                            + (1.0 - p.alpha) * c.basis_fetch_share;
                    }
                }
            }
        }
        c.prev = Some(sig.clone());

        // ---- throughput model ----
        let eff_cap = if c.capacity > 0.0 {
            // The learned capacity, corrected for how far the broker
            // hit rate and the selectivity estimate have drifted from
            // the conditions it was learned under.
            rescale_worker_capacity(
                c.capacity,
                c.basis_fetch_share,
                c.basis_hit,
                c.basis_sel,
                hit,
                c.selectivity,
            )
        } else {
            0.0
        };
        let model = if eff_cap > 0.0 && c.demand > 0.0 {
            // Workers needed so `target_cpu`-busy workers cover the
            // drained-rate demand with headroom.
            Some(
                (((c.demand * p.headroom) / (eff_cap * p.target_cpu)).ceil()
                    as usize)
                    .max(1),
            )
        } else {
            None
        };

        // ---- fuse with buffer-depth safety nets + hysteresis ----
        // Starved when average buffer depth is low, *or* when stall
        // attribution says trainers are losing real wall time to
        // worker starvation — the attribution path catches skew that
        // pool-wide buffer averages hide.
        let starved = avg_buf < p.min_buffered
            || starved_stall_frac > p.max_starved_stall_frac;
        let glutted =
            avg_buf > p.max_buffered && avg_cpu < p.target_cpu * 0.5;
        let mut desired = alive;
        let mut reason = "hold";
        match model {
            Some(m) if m > alive && pending > 0 => {
                desired = (alive + p.max_step_up).min(m);
                reason = "model-up";
            }
            _ if starved && pending > 0 && alive < p.max_workers => {
                // Buffers starving (or no observations yet): grow by
                // one, bounded — never proportionally.
                desired = alive + 1;
                reason = "starved-up";
            }
            Some(m) if m < alive => {
                desired = alive - (alive - m).min(p.max_step_down);
                reason = "model-down";
            }
            None if glutted => {
                desired = alive.saturating_sub(1);
                reason = "glutted-down";
            }
            _ => {}
        }
        // Never provision beyond the work that remains.
        desired = desired
            .min(pending.max(p.min_workers))
            .clamp(p.min_workers, p.max_workers);

        // Cooldown: after an action, hold for `cooldown_ticks`
        // decisions so the pipeline's response is observed before
        // acting again (the anti-flap half of the hysteresis).
        let cooling = c.cooldown > 0;
        if cooling {
            c.cooldown -= 1;
        }
        if desired != alive {
            if cooling {
                desired = alive;
                reason = "cooldown";
            } else {
                c.cooldown = p.cooldown_ticks;
            }
        }

        ScaleDecision {
            desired,
            alive,
            demand_rows_per_sec: c.demand,
            capacity_rows_per_busy_sec: eff_cap,
            selectivity: c.selectivity,
            broker_hit_rate: hit,
            reason,
        }
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        // Release any broker interest this session never consumed so
        // shared stripe buffers aren't pinned by finished sessions.
        if let Some(h) = &self.broker {
            h.broker.unregister(h.session);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dwrf::{Projection, WriterOptions};
    use crate::tectonic::ClusterConfig;
    use crate::transforms::TransformDag;

    fn setup() -> (Cluster, Catalog, SessionSpec) {
        let cluster = Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            7,
        )
        .unwrap();
        let proj: Vec<_> = h.schema.features.iter().take(8).map(|f| f.id).collect();
        let mut dag = TransformDag::default();
        for &f in &proj {
            let i = dag.input(f);
            dag.output(f, i);
        }
        let spec = SessionSpec {
            table: h.table_name,
            from_day: 0,
            to_day: 10,
            projection: Projection::new(proj),
            predicate: None,
            dag,
            batch_size: 16,
            stripes_per_split: 2,
            pipeline: Default::default(),
        };
        (cluster, catalog, spec)
    }

    #[test]
    fn master_enumerates_splits() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let (_, total) = m.progress();
        // tiny scale: 2 partitions × 64 rows, stripe 16 → 4 stripes each →
        // 2 splits per partition (2 stripes per split).
        assert_eq!(total, 4);
        assert_eq!(m.total_rows(), 128);
    }

    #[test]
    fn fetch_complete_lifecycle() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        let mut seen = Vec::new();
        while let Some(s) = m.fetch_split(w) {
            seen.push(s.id);
            m.complete_split(w, s.id);
        }
        assert_eq!(seen.len(), 4);
        assert!(m.is_done());
        assert_eq!(m.progress(), (4, 4));
    }

    #[test]
    fn failed_worker_splits_requeue() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s1 = m.fetch_split(w1).unwrap();
        let _s2 = m.fetch_split(w1).unwrap();
        m.complete_split(w1, s1.id);
        m.worker_failed(w1);
        assert!(!m.is_done());
        // A new worker picks up the orphaned split.
        let w2 = m.register_worker();
        let mut count = 0;
        while let Some(s) = m.fetch_split(w2) {
            m.complete_split(w2, s.id);
            count += 1;
        }
        assert_eq!(count, 3, "one completed + one requeued + two fresh... ");
        assert!(m.is_done());
    }

    #[test]
    fn heartbeat_timeout_reaps() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        let _ = m.fetch_split(w).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let requeued = m.reap_expired(Duration::from_millis(10));
        assert_eq!(requeued, 1);
        assert!(!m.is_done());
    }

    #[test]
    fn checkpoint_restore_skips_completed() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let w = m.register_worker();
        let s = m.fetch_split(w).unwrap();
        m.complete_split(w, s.id);
        let ckpt = m.checkpoint();
        assert_eq!(ckpt.completed.len(), 1);

        let m2 = Master::restore(&catalog, &cluster, spec, &ckpt).unwrap();
        let w2 = m2.register_worker();
        let mut remaining = 0;
        while let Some(s) = m2.fetch_split(w2) {
            m2.complete_split(w2, s.id);
            remaining += 1;
        }
        assert_eq!(remaining, 3);
        assert!(m2.is_done());
    }

    #[test]
    fn autoscaler_scales_up_on_empty_buffers() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        m.heartbeat(w, 0, 0.95, 0.4, 0.3);
        let d = m.autoscale(&ScaleSignals::default());
        assert_eq!(d.alive, 1);
        assert_eq!(d.desired, 2, "starved growth is +1, not proportional");
        assert_eq!(d.reason, "starved-up");
    }

    #[test]
    fn autoscaler_scales_down_on_idle_full_buffers() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        for _ in 0..4 {
            let w = m.register_worker();
            m.heartbeat(w, 20, 0.1, 0.2, 0.1);
        }
        let d = m.autoscale(&ScaleSignals::default());
        assert_eq!(d.desired, 3);
        assert_eq!(d.reason, "glutted-down");
    }

    #[test]
    fn autoscaler_steady_state_holds() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        m.heartbeat(w, 4, 0.8, 0.5, 0.5);
        let d = m.autoscale(&ScaleSignals::default());
        assert_eq!(d.desired, 1);
        assert_eq!(d.reason, "hold");
    }

    #[test]
    fn starved_stall_attribution_triggers_scale_up() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        // Healthy average buffer depth: the depth safety net is silent.
        m.heartbeat(w, 4, 0.8, 0.5, 0.5);
        let mut sig = ScaleSignals::default();
        let d0 = m.autoscale(&sig);
        assert_eq!(d0.reason, "hold", "no stall history yet");
        // Next tick: 80% of the fresh client-stall time is attributed
        // to worker starvation — above the 20% policy threshold.
        sig.wall_secs = 1.0;
        sig.stall_secs = 0.5;
        sig.stall_starved_secs = 0.4;
        let d1 = m.autoscale(&sig);
        assert_eq!(d1.reason, "starved-up", "attribution overrides depth");
        assert_eq!(d1.desired, 2);
    }

    #[test]
    fn autoscale_bases_on_alive_count_and_prunes_dead() {
        // Regression: the old controller was fed `workers.len()` from
        // the session loop, which still counted killed workers, so
        // proportional growth computed from an inflated base.
        let (cluster, catalog, spec) = setup();
        let mut m = Master::new(&catalog, &cluster, spec).unwrap();
        m.policy.dead_grace = Duration::from_millis(0);
        let ids: Vec<WorkerId> =
            (0..4).map(|_| m.register_worker()).collect();
        for &id in &ids {
            m.heartbeat(id, 0, 0.9, 0.4, 0.3);
        }
        m.worker_failed(ids[3]);
        assert_eq!(m.live_workers(), 3);
        let d = m.autoscale(&ScaleSignals::default());
        assert_eq!(d.alive, 3, "controller base excludes the dead worker");
        assert_eq!(d.desired, 4, "bounded +1 growth from the live base");
        assert_eq!(m.tracked_workers(), 3, "dead entry pruned after grace");
        // The pruned worker can no longer lease.
        assert!(m.fetch_split(ids[3]).is_none());
    }

    #[test]
    fn retired_worker_drains_lease_then_exits() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        let s = m.fetch_split(w).unwrap();
        assert!(m.retire_worker(w));
        assert!(m.is_draining(w));
        assert!(m.fetch_split(w).is_none(), "draining workers lease nothing");
        assert_eq!(m.live_workers(), 0);
        // The leased split still completes (drained, not requeued)...
        m.complete_split(w, s.id);
        m.worker_drained(w);
        assert_eq!(m.tracked_workers(), 0);
        // ...and the rest goes to a fresh worker; the drained split is
        // never re-served.
        let w2 = m.register_worker();
        let mut served = 0;
        while let Some(sp) = m.fetch_split(w2) {
            assert_ne!(sp.id, s.id);
            m.complete_split(w2, sp.id);
            served += 1;
        }
        assert_eq!(served, 3);
        assert!(m.is_done());
        assert!(!m.retire_worker(999), "unknown workers can't retire");
    }

    /// Synthetic plant for controller convergence: demand `demand`
    /// rows/s, per-worker capacity `cap` rows per busy second, the live
    /// pool tracking every decision instantly. Returns the desired-size
    /// history.
    fn run_plant(
        m: &Master,
        start_workers: usize,
        demand: f64,
        cap: f64,
        ticks: usize,
    ) -> Vec<usize> {
        let mut ids: Vec<WorkerId> =
            (0..start_workers).map(|_| m.register_worker()).collect();
        let mut sig = ScaleSignals::default();
        let mut history = Vec::new();
        for _ in 0..ticks {
            let capacity_total = ids.len() as f64 * cap;
            let produced_rate = capacity_total.min(demand);
            let dt = 0.1;
            sig.wall_secs += dt;
            let rows = (produced_rate * dt) as u64;
            sig.drained_rows += rows;
            sig.produced_rows += rows;
            sig.decoded_rows += rows;
            let dbusy = produced_rate * dt / cap;
            sig.busy_secs += dbusy;
            sig.fetch_decode_secs += 0.5 * dbusy;
            // Overshooting pools back up (deep buffers, idle CPUs);
            // undershooting ones starve.
            let (buf, cpu) = if capacity_total > demand * 1.05 {
                (12usize, demand / capacity_total.max(1e-9))
            } else {
                (0usize, 1.0)
            };
            for &id in &ids {
                m.heartbeat(id, buf, cpu, 0.4, 0.3);
            }
            let d = m.autoscale(&sig);
            while ids.len() < d.desired {
                ids.push(m.register_worker());
            }
            while ids.len() > d.desired {
                let id = ids.pop().unwrap();
                m.retire_worker(id);
                m.worker_drained(id);
            }
            history.push(d.desired);
        }
        history
    }

    #[test]
    fn controller_converges_from_below_without_flapping() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        // demand 1000 rows/s, 500 rows/busy-sec per worker:
        // ceil(1000 × 1.25 / (500 × 0.85)) = 3 workers.
        let history = run_plant(&m, 1, 1000.0, 500.0, 100);
        let settle = &history[40..];
        assert!(
            settle.iter().all(|&d| d == 3),
            "settled at 3, no oscillation: {history:?}"
        );
    }

    #[test]
    fn controller_converges_from_above_without_flapping() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let history = run_plant(&m, 4, 1000.0, 500.0, 100);
        let settle = &history[40..];
        assert!(
            settle.iter().all(|&d| d == 3),
            "settled at 3 from above: {history:?}"
        );
        // Hysteresis bound along the way: desired never moves by more
        // than the policy step between consecutive ticks.
        for w in history.windows(2) {
            assert!(
                w[1] as i64 - w[0] as i64 <= 2 && w[0] as i64 - w[1] as i64 <= 1,
                "step bound violated: {history:?}"
            );
        }
    }

    #[test]
    fn controller_never_outprovisions_remaining_work() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        // Only 4 splits exist: however starved the pool looks, desired
        // never exceeds the pending work.
        let history = run_plant(&m, 1, 1e9, 1.0, 60);
        assert!(
            history.iter().all(|&d| d <= 4),
            "desired capped at pending splits: {history:?}"
        );
    }

    #[test]
    fn capacity_rescale_tracks_hit_rate_and_selectivity() {
        // Learned cold with half the busy time in fetch+decode: a
        // fully-hitting session doubles per-worker capacity.
        let eff = rescale_worker_capacity(100.0, 0.5, 0.0, 1.0, 1.0, 1.0);
        assert!((eff - 200.0).abs() < 1e-6, "{eff}");
        // No drift ⇒ no rescale (the EMA already absorbed it).
        let same = rescale_worker_capacity(100.0, 0.5, 0.3, 0.7, 0.3, 0.7);
        assert!((same - 100.0).abs() < 1e-6, "{same}");
        // Monotone in the current hit rate.
        let mut last = 0.0;
        for i in 0..=10 {
            let h = i as f64 / 10.0;
            let e = rescale_worker_capacity(100.0, 0.5, 0.0, 1.0, h, 1.0);
            assert!(e >= last, "capacity must grow with hit rate");
            last = e;
        }
        // Losing a warm cache (learned hot, now cold) shrinks capacity.
        let colder = rescale_worker_capacity(100.0, 0.2, 0.9, 1.0, 0.0, 1.0);
        assert!(colder < 100.0, "{colder}");
        // A narrowing selectivity estimate (more decode per delivered
        // row) shrinks capacity; a widening one grows it.
        let narrower = rescale_worker_capacity(100.0, 0.5, 0.0, 1.0, 0.0, 0.5);
        assert!((narrower - 100.0 / 1.5).abs() < 1e-6, "{narrower}");
        let wider = rescale_worker_capacity(100.0, 0.5, 0.0, 0.5, 0.0, 1.0);
        assert!((wider - 100.0 / 0.75).abs() < 1e-6, "{wider}");
    }

    #[test]
    fn planned_worker_seconds_follows_prune_and_selectivity() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        let full = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        // Unfiltered: every row decodes and delivers.
        let base = full.planned_worker_seconds(1e-3, 1e-3);
        assert!((base - 128.0 * 2e-3).abs() < 1e-9, "{base}");
        // Fully pruned: nothing decodes, nothing delivers — zero cost.
        let none = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let pruned = Master::new(&catalog, &cluster, none).unwrap();
        assert_eq!(pruned.planned_worker_seconds(1e-3, 1e-3), 0.0);
    }

    #[test]
    fn prior_selectivity_seeds_from_stripe_stats() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        assert_eq!(m.prior_selectivity(), 1.0, "unfiltered prior");
        // A disjoint window's stats-aware prior is 0 — far sharper than
        // the stats-free TimestampRange prior of 1.0.
        let narrow = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let mn = Master::new(&catalog, &cluster, narrow).unwrap();
        assert!(mn.prior_selectivity() < 1e-9, "{}", mn.prior_selectivity());
    }

    #[test]
    fn predicate_prunes_fully_filtered_splits() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        // A timestamp window before every event: all splits prune away.
        let spec = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let w = m.register_worker();
        assert!(m.fetch_split(w).is_none(), "nothing to serve");
        assert!(m.is_done());
        assert_eq!(m.skipped_splits(), 4);
        assert_eq!(m.skipped_split_stripes(), 8);
        assert_eq!(m.progress(), (4, 4), "skipped counts as settled");
        assert_eq!(m.scheduled_rows(), 0);
        assert_eq!(m.total_rows(), 128, "accounting still sees all rows");
        // The baseline (pushdown off) still queues everything.
        let mut base = spec;
        base.pipeline.pushdown = false;
        let mb = Master::new(&catalog, &cluster, base).unwrap();
        assert_eq!(mb.skipped_splits(), 0);
        assert_eq!(mb.scheduled_rows(), 128);
    }

    #[test]
    fn checkpoint_records_skipped_and_restore_is_idempotent() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        let spec = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let ckpt = m.checkpoint();
        assert_eq!(ckpt.skipped.len(), 4);
        assert!(ckpt.completed.is_empty());

        // Restore with the *same* spec: skipped stays settled.
        let m2 =
            Master::restore(&catalog, &cluster, spec.clone(), &ckpt).unwrap();
        assert!(m2.is_done());
        assert_eq!(m2.checkpoint(), ckpt, "restore round-trips");

        // Restore with a spec that no longer prunes (predicate dropped):
        // the checkpoint's skipped record still keeps those splits
        // settled instead of silently re-queuing them.
        let mut plain = spec;
        plain.predicate = None;
        let m3 = Master::restore(&catalog, &cluster, plain, &ckpt).unwrap();
        assert!(m3.is_done(), "previously-skipped work is not re-served");
        assert_eq!(m3.skipped_splits(), 4);
    }

    #[test]
    fn unknown_table_errors() {
        let (cluster, catalog, mut spec) = setup();
        spec.table = "nope".into();
        assert!(Master::new(&catalog, &cluster, spec).is_err());
    }

    #[test]
    fn corrupt_footer_len_is_error_not_panic() {
        let (cluster, catalog, spec) = setup();
        // Craft a tail whose footer_len sits near u64::MAX: the old
        // `footer_len + 12 <= tail` guard wrapped and the start-offset
        // subtraction panicked on underflow.
        let table = catalog.get(&spec.table).unwrap();
        let src = table.partitions[0].file;
        let len = cluster.file_len(src).unwrap();
        let mut bytes = cluster
            .read_range(src, IoRange { offset: 0, len })
            .unwrap();
        let n = bytes.len();
        bytes[n - 12..n - 4].copy_from_slice(&(u64::MAX - 5).to_le_bytes());
        let bad = cluster.create("crafted/corrupt-footer.dwrf");
        cluster.append(bad, &bytes).unwrap();
        let err = Master::fetch_meta(&cluster, bad);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err())
            .contains("corrupt footer length"));
    }

    #[test]
    fn dead_or_unregistered_workers_cannot_lease() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        assert!(m.fetch_split(999).is_none(), "unregistered id refused");
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // requeues s
        // The dead worker must not lease the requeued split back.
        assert!(m.fetch_split(w1).is_none());
        let w2 = m.register_worker();
        let mut served = Vec::new();
        while let Some(sp) = m.fetch_split(w2) {
            served.push(sp.id);
            m.complete_split(w2, sp.id);
        }
        assert!(
            served.contains(&s.id),
            "requeued split goes to the live worker"
        );
        assert_eq!(served.len(), 4);
        assert!(m.is_done());
    }

    #[test]
    fn completion_after_reassignment_is_unambiguous() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // presumed dead; split requeued
        let w2 = m.register_worker();
        let s2 = m.fetch_split(w2).unwrap();
        assert_eq!(s.id, s2.id, "split reassigned to the live worker");
        // The stale worker finished after all: first completion wins...
        m.complete_split(w1, s.id);
        let settled = m.progress().0;
        // ...and the leaseholder's later report is an idempotent no-op.
        m.complete_split(w2, s.id);
        assert_eq!(m.progress().0, settled, "recorded exactly once");
        let mut rest = 0;
        while let Some(sp) = m.fetch_split(w2) {
            assert_ne!(sp.id, s.id, "settled split never re-served");
            m.complete_split(w2, sp.id);
            rest += 1;
        }
        assert_eq!(rest, 3);
        assert!(m.is_done());
    }

    #[test]
    fn stale_completion_cancels_requeue() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // split back on the queue
        m.complete_split(w1, s.id); // the "dead" worker had finished it
        let w2 = m.register_worker();
        let mut count = 0;
        while let Some(sp) = m.fetch_split(w2) {
            assert_ne!(sp.id, s.id, "completed split must not re-run");
            m.complete_split(w2, sp.id);
            count += 1;
        }
        assert_eq!(count, 3);
        assert!(m.is_done());
        assert_eq!(m.progress(), (4, 4));
    }

    #[test]
    fn shared_masters_reuse_cached_footers() {
        use crate::broker::ReadBroker;
        let (cluster, catalog, spec) = setup();
        let cluster = Arc::new(cluster);
        let broker =
            ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let m1 =
            Master::new_shared(&catalog, &cluster, spec.clone(), &broker)
                .unwrap();
        cluster.reset_stats();
        let m2 = Master::new_shared(&catalog, &cluster, spec, &broker)
            .unwrap();
        assert_eq!(
            cluster.stats().reads,
            0,
            "second session plans from cached footers"
        );
        assert_eq!(m1.progress(), m2.progress());
        assert!(m1.broker_handle().is_some());
    }
}
