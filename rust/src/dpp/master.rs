//! The DPP Master — control plane (§3.2.1): breaks the preprocessing
//! workload into splits, serves them to Workers on request, tracks
//! progress, checkpoints reader state, monitors Worker health (restarting
//! failed Workers without checkpoint restore, thanks to their stateless
//! design), and runs the auto-scaling controller.

use super::spec::SessionSpec;
use super::split::{splits_for_partition, Split, SplitId};
use crate::broker::{BrokerHandle, ReadBroker};
use crate::dwrf::{FileMeta, IoRange};
use crate::tectonic::{Cluster, FileId};
use crate::warehouse::Catalog;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub type WorkerId = usize;

/// Health/utilization report a Worker heartbeats to the Master — the
/// signals the auto-scaling controller consumes (§3.2.1: "utilization
/// (CPU, memory, and network) statistics and the number of buffered
/// tensors from each DPP Worker").
#[derive(Clone, Debug)]
pub struct WorkerHealth {
    pub last_heartbeat: Instant,
    pub buffered_tensors: usize,
    pub cpu_util: f64,
    pub mem_util: f64,
    pub net_util: f64,
    pub alive: bool,
}

impl Default for WorkerHealth {
    fn default() -> Self {
        WorkerHealth {
            last_heartbeat: Instant::now(),
            buffered_tensors: 0,
            cpu_util: 0.0,
            mem_util: 0.0,
            net_util: 0.0,
            alive: true,
        }
    }
}

/// Serializable master progress (the periodic checkpoint used to restore
/// reader state on failure).
#[derive(Clone, Debug, PartialEq)]
pub struct MasterCheckpoint {
    pub completed: Vec<u64>,
    /// Splits pruned by stripe-stat pushdown: never queued, recorded
    /// explicitly (not silently absent) so a restore with different
    /// stats or predicate still treats them as settled — restore stays
    /// idempotent.
    pub skipped: Vec<u64>,
}

struct MasterState {
    queue: VecDeque<SplitId>,
    all: HashMap<SplitId, Split>,
    in_flight: HashMap<SplitId, (WorkerId, Instant)>,
    completed: BTreeSet<SplitId>,
    /// Splits whose every stripe the footer stats prove row-free under
    /// the session predicate — skipped without any worker touching them.
    skipped: BTreeSet<SplitId>,
    workers: HashMap<WorkerId, WorkerHealth>,
    next_worker: WorkerId,
}

/// Auto-scaler targets.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// Scale up while average buffered tensors per worker is below this
    /// (buffer empty ⇒ trainers are at risk of stalling).
    pub min_buffered: f64,
    /// Scale down when buffers exceed this *and* CPUs are underutilized
    /// (wasted preprocessing capacity).
    pub max_buffered: f64,
    pub target_cpu: f64,
    pub min_workers: usize,
    pub max_workers: usize,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_buffered: 1.0,
            max_buffered: 8.0,
            target_cpu: 0.85,
            min_workers: 1,
            max_workers: 64,
        }
    }
}

pub struct Master {
    pub spec: SessionSpec,
    state: Mutex<MasterState>,
    pub policy: AutoscalePolicy,
    /// Present when this session's reads flow through a shared
    /// [`ReadBroker`] (see [`Master::new_shared`]).
    broker: Option<BrokerHandle>,
}

impl Master {
    /// Create a session: resolve the table, fetch partition footers
    /// (control-plane I/O through the same storage path), and enumerate
    /// splits.
    pub fn new(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
    ) -> Result<Master> {
        Self::build(catalog, cluster, spec, None)
    }

    /// [`Master::new`] with this session attached to a shared
    /// [`ReadBroker`]: footers come from the broker's cross-session
    /// cache (one fetch per file no matter how many sessions), and the
    /// session's planned (file, stripe) interest is registered so
    /// overlapping sessions fetch and decode each popular stripe once.
    /// Workers pick the shared path up via [`Master::broker_handle`].
    pub fn new_shared(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        broker: &Arc<ReadBroker>,
    ) -> Result<Master> {
        Self::build(catalog, cluster, spec, Some(broker))
    }

    fn build(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        broker: Option<&Arc<ReadBroker>>,
    ) -> Result<Master> {
        let table = catalog
            .get(&spec.table)
            .with_context(|| format!("unknown table {}", spec.table))?;
        let parts = table.select_partitions(spec.from_day, spec.to_day);
        if parts.is_empty() {
            bail!(
                "no partitions in [{}, {}] for {}",
                spec.from_day,
                spec.to_day,
                spec.table
            );
        }
        let mut next_id = 0u64;
        let mut all = HashMap::new();
        let mut queue = VecDeque::new();
        let mut skipped = BTreeSet::new();
        // Stats-aware split pruning: with pushdown on, a split whose
        // every stripe the footer stats prove row-free never reaches the
        // queue — fully-filtered files contribute zero live splits.
        let predicate = if spec.pipeline.pushdown {
            spec.predicate.as_ref()
        } else {
            None
        };
        // Planned (file, stripe) interest for broker registration: only
        // stripes a worker will actually fetch — whole-split prunes and
        // per-stripe prunes (the worker's plan applies the same
        // predicate to the same stats) are both excluded, so shared
        // buffers are never pinned waiting for a consumer that the
        // pushdown already proved will never come.
        let mut interest: HashMap<FileId, Vec<usize>> = HashMap::new();
        for p in parts {
            let meta: Arc<FileMeta> = match broker {
                // One cached footer per file across *all* sessions.
                Some(b) => b.footer(p.file)?,
                None => Arc::new(Self::fetch_meta(cluster, p.file)?),
            };
            let stripe_rows: Vec<u32> =
                meta.stripes.iter().map(|s| s.rows).collect();
            for split in splits_for_partition(
                &mut next_id,
                p.file,
                p.day,
                &stripe_rows,
                spec.stripes_per_split,
            ) {
                let s = split.stripe_start;
                let e = s + split.stripe_count;
                let pruned = match predicate {
                    Some(pr) => meta.stripes[s..e]
                        .iter()
                        .all(|st| pr.prunes_stripe(&st.stats, st.rows)),
                    None => false,
                };
                if pruned {
                    skipped.insert(split.id);
                } else {
                    queue.push_back(split.id);
                    if broker.is_some() {
                        let live = interest.entry(p.file).or_default();
                        for (si, st) in
                            meta.stripes[s..e].iter().enumerate()
                        {
                            let stripe_pruned = predicate.is_some_and(
                                |pr| pr.prunes_stripe(&st.stats, st.rows),
                            );
                            if !stripe_pruned {
                                live.push(s + si);
                            }
                        }
                    }
                }
                all.insert(split.id, split);
            }
        }
        let broker = broker.map(|b| BrokerHandle {
            broker: b.clone(),
            session: b.register(&spec.table, &spec.projection, interest),
        });
        Ok(Master {
            spec,
            state: Mutex::new(MasterState {
                queue,
                all,
                in_flight: HashMap::new(),
                completed: BTreeSet::new(),
                skipped,
                workers: HashMap::new(),
                next_worker: 0,
            }),
            policy: AutoscalePolicy::default(),
            broker,
        })
    }

    /// The shared-read handle workers attach to their cores (present
    /// only for [`Master::new_shared`] sessions).
    pub fn broker_handle(&self) -> Option<BrokerHandle> {
        self.broker.clone()
    }

    /// Fetch and parse a file's footer via ranged tail reads (doubling
    /// until the whole footer fits).
    pub fn fetch_meta(cluster: &Cluster, file: FileId) -> Result<FileMeta> {
        let flen = cluster.file_len(file).context("file length")?;
        let mut tail = flen.min(64 * 1024);
        loop {
            let io = IoRange {
                offset: flen - tail,
                len: tail,
            };
            let bytes = cluster.read_range(file, io)?;
            let n = bytes.len();
            if n < 12 {
                bail!("file too short");
            }
            let magic = u32::from_le_bytes(bytes[n - 4..].try_into().unwrap());
            if magic != crate::dwrf::MAGIC {
                bail!("bad DWRF magic");
            }
            let footer_len =
                u64::from_le_bytes(bytes[n - 12..n - 4].try_into().unwrap());
            // `footer_len` comes straight off storage: a corrupt value
            // near u64::MAX wraps `footer_len + 12` past this guard and
            // then underflows the start offset below.
            let Some(total) = footer_len.checked_add(12) else {
                bail!("corrupt footer length {footer_len}");
            };
            if total <= tail {
                let start = n - 12 - footer_len as usize;
                return FileMeta::decode_footer(
                    &bytes[start..n - 12],
                    flen,
                );
            }
            if tail == flen {
                bail!("footer larger than file");
            }
            tail = (tail * 2).min(flen);
        }
    }

    /// Register a new Worker; returns its id.
    pub fn register_worker(&self) -> WorkerId {
        let mut st = self.state.lock().unwrap();
        let id = st.next_worker;
        st.next_worker += 1;
        st.workers.insert(id, WorkerHealth::default());
        id
    }

    /// Worker requests the next split. `None` ⇒ no work remains *right
    /// now* (the session is done once `is_done`), or the caller is not a
    /// live registered worker — a worker already marked dead must never
    /// lease a split, or a requeued split can bounce straight back to
    /// the crashed worker id.
    pub fn fetch_split(&self, worker: WorkerId) -> Option<Split> {
        let mut st = self.state.lock().unwrap();
        if !st.workers.get(&worker).is_some_and(|h| h.alive) {
            return None;
        }
        let id = st.queue.pop_front()?;
        st.in_flight.insert(id, (worker, Instant::now()));
        Some(st.all[&id].clone())
    }

    /// Record a split completion. The first completion wins and is
    /// final, no matter who reports it: the lease (if any) is cleared —
    /// so a stale completion from a presumed-dead worker makes the
    /// current leaseholder's later report an idempotent no-op — and a
    /// pending requeue of the same split is cancelled, so settled work
    /// is never served twice.
    pub fn complete_split(&self, _worker: WorkerId, id: SplitId) {
        let mut st = self.state.lock().unwrap();
        let had_lease = st.in_flight.remove(&id).is_some();
        if !st.completed.insert(id) {
            return; // already settled — idempotent
        }
        // A stale completion can race the requeue that assumed its
        // worker died; the split is settled now, don't re-serve it. A
        // split with a live lease cannot also sit in the queue (leases
        // pop it; requeues drop the lease first), so the O(queue) scan
        // only runs on lease-less stale completions.
        if !had_lease {
            st.queue.retain(|&q| q != id);
        }
    }

    pub fn heartbeat(&self, worker: WorkerId, buffered: usize, cpu: f64, mem: f64, net: f64) {
        let mut st = self.state.lock().unwrap();
        if let Some(h) = st.workers.get_mut(&worker) {
            h.last_heartbeat = Instant::now();
            h.buffered_tensors = buffered;
            h.cpu_util = cpu;
            h.mem_util = mem;
            h.net_util = net;
            h.alive = true;
        }
    }

    /// Mark a worker dead (crash detected / drained); its in-flight splits
    /// go back on the queue — no checkpoint restore needed because
    /// Workers are stateless.
    pub fn worker_failed(&self, worker: WorkerId) {
        let mut st = self.state.lock().unwrap();
        if let Some(h) = st.workers.get_mut(&worker) {
            h.alive = false;
        }
        let orphaned: Vec<SplitId> = st
            .in_flight
            .iter()
            .filter(|(_, (w, _))| *w == worker)
            .map(|(id, _)| *id)
            .collect();
        for id in orphaned {
            st.in_flight.remove(&id);
            st.queue.push_front(id);
        }
    }

    /// Requeue splits whose worker missed heartbeats past `timeout`.
    pub fn reap_expired(&self, timeout: Duration) -> usize {
        let mut st = self.state.lock().unwrap();
        let now = Instant::now();
        let dead: Vec<WorkerId> = st
            .workers
            .iter()
            .filter(|(_, h)| h.alive && now.duration_since(h.last_heartbeat) > timeout)
            .map(|(&w, _)| w)
            .collect();
        let mut requeued = 0;
        for w in dead {
            st.workers.get_mut(&w).unwrap().alive = false;
            let orphaned: Vec<SplitId> = st
                .in_flight
                .iter()
                .filter(|(_, (wk, _))| *wk == w)
                .map(|(id, _)| *id)
                .collect();
            for id in orphaned {
                st.in_flight.remove(&id);
                st.queue.push_front(id);
                requeued += 1;
            }
        }
        requeued
    }

    pub fn is_done(&self) -> bool {
        let st = self.state.lock().unwrap();
        st.queue.is_empty() && st.in_flight.is_empty()
    }

    /// (settled, total) splits — settled counts completed *and* splits
    /// pruned by stripe stats (they are work that will never be queued,
    /// not silently-missing work).
    pub fn progress(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.completed.len() + st.skipped.len(), st.all.len())
    }

    /// Splits pruned at enumeration time by stripe-stat pushdown.
    pub fn skipped_splits(&self) -> usize {
        self.state.lock().unwrap().skipped.len()
    }

    /// Stripes contained in those pruned splits (exact — the tail split
    /// of a file may hold fewer than `stripes_per_split`).
    pub fn skipped_split_stripes(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.all
            .values()
            .filter(|s| st.skipped.contains(&s.id))
            .map(|s| s.stripe_count)
            .sum()
    }

    pub fn total_rows(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.all.values().map(|s| s.rows).sum()
    }

    /// Rows in splits that will actually be served (skipped splits'
    /// rows excluded).
    pub fn scheduled_rows(&self) -> u64 {
        let st = self.state.lock().unwrap();
        st.all
            .values()
            .filter(|s| !st.skipped.contains(&s.id))
            .map(|s| s.rows)
            .sum()
    }

    // ---- Fault tolerance: checkpoint / restore ----

    pub fn checkpoint(&self) -> MasterCheckpoint {
        let st = self.state.lock().unwrap();
        MasterCheckpoint {
            completed: st.completed.iter().map(|s| s.0).collect(),
            skipped: st.skipped.iter().map(|s| s.0).collect(),
        }
    }

    /// Rebuild a Master from a checkpoint: completed splits are not
    /// re-queued, and splits the checkpoint recorded as skipped stay
    /// skipped even if stats or the predicate since changed — restoring
    /// twice (or from a stale checkpoint) never re-serves settled work.
    pub fn restore(
        catalog: &Catalog,
        cluster: &Cluster,
        spec: SessionSpec,
        ckpt: &MasterCheckpoint,
    ) -> Result<Master> {
        let m = Master::new(catalog, cluster, spec)?;
        {
            let mut st = m.state.lock().unwrap();
            let done: BTreeSet<SplitId> =
                ckpt.completed.iter().map(|&i| SplitId(i)).collect();
            let skipped: BTreeSet<SplitId> =
                ckpt.skipped.iter().map(|&i| SplitId(i)).collect();
            st.queue
                .retain(|id| !done.contains(id) && !skipped.contains(id));
            st.completed = done;
            st.skipped.extend(skipped);
        }
        Ok(m)
    }

    // ---- Auto-scaling controller ----

    /// Evaluate a scaling decision: returns the desired worker count given
    /// live worker count and health reports. Goal (§3.2.1): maintain a
    /// non-zero number of buffered tensors with maximum utilization.
    pub fn autoscale(&self, current: usize) -> usize {
        let st = self.state.lock().unwrap();
        let alive: Vec<&WorkerHealth> =
            st.workers.values().filter(|h| h.alive).collect();
        drop_guard(&alive);
        if alive.is_empty() {
            return current.max(self.policy.min_workers);
        }
        let avg_buf: f64 = alive
            .iter()
            .map(|h| h.buffered_tensors as f64)
            .sum::<f64>()
            / alive.len() as f64;
        let avg_cpu: f64 =
            alive.iter().map(|h| h.cpu_util).sum::<f64>() / alive.len() as f64;
        let mut desired = current;
        if avg_buf < self.policy.min_buffered {
            // Trainers draining faster than workers fill: scale up
            // proportionally to the shortfall.
            let grow = ((self.policy.min_buffered - avg_buf)
                / self.policy.min_buffered
                * current as f64)
                .ceil() as usize;
            desired = current + grow.max(1);
        } else if avg_buf > self.policy.max_buffered
            && avg_cpu < self.policy.target_cpu * 0.5
        {
            desired = current.saturating_sub(1);
        }
        desired.clamp(self.policy.min_workers, self.policy.max_workers)
    }
}

impl Drop for Master {
    fn drop(&mut self) {
        // Release any broker interest this session never consumed so
        // shared stripe buffers aren't pinned by finished sessions.
        if let Some(h) = &self.broker {
            h.broker.unregister(h.session);
        }
    }
}

fn drop_guard<T>(_: &T) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dwrf::{Projection, WriterOptions};
    use crate::tectonic::ClusterConfig;
    use crate::transforms::TransformDag;

    fn setup() -> (Cluster, Catalog, SessionSpec) {
        let cluster = Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            7,
        )
        .unwrap();
        let proj: Vec<_> = h.schema.features.iter().take(8).map(|f| f.id).collect();
        let mut dag = TransformDag::default();
        for &f in &proj {
            let i = dag.input(f);
            dag.output(f, i);
        }
        let spec = SessionSpec {
            table: h.table_name,
            from_day: 0,
            to_day: 10,
            projection: Projection::new(proj),
            predicate: None,
            dag,
            batch_size: 16,
            stripes_per_split: 2,
            pipeline: Default::default(),
        };
        (cluster, catalog, spec)
    }

    #[test]
    fn master_enumerates_splits() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let (_, total) = m.progress();
        // tiny scale: 2 partitions × 64 rows, stripe 16 → 4 stripes each →
        // 2 splits per partition (2 stripes per split).
        assert_eq!(total, 4);
        assert_eq!(m.total_rows(), 128);
    }

    #[test]
    fn fetch_complete_lifecycle() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        let mut seen = Vec::new();
        while let Some(s) = m.fetch_split(w) {
            seen.push(s.id);
            m.complete_split(w, s.id);
        }
        assert_eq!(seen.len(), 4);
        assert!(m.is_done());
        assert_eq!(m.progress(), (4, 4));
    }

    #[test]
    fn failed_worker_splits_requeue() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s1 = m.fetch_split(w1).unwrap();
        let _s2 = m.fetch_split(w1).unwrap();
        m.complete_split(w1, s1.id);
        m.worker_failed(w1);
        assert!(!m.is_done());
        // A new worker picks up the orphaned split.
        let w2 = m.register_worker();
        let mut count = 0;
        while let Some(s) = m.fetch_split(w2) {
            m.complete_split(w2, s.id);
            count += 1;
        }
        assert_eq!(count, 3, "one completed + one requeued + two fresh... ");
        assert!(m.is_done());
    }

    #[test]
    fn heartbeat_timeout_reaps() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        let _ = m.fetch_split(w).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let requeued = m.reap_expired(Duration::from_millis(10));
        assert_eq!(requeued, 1);
        assert!(!m.is_done());
    }

    #[test]
    fn checkpoint_restore_skips_completed() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let w = m.register_worker();
        let s = m.fetch_split(w).unwrap();
        m.complete_split(w, s.id);
        let ckpt = m.checkpoint();
        assert_eq!(ckpt.completed.len(), 1);

        let m2 = Master::restore(&catalog, &cluster, spec, &ckpt).unwrap();
        let w2 = m2.register_worker();
        let mut remaining = 0;
        while let Some(s) = m2.fetch_split(w2) {
            m2.complete_split(w2, s.id);
            remaining += 1;
        }
        assert_eq!(remaining, 3);
        assert!(m2.is_done());
    }

    #[test]
    fn autoscaler_scales_up_on_empty_buffers() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        m.heartbeat(w, 0, 0.95, 0.4, 0.3);
        assert!(m.autoscale(1) > 1, "empty buffer must scale up");
    }

    #[test]
    fn autoscaler_scales_down_on_idle_full_buffers() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        for _ in 0..4 {
            let w = m.register_worker();
            m.heartbeat(w, 20, 0.1, 0.2, 0.1);
        }
        assert_eq!(m.autoscale(4), 3);
    }

    #[test]
    fn autoscaler_steady_state_holds() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w = m.register_worker();
        m.heartbeat(w, 4, 0.8, 0.5, 0.5);
        assert_eq!(m.autoscale(2), 2);
    }

    #[test]
    fn predicate_prunes_fully_filtered_splits() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        // A timestamp window before every event: all splits prune away.
        let spec = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let w = m.register_worker();
        assert!(m.fetch_split(w).is_none(), "nothing to serve");
        assert!(m.is_done());
        assert_eq!(m.skipped_splits(), 4);
        assert_eq!(m.skipped_split_stripes(), 8);
        assert_eq!(m.progress(), (4, 4), "skipped counts as settled");
        assert_eq!(m.scheduled_rows(), 0);
        assert_eq!(m.total_rows(), 128, "accounting still sees all rows");
        // The baseline (pushdown off) still queues everything.
        let mut base = spec;
        base.pipeline.pushdown = false;
        let mb = Master::new(&catalog, &cluster, base).unwrap();
        assert_eq!(mb.skipped_splits(), 0);
        assert_eq!(mb.scheduled_rows(), 128);
    }

    #[test]
    fn checkpoint_records_skipped_and_restore_is_idempotent() {
        use crate::filter::RowPredicate;
        let (cluster, catalog, spec) = setup();
        let spec = spec.with_predicate(RowPredicate::TimestampRange {
            min: u64::MAX - 1,
            max: u64::MAX,
        });
        let m = Master::new(&catalog, &cluster, spec.clone()).unwrap();
        let ckpt = m.checkpoint();
        assert_eq!(ckpt.skipped.len(), 4);
        assert!(ckpt.completed.is_empty());

        // Restore with the *same* spec: skipped stays settled.
        let m2 =
            Master::restore(&catalog, &cluster, spec.clone(), &ckpt).unwrap();
        assert!(m2.is_done());
        assert_eq!(m2.checkpoint(), ckpt, "restore round-trips");

        // Restore with a spec that no longer prunes (predicate dropped):
        // the checkpoint's skipped record still keeps those splits
        // settled instead of silently re-queuing them.
        let mut plain = spec;
        plain.predicate = None;
        let m3 = Master::restore(&catalog, &cluster, plain, &ckpt).unwrap();
        assert!(m3.is_done(), "previously-skipped work is not re-served");
        assert_eq!(m3.skipped_splits(), 4);
    }

    #[test]
    fn unknown_table_errors() {
        let (cluster, catalog, mut spec) = setup();
        spec.table = "nope".into();
        assert!(Master::new(&catalog, &cluster, spec).is_err());
    }

    #[test]
    fn corrupt_footer_len_is_error_not_panic() {
        let (cluster, catalog, spec) = setup();
        // Craft a tail whose footer_len sits near u64::MAX: the old
        // `footer_len + 12 <= tail` guard wrapped and the start-offset
        // subtraction panicked on underflow.
        let table = catalog.get(&spec.table).unwrap();
        let src = table.partitions[0].file;
        let len = cluster.file_len(src).unwrap();
        let mut bytes = cluster
            .read_range(src, IoRange { offset: 0, len })
            .unwrap();
        let n = bytes.len();
        bytes[n - 12..n - 4].copy_from_slice(&(u64::MAX - 5).to_le_bytes());
        let bad = cluster.create("crafted/corrupt-footer.dwrf");
        cluster.append(bad, &bytes).unwrap();
        let err = Master::fetch_meta(&cluster, bad);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err())
            .contains("corrupt footer length"));
    }

    #[test]
    fn dead_or_unregistered_workers_cannot_lease() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        assert!(m.fetch_split(999).is_none(), "unregistered id refused");
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // requeues s
        // The dead worker must not lease the requeued split back.
        assert!(m.fetch_split(w1).is_none());
        let w2 = m.register_worker();
        let mut served = Vec::new();
        while let Some(sp) = m.fetch_split(w2) {
            served.push(sp.id);
            m.complete_split(w2, sp.id);
        }
        assert!(
            served.contains(&s.id),
            "requeued split goes to the live worker"
        );
        assert_eq!(served.len(), 4);
        assert!(m.is_done());
    }

    #[test]
    fn completion_after_reassignment_is_unambiguous() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // presumed dead; split requeued
        let w2 = m.register_worker();
        let s2 = m.fetch_split(w2).unwrap();
        assert_eq!(s.id, s2.id, "split reassigned to the live worker");
        // The stale worker finished after all: first completion wins...
        m.complete_split(w1, s.id);
        let settled = m.progress().0;
        // ...and the leaseholder's later report is an idempotent no-op.
        m.complete_split(w2, s.id);
        assert_eq!(m.progress().0, settled, "recorded exactly once");
        let mut rest = 0;
        while let Some(sp) = m.fetch_split(w2) {
            assert_ne!(sp.id, s.id, "settled split never re-served");
            m.complete_split(w2, sp.id);
            rest += 1;
        }
        assert_eq!(rest, 3);
        assert!(m.is_done());
    }

    #[test]
    fn stale_completion_cancels_requeue() {
        let (cluster, catalog, spec) = setup();
        let m = Master::new(&catalog, &cluster, spec).unwrap();
        let w1 = m.register_worker();
        let s = m.fetch_split(w1).unwrap();
        m.worker_failed(w1); // split back on the queue
        m.complete_split(w1, s.id); // the "dead" worker had finished it
        let w2 = m.register_worker();
        let mut count = 0;
        while let Some(sp) = m.fetch_split(w2) {
            assert_ne!(sp.id, s.id, "completed split must not re-run");
            m.complete_split(w2, sp.id);
            count += 1;
        }
        assert_eq!(count, 3);
        assert!(m.is_done());
        assert_eq!(m.progress(), (4, 4));
    }

    #[test]
    fn shared_masters_reuse_cached_footers() {
        use crate::broker::ReadBroker;
        let (cluster, catalog, spec) = setup();
        let cluster = Arc::new(cluster);
        let broker =
            ReadBroker::with_budget_bytes(cluster.clone(), 64 << 20);
        let m1 =
            Master::new_shared(&catalog, &cluster, spec.clone(), &broker)
                .unwrap();
        cluster.reset_stats();
        let m2 = Master::new_shared(&catalog, &cluster, spec, &broker)
            .unwrap();
        assert_eq!(
            cluster.stats().reads,
            0,
            "second session plans from cached footers"
        );
        assert_eq!(m1.progress(), m2.progress());
        assert!(m1.broker_handle().is_some());
    }
}
