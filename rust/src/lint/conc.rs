//! Concurrency analysis: guard-scope tracking, the crate-wide
//! lock-order graph, and the blocking-under-lock lint.
//!
//! **Guard scopes.** Each `fn` body is walked with a block stack. An
//! acquisition (`lock_or_recover`/`read_or_recover`/`write_or_recover`,
//! or a zero-argument `.lock()`/`.read()`/`.write()`) registers a live
//! guard: `let`-bound guards die at the end of their block or at an
//! explicit `drop(g)`; unbound temporaries die at the statement's `;`.
//! `wait_or_recover(&cv, g, …)` is understood as releasing and
//! reacquiring `g`'s own lock — the guard stays live, and any *other*
//! guard held across the wait is a blocking-under-lock finding.
//!
//! **Lock names.** A lock site is canonicalized to a struct-field path:
//! `&self.state` inside `impl StripeBuffer` names `StripeBuffer.state`,
//! and `&self.buf.state` inside the `LoadGuard` drop impl resolves
//! through the struct field map back to the same `StripeBuffer.state`
//! node, so aliases unify. Paths rooted at unresolvable locals fall
//! back to a file-scoped name — still a node, just without cross-file
//! unification.
//!
//! **Lock-order graph.** Acquiring B while holding A adds edge A→B.
//! Edges also propagate interprocedurally: each fn's transitively
//! acquired lock set is computed by fixpoint over a name-resolved call
//! graph. Method calls resolve through the receiver's *type* —
//! `self.m(…)` within the impl, `self.field.m(…)`/`param.m(…)` through
//! the struct field map — never by bare name, because std collections
//! share method names (`insert`, `entry`, `clone`) with crate types.
//! `Path::f(…)` calls resolve against the qualifier's impl, falling
//! back to a unique crate-wide *free* fn for module paths; bare `f(…)`
//! calls resolve only to a unique free fn. Ambiguous or local-receiver
//! calls are skipped rather than over-approximated — a deliberate
//! no-false-positives trade. Any cycle — including a self-edge, i.e. a
//! call chain that re-locks a held lock — is reported as a potential
//! deadlock.
//!
//! Known blind spots, accepted for a linter: closures are analyzed at
//! their definition site (a deferred closure captured under no lock and
//! invoked under one is invisible), and guard lifetimes follow Rust
//! 2021 drop rules only approximately: a guard binds to its `let` var
//! only when the acquire call is the whole initializer, an `if let`
//! scrutinee guard lives exactly for the conditional's block, a plain
//! `if`/`while` condition guard dies at the block's `{`, and other
//! temporaries die at the statement's `;`.

use super::parse::{base_type, FnDef, ParsedFile};
use super::Finding;
use std::collections::{BTreeMap, HashMap, HashSet};

/// A single lock acquisition site.
#[derive(Clone, Debug)]
pub struct Acquire {
    pub lock: String,
    /// The `*_or_recover` context string, when present.
    pub ctx: Option<String>,
    pub line: u32,
}

/// A lock-order edge: `to` acquired while `from` is held.
#[derive(Clone, Debug)]
pub struct Edge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: u32,
    /// Interprocedural edges carry the callee that (transitively)
    /// acquires `to`.
    pub via: Option<String>,
}

/// The crate-wide lock-order graph.
#[derive(Default, Debug)]
pub struct LockGraph {
    /// Lock name → the `*_or_recover` ctx strings seen at its sites.
    pub nodes: BTreeMap<String, HashSet<String>>,
    pub edges: Vec<Edge>,
}

/// Per-fn facts collected by the guard walk.
struct FnFacts {
    qual: String,
    owner: Option<String>,
    name: String,
    file: String,
    acquires: Vec<Acquire>,
    /// (held lock, acquired) pairs — direct same-fn nesting.
    nested: Vec<(String, Acquire)>,
    calls: Vec<CallSite>,
    /// (held locks, op description, line).
    blocking: Vec<(Vec<String>, String, u32)>,
}

struct CallSite {
    name: String,
    /// For method calls: the receiver's resolved type (`None` when the
    /// receiver is a local). For path calls: the `Type::` qualifier.
    qualifier: Option<String>,
    /// Explicit `Self::`/`self.` call (resolves even if the name is
    /// ambiguous crate-wide).
    self_call: bool,
    /// `.name(…)` method-call shape — resolves via `qualifier` only,
    /// never by the unique-name rule.
    method: bool,
    held: Vec<String>,
    line: u32,
}

struct LiveGuard {
    var: Option<String>,
    lock: String,
    depth: usize,
    alive: bool,
}

/// Sentinel guard names for condition-scoped acquisitions; never match
/// a real `drop(var)` since they aren't identifiers.
const COND_GUARD: &str = "<cond>";
const IF_LET_GUARD: &str = "<if-let>";

/// Zero-arg method names that acquire (`m.lock()`, `l.read()`, …).
const METHOD_ACQUIRE: &[&str] = &["lock", "read", "write", "try_lock"];

/// Helper fns that acquire; arg 0 is the lock, arg 1 the ctx string.
const HELPER_ACQUIRE: &[&str] =
    &["lock_or_recover", "read_or_recover", "write_or_recover"];

/// Method calls that can block the calling thread.
const BLOCKING_METHODS: &[&str] = &[
    "send",
    "recv",
    "recv_timeout",
    "wait",
    "wait_timeout",
    "accept",
    "read_to_end",
    "read_exact",
    "write_all",
    "flush",
    "sync_all",
    "open",
    "join",
];

/// `Qualifier::name` paths that block: (qualifier, name, label).
const BLOCKING_PATHS: &[(&str, &str)] = &[
    ("thread", "sleep"),
    ("thread", "park"),
    ("thread", "park_timeout"),
    ("File", "open"),
    ("File", "create"),
    ("TcpStream", "connect"),
    ("TcpListener", "bind"),
    ("fs", "read"),
    ("fs", "write"),
    ("fs", "read_to_string"),
];

/// Analyze the crate: returns concurrency findings plus the lock-order
/// graph. `files` must be the whole crate so interprocedural resolution
/// and alias unification see every impl. Files under `sync/` are
/// skipped: the facade and model checker *are* the primitive layer and
/// deliberately use raw locks.
pub fn analyze(files: &[ParsedFile]) -> (Vec<Finding>, LockGraph) {
    let mut structs: HashMap<&str, &super::parse::StructDef> =
        HashMap::new();
    for f in files {
        for s in &f.structs {
            structs.entry(s.name.as_str()).or_insert(s);
        }
    }
    let mut facts: Vec<FnFacts> = Vec::new();
    for f in files {
        if f.rel.starts_with("sync/") {
            continue;
        }
        for d in &f.fns {
            if d.is_test {
                continue;
            }
            facts.push(walk_fn(f, d, &structs));
        }
    }
    let graph = build_graph(&facts);
    let mut findings = Vec::new();
    for fx in &facts {
        for (held, op, line) in &fx.blocking {
            findings.push(Finding {
                lint: "blocking-under-lock".into(),
                file: fx.file.clone(),
                line: *line,
                msg: format!(
                    "{op} in {} while holding {}",
                    fx.qual,
                    held.join(", ")
                ),
            });
        }
    }
    findings.extend(cycle_findings(&graph));
    (findings, graph)
}

/// Resolve a lock expression (tokens of the helper's first argument,
/// e.g. `& self . buf . state`) to a canonical name.
fn name_lock(
    f: &ParsedFile,
    d: &FnDef,
    expr: &[usize],
    structs: &HashMap<&str, &super::parse::StructDef>,
) -> String {
    // Collect the leading `a.b.c` path, ignoring `&`/`mut` and
    // stopping at indexing or calls.
    let mut segs: Vec<&str> = Vec::new();
    let mut expect_ident = true;
    for &j in expr {
        let t = f.text(j);
        match t {
            "&" | "mut" | "*" => continue,
            "." if !expect_ident => {
                expect_ident = true;
                continue;
            }
            _ if f.toks[j].kind == super::lex::TokKind::Ident => {
                // Accepts both dotted arg slices (`& self . buf . state`)
                // and bare receiver chains (`self buf state`).
                segs.push(t);
                expect_ident = false;
            }
            _ => break,
        }
    }
    let fallback = || format!("{}:{}", f.rel, segs.join("."));
    let Some((&first, rest)) = segs.split_first() else {
        return format!("{}:<expr>", f.rel);
    };
    // Root type: `self` → the impl owner; a param → its declared type.
    let (root_ty, path) = if first == "self" {
        match &d.owner {
            Some(o) => (o.clone(), rest),
            None => return fallback(),
        }
    } else if let Some((_, ty)) =
        d.params.iter().find(|(n, _)| n == first)
    {
        let ty = base_type(ty);
        if ty.is_empty() || rest.is_empty() {
            return fallback();
        }
        (ty, rest)
    } else {
        return fallback();
    };
    if path.is_empty() {
        // `&self` itself is not a lock; treat as unresolved.
        return fallback();
    }
    // Walk intermediate fields through the struct map so aliases like
    // LoadGuard's `self.buf.state` land on `StripeBuffer.state`.
    let mut cur = root_ty;
    for (i, seg) in path.iter().enumerate() {
        if i + 1 == path.len() {
            return format!("{cur}.{seg}");
        }
        let next = structs
            .get(cur.as_str())
            .and_then(|s| s.fields.iter().find(|(n, _)| n == seg))
            .map(|(_, ty)| base_type(ty));
        match next {
            Some(t) if !t.is_empty() => cur = t,
            _ => return format!("{cur}.{}", path[i..].join(".")),
        }
    }
    fallback()
}

fn walk_fn(
    f: &ParsedFile,
    d: &FnDef,
    structs: &HashMap<&str, &super::parse::StructDef>,
) -> FnFacts {
    let qual = match &d.owner {
        Some(o) => format!("{o}::{}", d.name),
        None => d.name.clone(),
    };
    let mut fx = FnFacts {
        qual,
        owner: d.owner.clone(),
        name: d.name.clone(),
        file: f.rel.clone(),
        acquires: Vec::new(),
        nested: Vec::new(),
        calls: Vec::new(),
        blocking: Vec::new(),
    };
    let mut guards: Vec<LiveGuard> = Vec::new();
    let mut depth = 0usize;
    let mut stmt_let: Option<String> = None;
    // Inside an `if`/`while` condition; upgraded to a let-condition
    // (`if let`/`while let`) when the `let` keyword follows.
    let mut in_cond = false;
    let mut in_let_cond = false;
    let live =
        |gs: &[LiveGuard]| -> Vec<String> {
            gs.iter().filter(|g| g.alive).map(|g| g.lock.clone()).collect()
        };

    let (start, end) = d.body;
    let mut j = start;
    while j < end {
        if f.toks[j].is_trivia() {
            j += 1;
            continue;
        }
        let t = f.text(j);
        match t {
            "{" => {
                // A plain condition's temporaries drop before the block
                // runs; an `if let` scrutinee guard (registered one
                // level deeper) survives into it.
                for g in guards.iter_mut() {
                    if g.var.as_deref() == Some(COND_GUARD) {
                        g.alive = false;
                    }
                }
                in_cond = false;
                in_let_cond = false;
                stmt_let = None;
                depth += 1;
                j += 1;
                continue;
            }
            "}" => {
                for g in guards.iter_mut() {
                    if g.depth >= depth {
                        g.alive = false;
                    }
                }
                depth = depth.saturating_sub(1);
                stmt_let = None;
                j += 1;
                continue;
            }
            ";" => {
                for g in guards.iter_mut() {
                    if g.var.is_none() && g.depth == depth {
                        g.alive = false;
                    }
                }
                stmt_let = None;
                in_cond = false;
                in_let_cond = false;
                j += 1;
                continue;
            }
            "if" | "while" => {
                in_cond = true;
                j += 1;
                continue;
            }
            "let" => {
                if in_cond {
                    in_let_cond = true;
                }
                let mut k = f.skip_trivia(j + 1);
                if k < end && f.text(k) == "mut" {
                    k = f.skip_trivia(k + 1);
                }
                if k < end
                    && f.toks[k].kind == super::lex::TokKind::Ident
                {
                    stmt_let = Some(f.text(k).to_string());
                }
                j += 1;
                continue;
            }
            _ => {}
        }
        if f.toks[j].kind != super::lex::TokKind::Ident {
            j += 1;
            continue;
        }
        let next = f.skip_trivia(j + 1);
        let next_is = |s: &str| next < end && f.text(next) == s;

        // drop(g): explicit release.
        if t == "drop" && next_is("(") {
            let k = f.skip_trivia(next + 1);
            if k < end && f.toks[k].kind == super::lex::TokKind::Ident {
                let var = f.text(k);
                for g in guards.iter_mut() {
                    if g.var.as_deref() == Some(var) {
                        g.alive = false;
                    }
                }
            }
            j = next + 1;
            continue;
        }

        // wait_or_recover(&cv, g, "ctx"): g's lock is released and
        // reacquired; other held guards span a blocking wait.
        if t == "wait_or_recover" && next_is("(") {
            let args = split_args(f, next, end);
            let waited: Option<&str> = args.get(1).and_then(|a| {
                a.iter()
                    .find(|&&k| {
                        f.toks[k].kind == super::lex::TokKind::Ident
                    })
                    .map(|&k| f.text(k))
            });
            let waited_lock = waited.and_then(|v| {
                guards
                    .iter()
                    .find(|g| g.alive && g.var.as_deref() == Some(v))
                    .map(|g| g.lock.clone())
            });
            let others: Vec<String> = guards
                .iter()
                .filter(|g| {
                    g.alive && Some(&g.lock) != waited_lock.as_ref()
                })
                .map(|g| g.lock.clone())
                .collect();
            if !others.is_empty() {
                fx.blocking.push((
                    others,
                    "condvar wait (releases only its own lock)".into(),
                    f.toks[j].line,
                ));
            }
            j = skip_call(f, next, end);
            continue;
        }

        // Helper-form acquisition.
        if HELPER_ACQUIRE.contains(&t) && next_is("(") {
            let args = split_args(f, next, end);
            let lock = args
                .first()
                .map(|a| name_lock(f, d, a, structs))
                .unwrap_or_else(|| format!("{}:<expr>", f.rel));
            let ctx = args.get(1).and_then(|a| {
                a.iter()
                    .find(|&&k| {
                        f.toks[k].kind == super::lex::TokKind::Str
                    })
                    .map(|&k| f.text(k).trim_matches('"').to_string())
            });
            let past = skip_call(f, next, end);
            let (var, gdepth) = guard_binding(
                f, past, end, &stmt_let, in_cond, in_let_cond, depth,
            );
            register_acquire(
                &mut fx, &mut guards, var, lock, ctx, gdepth,
                f.toks[j].line,
            );
            j = past;
            continue;
        }

        // Method-form acquisition: recv.lock() / recv.read() /
        // recv.write() with empty parens.
        let prev = prev_sig(f, start, j);
        let prev_is_dot = prev.is_some_and(|p| f.text(p) == ".");
        if METHOD_ACQUIRE.contains(&t) && prev_is_dot && next_is("(") {
            let after = f.skip_trivia(next + 1);
            if after < end && f.text(after) == ")" {
                let expr = receiver_chain(f, start, prev.unwrap());
                let lock = name_lock(f, d, &expr, structs);
                let (var, gdepth) = guard_binding(
                    f,
                    after + 1,
                    end,
                    &stmt_let,
                    in_cond,
                    in_let_cond,
                    depth,
                );
                register_acquire(
                    &mut fx, &mut guards, var, lock, None, gdepth,
                    f.toks[j].line,
                );
                j = after + 1;
                continue;
            }
        }

        // Blocking operations under a live guard.
        let held = live(&guards);
        if !held.is_empty() {
            if prev_is_dot && BLOCKING_METHODS.contains(&t) && next_is("(")
            {
                // `.join()`/`.wait(g)` etc. — but `.join(sep)` on
                // slices is string work: require zero args for join.
                let blocked = if t == "join" {
                    let a = f.skip_trivia(next + 1);
                    a < end && f.text(a) == ")"
                } else {
                    true
                };
                if blocked {
                    fx.blocking.push((
                        held.clone(),
                        format!("`.{t}(…)`"),
                        f.toks[j].line,
                    ));
                }
            } else if !prev_is_dot && next_is("(") {
                if let Some(p) = prev {
                    if f.text(p) == ":" {
                        if let Some(q) = path_qualifier(f, start, p) {
                            if BLOCKING_PATHS
                                .iter()
                                .any(|(pq, pn)| *pq == q && *pn == t)
                            {
                                fx.blocking.push((
                                    held.clone(),
                                    format!("`{q}::{t}(…)`"),
                                    f.toks[j].line,
                                ));
                            }
                        }
                    }
                }
            }
        }

        // Call sites for interprocedural propagation.
        if next_is("(") && !is_keyword(t) {
            let (qualifier, self_call, method) = if prev_is_dot {
                let chain = receiver_chain(f, start, prev.unwrap());
                let only_self = chain.len() == 1
                    && f.text(chain[0]) == "self";
                let ty = receiver_type(f, d, &chain, structs);
                (ty, only_self, true)
            } else if prev.is_some_and(|p| f.text(p) == ":") {
                let q = path_qualifier(f, start, prev.unwrap());
                let q = q.map(|q| {
                    if q == "Self" {
                        d.owner.clone().unwrap_or(q)
                    } else {
                        q
                    }
                });
                (q.clone(), q.is_some() && q == d.owner, false)
            } else {
                (None, false, false)
            };
            fx.calls.push(CallSite {
                name: t.to_string(),
                qualifier,
                self_call,
                method,
                held: held.clone(),
                line: f.toks[j].line,
            });
        }
        j += 1;
    }
    fx
}

/// How an acquire binds. An `if let`/`while let` scrutinee guard lives
/// exactly for the conditional's block (sentinel var, one level
/// deeper); a plain condition guard dies at the block's `{`; a direct
/// `let g = acquire(…);` — where the call is the *whole* initializer —
/// binds to `g`; anything else (a `let x = acquire(…).chain()` where
/// `x` keeps only the chained result, or a bare expression) is a
/// statement temporary that dies at the `;`.
fn guard_binding(
    f: &ParsedFile,
    past_call: usize,
    end: usize,
    stmt_let: &Option<String>,
    in_cond: bool,
    in_let_cond: bool,
    depth: usize,
) -> (Option<String>, usize) {
    if in_let_cond {
        return (Some(IF_LET_GUARD.to_string()), depth + 1);
    }
    if in_cond {
        return (Some(COND_GUARD.to_string()), depth);
    }
    let after = f.skip_trivia(past_call);
    let whole_init = after < end && f.text(after) == ";";
    match (whole_init, stmt_let) {
        (true, Some(v)) => (Some(v.clone()), depth),
        _ => (None, depth),
    }
}

fn register_acquire(
    fx: &mut FnFacts,
    guards: &mut Vec<LiveGuard>,
    var: Option<String>,
    lock: String,
    ctx: Option<String>,
    depth: usize,
    line: u32,
) {
    let acq = Acquire {
        lock: lock.clone(),
        ctx,
        line,
    };
    for g in guards.iter() {
        if g.alive {
            fx.nested.push((g.lock.clone(), acq.clone()));
        }
    }
    // A shadowing rebind (`let g = lock(…)` with `g` already a live
    // guard) releases the old guard first. Sentinel vars never rebind.
    if let Some(v) = var.as_deref() {
        if !v.starts_with('<') {
            for g in guards.iter_mut() {
                if g.var.as_deref() == Some(v) {
                    g.alive = false;
                }
            }
        }
    }
    fx.acquires.push(acq);
    guards.push(LiveGuard {
        var,
        lock,
        depth,
        alive: true,
    });
}

/// Index of the previous non-trivia token before `j` (≥ `start`).
fn prev_sig(f: &ParsedFile, start: usize, j: usize) -> Option<usize> {
    let mut k = j;
    while k > start {
        k -= 1;
        if !f.toks[k].is_trivia() {
            return Some(k);
        }
    }
    None
}

/// Walking back from a `.` at index `dot`, collect the receiver's
/// `a.b.c` ident chain (in source order). Stops at anything fancier
/// (calls, indexing) — those receivers resolve as locals.
fn receiver_chain(f: &ParsedFile, start: usize, dot: usize) -> Vec<usize> {
    let mut chain = Vec::new();
    let mut k = dot;
    let mut expect_ident = true;
    while let Some(p) = prev_sig(f, start, k) {
        let t = f.text(p);
        if expect_ident {
            if f.toks[p].kind == super::lex::TokKind::Ident {
                chain.push(p);
                expect_ident = false;
                k = p;
                continue;
            }
            break;
        }
        if t == "." {
            expect_ident = true;
            k = p;
            continue;
        }
        break;
    }
    chain.reverse();
    chain
}

/// Resolve a receiver chain (`self.field.sub` / `param.field`) to the
/// type whose method is being called. `None` for local receivers:
/// method calls on unresolvable receivers are deliberately never
/// matched by name, because std collections share method names
/// (`insert`, `entry`, `clone`) with crate types.
fn receiver_type(
    f: &ParsedFile,
    d: &FnDef,
    chain: &[usize],
    structs: &HashMap<&str, &super::parse::StructDef>,
) -> Option<String> {
    let (&first, rest) = chain.split_first()?;
    let mut cur = if f.text(first) == "self" {
        d.owner.clone()?
    } else {
        let name = f.text(first);
        let ty = d
            .params
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, ty)| base_type(ty))?;
        if ty.is_empty() {
            return None;
        }
        ty
    };
    for &seg in rest {
        let seg = f.text(seg);
        let ty = structs
            .get(cur.as_str())
            .and_then(|s| s.fields.iter().find(|(n, _)| n == seg))
            .map(|(_, ty)| base_type(ty))?;
        if ty.is_empty() {
            return None;
        }
        cur = ty;
    }
    Some(cur)
}

/// For an ident at a `Path :: name(` call, the qualifier ident two
/// colons back (`p` is the second `:`).
fn path_qualifier<'a>(
    f: &'a ParsedFile,
    start: usize,
    p: usize,
) -> Option<&'a str> {
    let c1 = prev_sig(f, start, p)?;
    if f.text(c1) != ":" {
        return None;
    }
    let q = prev_sig(f, start, c1)?;
    (f.toks[q].kind == super::lex::TokKind::Ident).then(|| f.text(q))
}

/// Token-index lists of a call's comma-separated top-level arguments;
/// `open` is the `(`.
fn split_args(f: &ParsedFile, open: usize, end: usize) -> Vec<Vec<usize>> {
    let mut args = Vec::new();
    let mut cur = Vec::new();
    let mut depth = 0i32;
    let mut j = open;
    while j < end {
        let t = f.text(j);
        if f.toks[j].kind == super::lex::TokKind::Punct {
            match t {
                "(" | "[" | "{" => {
                    depth += 1;
                    if depth == 1 {
                        j += 1;
                        continue;
                    }
                }
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                "," if depth == 1 => {
                    args.push(std::mem::take(&mut cur));
                    j += 1;
                    continue;
                }
                _ => {}
            }
        }
        if depth >= 1 && !f.toks[j].is_trivia() {
            cur.push(j);
        }
        j += 1;
    }
    if !cur.is_empty() {
        args.push(cur);
    }
    args
}

/// Index just past a call's closing paren; `open` is the `(`.
fn skip_call(f: &ParsedFile, open: usize, end: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < end {
        if f.toks[j].kind == super::lex::TokKind::Punct {
            match f.text(j) {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return j + 1;
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    end
}

fn is_keyword(t: &str) -> bool {
    matches!(
        t,
        "if" | "while"
            | "for"
            | "match"
            | "return"
            | "loop"
            | "move"
            | "unsafe"
            | "drop"
            | "Some"
            | "Ok"
            | "Err"
            | "None"
            | "vec"
            | "assert"
            | "panic"
    )
}

/// The unique crate-wide *free* fn with this name, if any. Methods are
/// excluded: they cannot be called by bare name, and letting them match
/// would alias std method names onto crate types.
fn unique_free_fn(
    by_name: &HashMap<&str, Vec<usize>>,
    facts: &[FnFacts],
    name: &str,
) -> Option<usize> {
    match by_name.get(name) {
        Some(v) if v.len() == 1 && facts[v[0]].owner.is_none() => {
            Some(v[0])
        }
        _ => None,
    }
}

fn build_graph(facts: &[FnFacts]) -> LockGraph {
    let mut graph = LockGraph::default();
    // Nodes: every acquisition site, keyed by canonical name.
    for fx in facts {
        for a in &fx.acquires {
            let ctxs = graph.nodes.entry(a.lock.clone()).or_default();
            if let Some(c) = &a.ctx {
                ctxs.insert(c.clone());
            }
        }
    }
    // Direct edges from same-fn nesting.
    let mut seen: HashSet<(String, String, Option<String>)> =
        HashSet::new();
    for fx in facts {
        for (held, acq) in &fx.nested {
            if seen.insert((held.clone(), acq.lock.clone(), None)) {
                graph.edges.push(Edge {
                    from: held.clone(),
                    to: acq.lock.clone(),
                    file: fx.file.clone(),
                    line: acq.line,
                    via: None,
                });
            }
        }
    }
    // Interprocedural: fixpoint of transitively-acquired lock sets over
    // the resolved call graph.
    let by_owner: HashMap<(Option<&str>, &str), usize> = facts
        .iter()
        .enumerate()
        .map(|(i, fx)| ((fx.owner.as_deref(), fx.name.as_str()), i))
        .collect();
    let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, fx) in facts.iter().enumerate() {
        by_name.entry(fx.name.as_str()).or_default().push(i);
    }
    let resolve = |c: &CallSite| -> Option<usize> {
        if let Some(q) = &c.qualifier {
            let hit = by_owner
                .get(&(Some(q.as_str()), c.name.as_str()))
                .copied();
            if hit.is_some() || c.method || c.self_call {
                return hit;
            }
            // A `mod::free_fn(…)` path misses by_owner; fall through
            // to the unique-name rule, but only onto a free fn —
            // `File::create` must not resolve to a type's `create`.
            return unique_free_fn(&by_name, facts, c.name.as_str());
        }
        if c.method {
            // Method call on an unresolvable (local) receiver: skipped
            // rather than name-matched (see module docs).
            return None;
        }
        // Bare call: only a free fn can be called unqualified.
        unique_free_fn(&by_name, facts, c.name.as_str())
    };
    let mut acq_sets: Vec<HashSet<String>> = facts
        .iter()
        .map(|fx| {
            fx.acquires.iter().map(|a| a.lock.clone()).collect()
        })
        .collect();
    loop {
        let mut changed = false;
        for (i, fx) in facts.iter().enumerate() {
            for c in &fx.calls {
                let Some(t) = resolve(c) else { continue };
                if t == i {
                    continue;
                }
                let add: Vec<String> = acq_sets[t]
                    .iter()
                    .filter(|l| !acq_sets[i].contains(*l))
                    .cloned()
                    .collect();
                if !add.is_empty() {
                    changed = true;
                    acq_sets[i].extend(add);
                }
            }
        }
        if !changed {
            break;
        }
    }
    for fx in facts {
        for c in &fx.calls {
            if c.held.is_empty() {
                continue;
            }
            let Some(t) = resolve(c) else { continue };
            for to in &acq_sets[t] {
                for from in &c.held {
                    let via = Some(facts[t].qual.clone());
                    let k = (from.clone(), to.clone(), via.clone());
                    if seen.insert(k) {
                        graph.edges.push(Edge {
                            from: from.clone(),
                            to: to.clone(),
                            file: fx.file.clone(),
                            line: c.line,
                            via,
                        });
                    }
                }
            }
        }
    }
    graph
}

/// Report every cycle in the lock-order graph (incl. self-edges) as a
/// potential deadlock, one finding per strongly-connected component.
fn cycle_findings(graph: &LockGraph) -> Vec<Finding> {
    let nodes: Vec<&String> = graph.nodes.keys().collect();
    let idx: HashMap<&str, usize> = nodes
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), i))
        .collect();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for e in &graph.edges {
        if let (Some(&a), Some(&b)) =
            (idx.get(e.from.as_str()), idx.get(e.to.as_str()))
        {
            adj[a].push(b);
        }
    }
    // Tarjan SCC, iterative.
    let n = nodes.len();
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut sccs: Vec<Vec<usize>> = Vec::new();
    let mut counter = 0usize;
    for root in 0..n {
        if index[root] != usize::MAX {
            continue;
        }
        let mut work: Vec<(usize, usize)> = vec![(root, 0)];
        while let Some(&mut (v, ref mut pi)) = work.last_mut() {
            if *pi == 0 {
                index[v] = counter;
                low[v] = counter;
                counter += 1;
                stack.push(v);
                on_stack[v] = true;
            }
            if *pi < adj[v].len() {
                let w = adj[v][*pi];
                *pi += 1;
                if index[w] == usize::MAX {
                    work.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            } else {
                if low[v] == index[v] {
                    let mut scc = Vec::new();
                    while let Some(w) = stack.pop() {
                        on_stack[w] = false;
                        scc.push(w);
                        if w == v {
                            break;
                        }
                    }
                    sccs.push(scc);
                }
                work.pop();
                if let Some(&mut (u, _)) = work.last_mut() {
                    low[u] = low[u].min(low[v]);
                }
            }
        }
    }
    let mut out = Vec::new();
    for scc in sccs {
        let cyclic = scc.len() > 1
            || adj[scc[0]].contains(&scc[0]);
        if !cyclic {
            continue;
        }
        let mut names: Vec<&str> =
            scc.iter().map(|&i| nodes[i].as_str()).collect();
        names.sort_unstable();
        // Anchor the finding at one edge inside the component.
        let member: HashSet<&str> = names.iter().copied().collect();
        let site = graph
            .edges
            .iter()
            .find(|e| {
                member.contains(e.from.as_str())
                    && member.contains(e.to.as_str())
            })
            .map(|e| (e.file.clone(), e.line))
            .unwrap_or_default();
        out.push(Finding {
            lint: "lock-order-cycle".into(),
            file: site.0,
            line: site.1,
            msg: format!(
                "potential deadlock: lock-order cycle through {}",
                names.join(" -> ")
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::parse::ParsedFile;

    fn analyze_src(src: &str) -> (Vec<Finding>, LockGraph) {
        let files = vec![ParsedFile::parse("fix.rs", src.to_string())];
        analyze(&files)
    }

    fn edge_pairs(g: &LockGraph) -> Vec<(&str, &str)> {
        g.edges
            .iter()
            .map(|e| (e.from.as_str(), e.to.as_str()))
            .collect()
    }

    #[test]
    fn opposite_lock_orders_are_a_cycle() {
        let src = r#"
pub struct Pair { left: Mutex<u32>, right: Mutex<u32> }
impl Pair {
    pub fn forward(&self) {
        let _a = lock_or_recover(&self.left, "left");
        let _b = lock_or_recover(&self.right, "right");
    }
    pub fn backward(&self) {
        let _b = lock_or_recover(&self.right, "right");
        let _a = lock_or_recover(&self.left, "left");
    }
}
"#;
        let (findings, graph) = analyze_src(src);
        assert_eq!(
            edge_pairs(&graph),
            vec![
                ("Pair.left", "Pair.right"),
                ("Pair.right", "Pair.left")
            ]
        );
        let cycle = findings
            .iter()
            .find(|f| f.lint == "lock-order-cycle")
            .expect("cycle reported");
        assert!(cycle.msg.contains("Pair.left -> Pair.right"));
    }

    #[test]
    fn blocking_call_under_guard_is_flagged_at_its_line() {
        let src = r#"
pub struct Q { state: Mutex<u32> }
pub fn drain(q: &Q, rx: &Receiver<u32>) {
    let _g = lock_or_recover(&q.state, "q state");
    let _v = rx.recv();
}
"#;
        let (findings, _) = analyze_src(src);
        let f = findings
            .iter()
            .find(|f| f.lint == "blocking-under-lock")
            .expect("blocking reported");
        assert_eq!((f.file.as_str(), f.line), ("fix.rs", 5));
        assert!(f.msg.contains("Q.state"), "{}", f.msg);
    }

    /// `let x = acquire(…).chain()` keeps only the chained result: the
    /// guard is a statement temporary, not held for the rest of the fn.
    #[test]
    fn chained_initializer_guard_is_a_temporary() {
        let src = r#"
pub struct S { a: Mutex<Vec<u32>>, b: Mutex<u32> }
impl S {
    pub fn f(&self) -> usize {
        let n = lock_or_recover(&self.a, "a").len();
        let _g = lock_or_recover(&self.b, "b");
        n
    }
}
"#;
        let (findings, graph) = analyze_src(src);
        assert!(graph.edges.is_empty(), "{:?}", graph.edges);
        assert!(findings.is_empty(), "{findings:?}");
    }

    /// Rust 2021: an `if let` scrutinee temporary lives exactly for the
    /// conditional's block — held inside it, dead after it.
    #[test]
    fn if_let_scrutinee_guard_scopes_to_its_block() {
        let src = r#"
pub struct S { a: Mutex<u32>, b: Mutex<u32>, c: Mutex<u32> }
impl S {
    pub fn f(&self) -> u32 {
        if let Some(v) = lock_or_recover(&self.a, "a").checked_add(1) {
            let _g = lock_or_recover(&self.b, "b");
            return v;
        }
        let _h = lock_or_recover(&self.c, "c");
        0
    }
}
"#;
        let (_, graph) = analyze_src(src);
        assert_eq!(edge_pairs(&graph), vec![("S.a", "S.b")]);
    }

    /// A plain `if`/`while` condition temporary drops before the block
    /// body runs.
    #[test]
    fn plain_condition_guard_dies_at_the_block() {
        let src = r#"
pub struct S { a: Mutex<u32>, b: Mutex<u32> }
impl S {
    pub fn f(&self) {
        if *lock_or_recover(&self.a, "a") == 0 {
            let _g = lock_or_recover(&self.b, "b");
        }
    }
}
"#;
        let (_, graph) = analyze_src(src);
        assert!(graph.edges.is_empty(), "{:?}", graph.edges);
    }

    /// Std collections share method names with crate types; a method
    /// call on a local receiver must never resolve to a crate fn.
    #[test]
    fn local_receiver_methods_never_resolve_to_crate_fns() {
        let src = r#"
pub struct Registry { names: Mutex<u32> }
impl Registry {
    pub fn insert(&self) {
        let _g = lock_or_recover(&self.names, "names");
    }
}
pub struct Holder { m: Mutex<u32> }
impl Holder {
    pub fn run(&self) {
        let _g = lock_or_recover(&self.m, "m");
        let mut map = HashMap::new();
        map.insert(1, 2);
    }
}
"#;
        let (findings, graph) = analyze_src(src);
        assert!(
            graph.edges.is_empty(),
            "std `.insert()` aliased onto Registry::insert: {:?}",
            graph.edges
        );
        assert!(findings.is_empty(), "{findings:?}");
    }

    /// `self.field.m(…)` resolves through the field's declared type, so
    /// interprocedural edges cross impl boundaries.
    #[test]
    fn field_receiver_resolves_through_struct_types() {
        let src = r#"
pub struct Inner { bits: Mutex<u32> }
impl Inner {
    pub fn touch(&self) {
        let _g = lock_or_recover(&self.bits, "bits");
    }
}
pub struct Outer { inner: Inner, m: Mutex<u32> }
impl Outer {
    pub fn run(&self) {
        let _g = lock_or_recover(&self.m, "m");
        self.inner.touch();
    }
}
"#;
        let (_, graph) = analyze_src(src);
        assert_eq!(edge_pairs(&graph), vec![("Outer.m", "Inner.bits")]);
        assert_eq!(graph.edges[0].via.as_deref(), Some("Inner::touch"));
    }

    /// Re-locking a held lock through a callee is a self-edge, reported
    /// as a cycle.
    #[test]
    fn relocking_through_a_callee_is_a_self_edge_cycle() {
        let src = r#"
pub struct S { m: Mutex<u32> }
impl S {
    pub fn outer(&self) {
        let _g = lock_or_recover(&self.m, "m");
        self.inner_op();
    }
    pub fn inner_op(&self) {
        let _g = lock_or_recover(&self.m, "m");
    }
}
"#;
        let (findings, graph) = analyze_src(src);
        assert!(edge_pairs(&graph).contains(&("S.m", "S.m")));
        assert!(findings
            .iter()
            .any(|f| f.lint == "lock-order-cycle"
                && f.msg.contains("S.m")));
    }

    /// `Type::method(…)` path calls resolve only against that type's
    /// impl — a miss must not fall back onto a same-named method of a
    /// different type.
    #[test]
    fn qualified_miss_does_not_alias_other_types_methods() {
        let src = r#"
pub struct Cluster { files: Mutex<u32> }
impl Cluster {
    pub fn create(&self) {
        let _g = lock_or_recover(&self.files, "files");
    }
}
pub struct W { m: Mutex<u32> }
impl W {
    pub fn run(&self) {
        let _g = lock_or_recover(&self.m, "m");
        let _f = File::create("x");
    }
}
"#;
        let (_, graph) = analyze_src(src);
        assert!(
            graph.edges.is_empty(),
            "File::create aliased onto Cluster::create: {:?}",
            graph.edges
        );
    }
}
