//! Repo lints — machine-checked invariants that make "add a field,
//! forget a site" and "lock in the wrong order" CI failures instead of
//! latent bugs.
//!
//! Two generations live here:
//!
//! * **v1 invariant checks** (this module): fingerprint/clock/merge
//!   coverage, below. Cheap and textual.
//! * **v2 syntax-aware analysis** ([`lex`] → [`parse`] → [`checks`] +
//!   [`conc`]): a real tokenizer and item parser feeding convention
//!   lints (std::sync hygiene, bare lock unwraps, undocumented
//!   `Relaxed`, unchecked wire arithmetic) and concurrency analysis
//!   (guard-scope tracking, a crate-wide lock-order graph with deadlock
//!   cycle detection, blocking-under-lock). Entry point:
//!   [`run_analysis`]; findings suppress via
//!   `// dsi-lint: allow(<lint>): <reason>` comments.
//!
//! Run via the `dsi-lint` binary (`cargo run --release --bin dsi-lint`)
//! or in-process from `tests/lint.rs`. v1 checks:
//!
//! 1. **Fingerprint coverage** — every [`crate::dpp::PipelineOptions`]
//!    field is either hashed by `session_fingerprint` (dpp/cache.rs) or
//!    listed in `FINGERPRINT_EXEMPT` with a justification comment
//!    directly above its entry. Stale (hashed *and* exempt) and dangling
//!    (exempt but not a field) entries are errors too.
//! 2. **Clock coverage** — every `StageClock` field of
//!    [`crate::metrics::EtlMetrics`] is summed by `total_secs` or listed
//!    in `TOTAL_SECS_EXEMPT` with a justification.
//! 3. **Merge coverage** — for each mergeable stats struct
//!    ([`MERGE_PAIRS`]), every field appears in its `merge` body, so a
//!    counter added to the struct cannot silently vanish on aggregation.
//!    (`EtlMetrics` and `SessionReport` have no merge site — their
//!    cross-site invariant is the clock coverage above.)
//!
//! The v1 scanner is deliberately small: comments are stripped (via the
//! v2 lexer, so block comments and raw strings are handled correctly),
//! string literals are honored during brace matching, and "is this
//! field handled" means
//! "does its identifier appear in the body". That over-approximates
//! coverage (a mention in dead code would pass), which is the right
//! trade-off for a guard rail: no false alarms, and the common failure —
//! a field nobody typed anywhere — is always caught.

pub mod checks;
pub mod conc;
pub mod lex;
pub mod parse;

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::path::{Path, PathBuf};

/// One v2 finding: which lint fired, where, and why.
#[derive(Clone, Debug)]
pub struct Finding {
    pub lint: String,
    /// Path relative to the analyzed `src/` root, forward slashes.
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "src/{}:{}: [{}] {}",
            self.file, self.line, self.lint, self.msg
        )
    }
}

/// Result of the v2 analysis over a source tree.
pub struct Analysis {
    pub findings: Vec<Finding>,
    pub graph: conc::LockGraph,
}

/// The `src/` root the v2 analysis reads. `DSI_LINT_SRC_ROOT`
/// overrides it (fixture tests point it at doctored trees).
pub fn src_root(manifest_dir: &str) -> PathBuf {
    std::env::var("DSI_LINT_SRC_ROOT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(manifest_dir).join("src"))
}

/// Parse every `.rs` file under `root` (recursively, sorted for
/// deterministic output order).
pub fn load_tree(root: &Path) -> Result<Vec<parse::ParsedFile>> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)
        .with_context(|| format!("walking {}", root.display()))?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let src = std::fs::read_to_string(&p)
            .with_context(|| format!("reading {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(parse::ParsedFile::parse(&rel, src));
    }
    Ok(files)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the full v2 analysis: convention lints + concurrency analysis,
/// allowlist applied, findings sorted by location.
pub fn run_analysis(manifest_dir: &str) -> Result<Analysis> {
    let files = load_tree(&src_root(manifest_dir))?;
    let mut findings = checks::conventions(&files);
    let (conc_findings, graph) = conc::analyze(&files);
    findings.extend(conc_findings);
    let mut findings = checks::apply_allowlist(&files, findings);
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.lint).cmp(&(&b.file, b.line, &b.lint))
    });
    Ok(Analysis { findings, graph })
}

/// Machine-readable report: findings, v1 invariant errors, and the
/// full lock-order graph (nodes carry their `*_or_recover` contexts).
pub fn report_json(analysis: &Analysis, invariant_errs: &[String]) -> Json {
    let mut j = Json::obj();
    j.set("schema", "dsi-lint-v2");
    let findings: Vec<Json> = analysis
        .findings
        .iter()
        .map(|f| {
            let mut o = Json::obj();
            o.set("lint", f.lint.as_str())
                .set("file", f.file.as_str())
                .set("line", f.line)
                .set("msg", f.msg.as_str());
            o
        })
        .collect();
    j.set("findings", Json::Arr(findings));
    j.set(
        "invariant_errors",
        Json::Arr(
            invariant_errs.iter().map(|e| Json::from(e.as_str())).collect(),
        ),
    );
    let nodes: Vec<Json> = analysis
        .graph
        .nodes
        .iter()
        .map(|(name, ctxs)| {
            let mut o = Json::obj();
            let mut cs: Vec<&str> = ctxs.iter().map(String::as_str).collect();
            cs.sort_unstable();
            o.set("name", name.as_str()).set(
                "contexts",
                Json::Arr(cs.into_iter().map(Json::from).collect()),
            );
            o
        })
        .collect();
    let edges: Vec<Json> = analysis
        .graph
        .edges
        .iter()
        .map(|e| {
            let mut o = Json::obj();
            o.set("from", e.from.as_str())
                .set("to", e.to.as_str())
                .set("file", e.file.as_str())
                .set("line", e.line);
            o.set(
                "via",
                e.via.as_deref().map(Json::from).unwrap_or(Json::Null),
            );
            o
        })
        .collect();
    let mut graph = Json::obj();
    graph
        .set("nodes", Json::Arr(nodes))
        .set("edges", Json::Arr(edges));
    j.set("lock_graph", graph);
    let mut summary = Json::obj();
    summary
        .set("findings", analysis.findings.len())
        .set("invariant_errors", invariant_errs.len())
        .set("lock_nodes", analysis.graph.nodes.len())
        .set("lock_edges", analysis.graph.edges.len());
    j.set("summary", summary);
    j
}

/// The mergeable stats structs: (file under `src/`, struct name). Each
/// must have a `merge` fn in the same file covering every field.
pub const MERGE_PAIRS: &[(&str, &str)] = &[
    ("tectonic/node.rs", "IoStats"),
    ("dedup/mod.rs", "DedupStats"),
    ("transforms/dag.rs", "DagStats"),
    ("util/stats.rs", "OnlineStats"),
    ("obs/hist.rs", "Histogram"),
];

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Drop comments (line, block, doc), preserving newlines and the
/// contents of string literals. Built on the v2 lexer, so raw strings
/// and nested block comments are handled exactly.
pub fn strip_comments(src: &str) -> String {
    let mut out = String::with_capacity(src.len());
    for t in lex::lex(src) {
        let text = t.text(src);
        match t.kind {
            lex::TokKind::LineComment | lex::TokKind::BlockComment => {
                out.extend(text.chars().filter(|&c| c == '\n'));
            }
            _ => out.push_str(text),
        }
    }
    out
}

/// Offset just past `"{kw} {name}"` where `name` is a whole identifier.
fn find_decl(src: &str, kw: &str, name: &str) -> Option<usize> {
    let pat = format!("{kw} {name}");
    let mut start = 0;
    while let Some(i) = src[start..].find(&pat) {
        let at = start + i;
        let end = at + pat.len();
        let before_ok = at == 0
            || !is_ident_char(src[..at].chars().next_back().unwrap());
        let after_ok = end >= src.len()
            || !is_ident_char(src[end..].chars().next().unwrap());
        if before_ok && after_ok {
            return Some(end);
        }
        start = end;
    }
    None
}

/// Byte offsets of the first balanced `{...}` block at or after `from`.
/// String-aware; expects comment-stripped input.
fn find_block(src: &str, from: usize) -> Option<(usize, usize)> {
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escape = false;
    let mut open = None;
    for (i, c) in src[from..].char_indices() {
        let i = from + i;
        if in_str {
            if escape {
                escape = false;
            } else if c == '\\' {
                escape = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => {
                if open.is_none() {
                    open = Some(i);
                }
                depth += 1;
            }
            '}' if open.is_some() => {
                depth -= 1;
                if depth == 0 {
                    return Some((open.unwrap(), i));
                }
            }
            _ => {}
        }
    }
    None
}

/// `(field, type)` pairs of `struct name`, one field per line (the
/// repo's style). Expects comment-stripped input.
pub fn extract_struct_fields(src: &str, name: &str) -> Vec<(String, String)> {
    let Some(at) = find_decl(src, "struct", name) else {
        return Vec::new();
    };
    let Some((open, close)) = find_block(src, at) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    for line in src[open + 1..close].lines() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let t = t.strip_prefix("pub ").unwrap_or(t);
        let Some((field, ty)) = t.split_once(':') else {
            continue;
        };
        let field = field.trim();
        let valid = !field.is_empty()
            && field.chars().all(is_ident_char)
            && !field.starts_with(|c: char| c.is_ascii_digit());
        if valid {
            out.push((
                field.to_string(),
                ty.trim().trim_end_matches(',').trim().to_string(),
            ));
        }
    }
    out
}

/// Body text of `fn name` (between its braces). Expects comment-stripped
/// input; returns the *first* fn of that name in the file.
pub fn extract_fn_body(src: &str, name: &str) -> Option<String> {
    let at = find_decl(src, "fn", name)?;
    let (open, close) = find_block(src, at)?;
    Some(src[open + 1..close].to_string())
}

/// Entries of a `const NAME: &[&str] = &[...]` list as
/// `(entry, has_justification)`, where a justification is a `//` comment
/// on the line(s) directly above the entry. Takes the *raw* source —
/// the comments are the point.
pub fn extract_const_entries(
    src: &str,
    name: &str,
) -> Option<Vec<(String, bool)>> {
    let at = find_decl(src, "const", name)?;
    let eq = at + src[at..].find('=')?;
    let open = eq + src[eq..].find('[')?;
    let close = open + src[open..].find("];")?;
    let mut out = Vec::new();
    let mut prev_comment = false;
    for line in src[open + 1..close].lines() {
        let t = line.trim();
        if t.is_empty() {
            prev_comment = false;
        } else if t.starts_with("//") {
            prev_comment = true;
        } else {
            if let Some(rest) = t.strip_prefix('"') {
                if let Some(entry) = rest.split('"').next() {
                    out.push((entry.to_string(), prev_comment));
                }
            }
            prev_comment = false;
        }
    }
    Some(out)
}

/// All identifier-shaped tokens in `src`.
fn idents(src: &str) -> HashSet<String> {
    let mut out = HashSet::new();
    let mut cur = String::new();
    for c in src.chars() {
        if is_ident_char(c) {
            cur.push(c);
        } else if !cur.is_empty() {
            out.insert(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.insert(cur);
    }
    out
}

/// Shared field-vs-handler-vs-exemption logic for checks 1 and 2.
fn check_coverage(
    fields: &[String],
    handled: &HashSet<String>,
    exempt: &[(String, bool)],
    what: &str,
    site: &str,
    exempt_name: &str,
) -> Vec<String> {
    let mut errs = Vec::new();
    for f in fields {
        let in_site = handled.contains(f.as_str());
        match (in_site, exempt.iter().find(|(n, _)| n == f)) {
            (true, Some(_)) => errs.push(format!(
                "{what}.{f}: covered by {site} AND listed in \
                 {exempt_name} — drop the stale exemption"
            )),
            (true, None) | (false, Some((_, true))) => {}
            (false, Some((_, false))) => errs.push(format!(
                "{exempt_name} entry \"{f}\" has no justification \
                 comment directly above it"
            )),
            (false, None) => errs.push(format!(
                "{what}.{f}: neither covered by {site} nor exempted in \
                 {exempt_name}"
            )),
        }
    }
    for (n, _) in exempt {
        if !fields.iter().any(|f| f == n) {
            errs.push(format!(
                "{exempt_name} entry \"{n}\" is not a {what} field — \
                 dangling exemption"
            ));
        }
    }
    errs
}

/// Check 1: every `PipelineOptions` field (from `spec_src`) is hashed by
/// `session_fingerprint` or exempted in `FINGERPRINT_EXEMPT` (both in
/// `cache_src`).
pub fn check_fingerprint_coverage(
    spec_src: &str,
    cache_src: &str,
) -> Vec<String> {
    let spec = strip_comments(spec_src);
    let fields: Vec<String> = extract_struct_fields(&spec, "PipelineOptions")
        .into_iter()
        .map(|(f, _)| f)
        .collect();
    if fields.is_empty() {
        return vec!["PipelineOptions: no fields parsed".to_string()];
    }
    let cache = strip_comments(cache_src);
    let Some(body) = extract_fn_body(&cache, "session_fingerprint") else {
        return vec!["session_fingerprint: fn not found".to_string()];
    };
    let exempt =
        extract_const_entries(cache_src, "FINGERPRINT_EXEMPT")
            .unwrap_or_default();
    check_coverage(
        &fields,
        &idents(&body),
        &exempt,
        "PipelineOptions",
        "session_fingerprint",
        "FINGERPRINT_EXEMPT",
    )
}

/// Check 2: every `StageClock` field of `EtlMetrics` is summed by
/// `total_secs` or exempted in `TOTAL_SECS_EXEMPT`.
pub fn check_clock_coverage(metrics_src: &str) -> Vec<String> {
    let stripped = strip_comments(metrics_src);
    let clocks: Vec<String> = extract_struct_fields(&stripped, "EtlMetrics")
        .into_iter()
        .filter(|(_, ty)| ty.contains("StageClock"))
        .map(|(f, _)| f)
        .collect();
    if clocks.is_empty() {
        return vec!["EtlMetrics: no StageClock fields parsed".to_string()];
    }
    let Some(body) = extract_fn_body(&stripped, "total_secs") else {
        return vec!["EtlMetrics::total_secs: fn not found".to_string()];
    };
    let exempt = extract_const_entries(metrics_src, "TOTAL_SECS_EXEMPT")
        .unwrap_or_default();
    check_coverage(
        &clocks,
        &idents(&body),
        &exempt,
        "EtlMetrics",
        "total_secs",
        "TOTAL_SECS_EXEMPT",
    )
}

/// Check 3: every field of `struct_name` appears in the `merge` body in
/// the same file.
pub fn check_merge_coverage(
    src: &str,
    struct_name: &str,
    file: &str,
) -> Vec<String> {
    let stripped = strip_comments(src);
    let fields = extract_struct_fields(&stripped, struct_name);
    if fields.is_empty() {
        return vec![format!("{file}: struct {struct_name} has no fields")];
    }
    let Some(body) = extract_fn_body(&stripped, "merge") else {
        return vec![format!("{file}: {struct_name} has no merge fn")];
    };
    let ids = idents(&body);
    fields
        .iter()
        .filter(|(f, _)| !ids.contains(f.as_str()))
        .map(|(f, _)| {
            format!("{file}: {struct_name}.{f} is not handled by merge")
        })
        .collect()
}

/// Run every check against the real sources under `manifest_dir/src`.
/// `DSI_LINT_SPEC_PATH` overrides the `PipelineOptions` source file
/// (used by the fixture test to prove the lint fails on a bad spec).
pub fn run_repo_checks(manifest_dir: &str) -> Result<Vec<String>> {
    let root = Path::new(manifest_dir).join("src");
    let spec_path = std::env::var("DSI_LINT_SPEC_PATH")
        .map(PathBuf::from)
        .unwrap_or_else(|_| root.join("dpp/spec.rs"));
    let read = |p: &Path| {
        std::fs::read_to_string(p)
            .with_context(|| format!("reading {}", p.display()))
    };
    let spec_src = read(&spec_path)?;
    let cache_src = read(&root.join("dpp/cache.rs"))?;
    let metrics_src = read(&root.join("metrics/mod.rs"))?;
    let mut errs = check_fingerprint_coverage(&spec_src, &cache_src);
    errs.extend(check_clock_coverage(&metrics_src));
    for (file, name) in MERGE_PAIRS {
        errs.extend(check_merge_coverage(&read(&root.join(file))?, name, file));
    }
    Ok(errs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC_FIXTURE: &str = r#"
/// Doc comment mentioning fake_field should be ignored.
pub struct PipelineOptions {
    /// a knob
    pub alpha: bool,
    pub beta: Option<u64>,
    pub gamma: usize,
}
"#;

    #[test]
    fn strip_comments_keeps_strings_and_lines() {
        let s = "let x = \"a // not comment\"; // real\nnext";
        let out = strip_comments(s);
        assert!(out.contains("a // not comment"));
        assert!(!out.contains("real"));
        assert_eq!(out.lines().count(), 2, "newlines preserved");
    }

    #[test]
    fn struct_fields_parse_with_docs_and_attrs() {
        let fields =
            extract_struct_fields(&strip_comments(SPEC_FIXTURE), "PipelineOptions");
        let names: Vec<&str> =
            fields.iter().map(|(f, _)| f.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta", "gamma"]);
        assert_eq!(fields[1].1, "Option<u64>");
    }

    #[test]
    fn unhashed_unexempted_field_is_a_violation() {
        let cache = r#"
pub const FINGERPRINT_EXEMPT: &[&str] = &[
    // gamma never changes output bytes.
    "gamma",
];
pub fn session_fingerprint(o: &PipelineOptions) -> u64 {
    hash(o.alpha)
}
"#;
        let errs = check_fingerprint_coverage(SPEC_FIXTURE, cache);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("beta"), "{errs:?}");
    }

    #[test]
    fn exemption_without_justification_is_a_violation() {
        let cache = r#"
pub const FINGERPRINT_EXEMPT: &[&str] = &[
    // beta is a transport cap.
    "beta",
    "gamma",
];
pub fn session_fingerprint(o: &PipelineOptions) -> u64 {
    hash(o.alpha)
}
"#;
        let errs = check_fingerprint_coverage(SPEC_FIXTURE, cache);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("gamma"));
        assert!(errs[0].contains("justification"));
    }

    #[test]
    fn stale_and_dangling_exemptions_are_violations() {
        let cache = r#"
pub const FINGERPRINT_EXEMPT: &[&str] = &[
    // alpha is hashed below: stale.
    "alpha",
    // not a field at all: dangling.
    "delta",
];
pub fn session_fingerprint(o: &PipelineOptions) -> u64 {
    hash(o.alpha, o.beta, o.gamma)
}
"#;
        let errs = check_fingerprint_coverage(SPEC_FIXTURE, cache);
        assert_eq!(errs.len(), 2, "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("stale")));
        assert!(errs.iter().any(|e| e.contains("dangling")));
    }

    #[test]
    fn comment_mentions_do_not_count_as_hashing() {
        let cache = r#"
pub fn session_fingerprint(o: &PipelineOptions) -> u64 {
    // beta and gamma are deliberately not hashed (but this comment
    // must not fool the lint).
    hash(o.alpha)
}
"#;
        let errs = check_fingerprint_coverage(SPEC_FIXTURE, cache);
        assert_eq!(errs.len(), 2, "{errs:?}");
    }

    #[test]
    fn merge_gap_is_a_violation() {
        let src = r#"
pub struct S {
    pub a: u64,
    pub b: u64,
}
impl S {
    pub fn merge(&mut self, o: &S) {
        self.a += o.a;
    }
}
"#;
        let errs = check_merge_coverage(src, "S", "x.rs");
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("S.b"));
    }

    #[test]
    fn clock_gap_is_a_violation() {
        let src = r#"
pub struct EtlMetrics {
    pub bytes: Counter,
    pub t_a: StageClock,
    pub t_b: StageClock,
}
impl EtlMetrics {
    pub fn total_secs(&self) -> f64 {
        self.t_a.secs()
    }
}
"#;
        let errs = check_clock_coverage(src);
        assert_eq!(errs.len(), 1, "{errs:?}");
        assert!(errs[0].contains("t_b"), "{errs:?}");
    }
}
