//! A lightweight item/scope parser over the [`super::lex`] token
//! stream.
//!
//! This is not a Rust grammar — it recovers exactly the structure the
//! concurrency and convention lints need: which `fn` bodies exist and
//! who owns them (`impl Type`), which struct fields have which types
//! (so a lock expression like `self.buf.state` can be resolved to a
//! canonical `StripeBuffer.state` name), which `use` declarations a
//! file makes, and which regions are test-only (`#[cfg(test)]`,
//! `#[test]`, `mod tests`) so lints that deliberately exempt test code
//! can skip them.
//!
//! Anything it does not understand it skips by brace matching, so a
//! novel construct degrades to "no findings here", never to a crash or
//! a misparse of the surrounding items.

use super::lex::{lex, Tok, TokKind};

/// A `fn` item with a body.
#[derive(Debug)]
pub struct FnDef {
    pub name: String,
    /// Enclosing `impl` type (or `trait` name), if any.
    pub owner: Option<String>,
    /// `(name, type-text)` of each ordinary parameter; `self` receivers
    /// are not listed (the owner covers them).
    pub params: Vec<(String, String)>,
    /// Token-index range of the body, *exclusive* of its braces.
    pub body: (usize, usize),
    /// Inside `#[cfg(test)]` / `#[test]` / `mod tests`.
    pub is_test: bool,
    pub line: u32,
}

/// A struct with named fields: the type map for lock-path resolution.
#[derive(Debug)]
pub struct StructDef {
    pub name: String,
    /// `(field, type-text)` in declaration order.
    pub fields: Vec<(String, String)>,
}

/// One `use …;` declaration, flattened to its token text.
#[derive(Debug)]
pub struct UseDecl {
    /// The declaration's non-trivia token texts joined by one space,
    /// e.g. `use std :: sync :: { Arc , Mutex } ;`.
    pub text: String,
    pub line: u32,
    pub is_test: bool,
}

/// A parsed source file: the token stream plus the recovered items.
pub struct ParsedFile {
    /// Path relative to the source root, with `/` separators.
    pub rel: String,
    pub src: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnDef>,
    pub structs: Vec<StructDef>,
    pub uses: Vec<UseDecl>,
    /// Token-index ranges that are test-only (`#[cfg(test)]` items,
    /// `mod tests` bodies, `#[test]` fns).
    pub test_ranges: Vec<(usize, usize)>,
}

impl ParsedFile {
    pub fn parse(rel: &str, src: String) -> ParsedFile {
        let toks = lex(&src);
        let mut p = Parser {
            src: &src,
            toks: &toks,
            i: 0,
            fns: Vec::new(),
            structs: Vec::new(),
            uses: Vec::new(),
            test_ranges: Vec::new(),
        };
        p.items(None, false, toks.len());
        ParsedFile {
            rel: rel.to_string(),
            fns: p.fns,
            structs: p.structs,
            uses: p.uses,
            test_ranges: p.test_ranges,
            src,
            toks,
        }
    }

    /// True when token `i` sits inside a test-only region.
    pub fn is_test_tok(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| i >= a && i <= b)
    }

    /// Text of token `i`.
    pub fn text(&self, i: usize) -> &str {
        self.toks[i].text(&self.src)
    }

    /// Index of the next non-trivia token at or after `i`.
    pub fn skip_trivia(&self, mut i: usize) -> usize {
        while i < self.toks.len() && self.toks[i].is_trivia() {
            i += 1;
        }
        i
    }
}

struct Parser<'a> {
    src: &'a str,
    toks: &'a [Tok],
    i: usize,
    fns: Vec<FnDef>,
    structs: Vec<StructDef>,
    uses: Vec<UseDecl>,
    test_ranges: Vec<(usize, usize)>,
}

impl<'a> Parser<'a> {
    fn text(&self, i: usize) -> &'a str {
        self.toks[i].text(self.src)
    }

    fn peek(&self) -> Option<&'a str> {
        (self.i < self.toks.len()).then(|| self.text(self.i))
    }

    /// Advance past trivia; true while tokens remain.
    fn skip_trivia(&mut self) -> bool {
        while self.i < self.toks.len() && self.toks[self.i].is_trivia() {
            self.i += 1;
        }
        self.i < self.toks.len()
    }

    /// With `self.i` on an opening delimiter, return the index of its
    /// matching closer (or the last token if unbalanced).
    fn matching(&self, open: &str, close: &str) -> usize {
        let mut depth = 0usize;
        let mut j = self.i;
        while j < self.toks.len() {
            if self.toks[j].kind == TokKind::Punct {
                let t = self.text(j);
                if t == open {
                    depth += 1;
                } else if t == close {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
            }
            j += 1;
        }
        self.toks.len().saturating_sub(1)
    }

    /// Skip a balanced `<…>` generic list if one starts here. Generics
    /// nest but never contain braces/semicolons in item position, so a
    /// simple depth count is enough.
    fn skip_generics(&mut self) {
        if self.peek() != Some("<") {
            return;
        }
        let mut depth = 0i32;
        while self.i < self.toks.len() {
            match self.text(self.i) {
                "<" => depth += 1,
                ">" => {
                    depth -= 1;
                    if depth == 0 {
                        self.i += 1;
                        return;
                    }
                }
                "{" | ";" => return, // give up: not a generic list
                _ => {}
            }
            self.i += 1;
        }
    }

    /// Parse items until token index `end`, attributing them to
    /// `owner` (the enclosing impl/trait type) and `in_test`.
    fn items(&mut self, owner: Option<&str>, in_test: bool, end: usize) {
        if in_test {
            self.test_ranges.push((self.i, end));
        }
        let mut next_is_test = false;
        while self.skip_trivia() && self.i < end {
            let t = self.text(self.i);
            match t {
                "#" => {
                    // Attribute: `#[…]` (or `#![…]`). cfg(test)/test
                    // marks the next item as test-only.
                    let start = self.i;
                    self.i += 1;
                    if self.peek() == Some("!") {
                        self.i += 1;
                    }
                    if self.peek() == Some("[") {
                        let close = self.matching("[", "]");
                        let body: Vec<&str> = (start..=close)
                            .filter(|&j| !self.toks[j].is_trivia())
                            .map(|j| self.text(j))
                            .collect();
                        if body.contains(&"test") {
                            next_is_test = true;
                        }
                        self.i = close + 1;
                    }
                }
                "mod" => {
                    self.i += 1;
                    self.skip_trivia();
                    let name = self.peek().unwrap_or("").to_string();
                    self.i += 1;
                    self.skip_trivia();
                    if self.peek() == Some("{") {
                        let close = self.matching("{", "}");
                        let inner_test =
                            in_test || next_is_test || name == "tests";
                        self.i += 1;
                        self.items(owner, inner_test, close);
                        self.i = close + 1;
                    }
                    // `mod name;` falls through: file modules are
                    // parsed separately.
                    next_is_test = false;
                }
                "impl" | "trait" => {
                    let is_impl = t == "impl";
                    self.i += 1;
                    self.skip_trivia();
                    self.skip_generics();
                    // Type name: last path segment before the body (or
                    // before `<`/`for`); a `for` restarts the capture
                    // so `impl Drop for StripeBuffer` names the type,
                    // not the trait.
                    let mut name = String::new();
                    while self.skip_trivia() {
                        match self.text(self.i) {
                            "{" | ";" => break,
                            "for" => name.clear(),
                            "<" => {
                                self.skip_generics();
                                continue;
                            }
                            "where" => {
                                // Skip bounds up to the body.
                                while self.skip_trivia()
                                    && self.peek() != Some("{")
                                    && self.peek() != Some(";")
                                {
                                    self.i += 1;
                                }
                                break;
                            }
                            s if self.toks[self.i].kind == TokKind::Ident => {
                                name = s.to_string();
                            }
                            _ => {}
                        }
                        self.i += 1;
                    }
                    if self.peek() == Some("{") {
                        let close = self.matching("{", "}");
                        let scope = if is_impl || !name.is_empty() {
                            Some(name)
                        } else {
                            None
                        };
                        self.i += 1;
                        self.items(
                            scope.as_deref(),
                            in_test || next_is_test,
                            close,
                        );
                        self.i = close + 1;
                    }
                    next_is_test = false;
                }
                "fn" => {
                    self.fn_item(owner, in_test || next_is_test);
                    next_is_test = false;
                }
                "struct" => {
                    self.struct_item();
                    next_is_test = false;
                }
                "use" => {
                    let start = self.i;
                    let line = self.toks[self.i].line;
                    while self.skip_trivia() && self.peek() != Some(";") {
                        self.i += 1;
                    }
                    let text: Vec<&str> = (start..self.i)
                        .filter(|&j| !self.toks[j].is_trivia())
                        .map(|j| self.text(j))
                        .collect();
                    self.uses.push(UseDecl {
                        text: text.join(" "),
                        line,
                        is_test: in_test || next_is_test,
                    });
                    next_is_test = false;
                }
                "{" => {
                    // A stray block (e.g. a const body): recurse so
                    // nothing inside is missed, keeping scope.
                    let close = self.matching("{", "}");
                    self.i += 1;
                    self.items(owner, in_test || next_is_test, close);
                    self.i = close + 1;
                    next_is_test = false;
                }
                _ => {
                    self.i += 1;
                }
            }
        }
        self.i = end;
    }

    fn fn_item(&mut self, owner: Option<&str>, is_test: bool) {
        let line = self.toks[self.i].line;
        self.i += 1;
        self.skip_trivia();
        let name = self.peek().unwrap_or("").to_string();
        self.i += 1;
        self.skip_trivia();
        self.skip_generics();
        self.skip_trivia();
        let mut params = Vec::new();
        if self.peek() == Some("(") {
            let close = self.matching("(", ")");
            params = self.param_list(self.i + 1, close);
            self.i = close + 1;
        }
        // Skip `-> Type` and `where` clauses up to the body or `;`.
        while self.skip_trivia()
            && self.peek() != Some("{")
            && self.peek() != Some(";")
        {
            if self.peek() == Some("<") {
                self.skip_generics();
            } else {
                self.i += 1;
            }
        }
        if self.peek() == Some("{") {
            let close = self.matching("{", "}");
            self.fns.push(FnDef {
                name,
                owner: owner.map(str::to_string),
                params,
                body: (self.i + 1, close),
                is_test,
                line,
            });
            // Recurse for nested fns (closures with inner fns, test
            // helpers); they are parsed as their own items too.
            self.i += 1;
            self.items(owner, is_test, close);
            self.i = close + 1;
        } else if self.peek() == Some(";") {
            self.i += 1; // trait method declaration: no body
        }
    }

    /// `(name, type-text)` pairs between token indices `from..to`,
    /// splitting on top-level commas. `self` receivers are dropped.
    fn param_list(&self, from: usize, to: usize) -> Vec<(String, String)> {
        let mut out = Vec::new();
        let mut depth = 0i32;
        let mut start = from;
        let mut j = from;
        let flush = |s: usize, e: usize, out: &mut Vec<_>| {
            let parts: Vec<usize> = (s..e)
                .filter(|&k| !self.toks[k].is_trivia())
                .collect();
            // name : Type  (skip `mut` prefixes and self receivers)
            let mut parts = parts.as_slice();
            while let Some(&first) = parts.first() {
                if matches!(self.text(first), "mut" | "&" | "'") {
                    parts = &parts[1..];
                } else {
                    break;
                }
            }
            let Some((&first, rest)) = parts.split_first() else {
                return;
            };
            if self.text(first) == "self" {
                return;
            }
            if rest.first().map(|&k| self.text(k)) != Some(":") {
                return;
            }
            let ty: Vec<&str> =
                rest[1..].iter().map(|&k| self.text(k)).collect();
            out.push((self.text(first).to_string(), ty.join(" ")));
        };
        while j < to {
            if self.toks[j].kind == TokKind::Punct {
                match self.text(j) {
                    "(" | "[" | "{" | "<" => depth += 1,
                    ")" | "]" | "}" | ">" => depth -= 1,
                    "," if depth == 0 => {
                        flush(start, j, &mut out);
                        start = j + 1;
                    }
                    _ => {}
                }
            }
            j += 1;
        }
        flush(start, to, &mut out);
        out
    }

    fn struct_item(&mut self) {
        self.i += 1;
        self.skip_trivia();
        let name = self.peek().unwrap_or("").to_string();
        self.i += 1;
        self.skip_trivia();
        self.skip_generics();
        self.skip_trivia();
        // Only brace structs carry the field map; tuple/unit structs
        // have nothing to resolve through.
        if self.peek() != Some("{") {
            while self.skip_trivia()
                && self.peek() != Some(";")
                && self.peek() != Some("{")
            {
                self.i += 1;
            }
            if self.peek() == Some("{") {
                self.i = self.matching("{", "}") + 1;
            }
            return;
        }
        let close = self.matching("{", "}");
        let mut fields = Vec::new();
        let mut j = self.i + 1;
        while j < close {
            // Field grammar per entry: [attrs] [pub[(..)]] name : Type ,
            while j < close
                && (self.toks[j].is_trivia() || self.text(j) == ",")
            {
                j += 1;
            }
            if j >= close {
                break;
            }
            if self.text(j) == "#" {
                // Skip the attribute.
                j += 1;
                while j < close && self.toks[j].is_trivia() {
                    j += 1;
                }
                if j < close && self.text(j) == "[" {
                    let save = self.i;
                    // matching() reads self.i; emulate locally instead.
                    let mut depth = 0usize;
                    while j < close {
                        match self.text(j) {
                            "[" => depth += 1,
                            "]" => {
                                depth -= 1;
                                if depth == 0 {
                                    j += 1;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    let _ = save;
                }
                continue;
            }
            if self.text(j) == "pub" {
                j += 1;
                while j < close && self.toks[j].is_trivia() {
                    j += 1;
                }
                if j < close && self.text(j) == "(" {
                    let mut depth = 0usize;
                    while j < close {
                        match self.text(j) {
                            "(" => depth += 1,
                            ")" => {
                                depth -= 1;
                                if depth == 0 {
                                    j += 1;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                }
                continue;
            }
            // name : Type up to a top-level comma.
            let fname = self.text(j).to_string();
            j += 1;
            while j < close && self.toks[j].is_trivia() {
                j += 1;
            }
            if j >= close || self.text(j) != ":" {
                // Not a named field (unit variant in a misparse):
                // resync to the next comma.
                while j < close && self.text(j) != "," {
                    j += 1;
                }
                continue;
            }
            j += 1;
            let ty_start = j;
            let mut depth = 0i32;
            while j < close {
                if self.toks[j].kind == TokKind::Punct {
                    match self.text(j) {
                        "(" | "[" | "{" | "<" => depth += 1,
                        ")" | "]" | "}" | ">" => depth -= 1,
                        "," if depth == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            let ty: Vec<&str> = (ty_start..j)
                .filter(|&k| !self.toks[k].is_trivia())
                .map(|k| self.text(k))
                .collect();
            let ok = !fname.is_empty()
                && fname
                    .chars()
                    .all(|c| c.is_alphanumeric() || c == '_')
                && !fname.starts_with(|c: char| c.is_ascii_digit());
            if ok {
                fields.push((fname, ty.join(" ")));
            }
        }
        if !name.is_empty() {
            self.structs.push(StructDef { name, fields });
        }
        self.i = close + 1;
    }
}

/// Last path segment of a type's base struct: strips references,
/// lifetimes, `mut`, and unwraps one smart-pointer/container layer at a
/// time (`Arc<T>`, `Box<T>`, `Rc<T>`, `Option<T>`, `Vec<T>`), so
/// `& 'a Arc < StripeBuffer >` resolves to `StripeBuffer`. Returns the
/// outermost non-wrapper segment otherwise (`Mutex < BufState >` stays
/// `Mutex`: lock cells name themselves by owner+field, not by type).
pub fn base_type(ty: &str) -> String {
    let toks: Vec<&str> = ty.split_whitespace().collect();
    let mut i = 0;
    loop {
        while i < toks.len()
            && (toks[i] == "&"
                || toks[i] == "mut"
                || toks[i].starts_with('\''))
        {
            i += 1;
        }
        if i >= toks.len() {
            return String::new();
        }
        let head = toks[i];
        let wrapper =
            matches!(head, "Arc" | "Rc" | "Box" | "Option" | "Vec");
        if wrapper && toks.get(i + 1) == Some(&"<") {
            i += 2;
            continue;
        }
        // Path: a::b::C — take the last segment.
        let mut last = head;
        let mut j = i + 1;
        while toks.get(j) == Some(&":") && toks.get(j + 1) == Some(&":") {
            if let Some(seg) = toks.get(j + 2) {
                last = seg;
                j += 3;
            } else {
                break;
            }
        }
        return last.to_string();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"
use std::sync::Arc;

pub struct StripeBuffer {
    state: Mutex<BufState>,
    pub budget: MemoryBudget,
}

pub struct LoadGuard<'a> {
    buf: &'a StripeBuffer,
    key: (u64, usize),
}

impl StripeBuffer {
    pub fn serve(&self, key: u64, remaining: usize) -> u64 {
        let st = lock_or_recover(&self.state, "stripe buffer");
        key + remaining
    }
}

impl<'a> Drop for LoadGuard<'a> {
    fn drop(&mut self) {
        let st = lock_or_recover(&self.buf.state, "stripe load cleanup");
    }
}

fn free_helper(buf: &StripeBuffer, n: usize) -> usize { n }

#[cfg(test)]
mod tests {
    use std::sync::Mutex;
    #[test]
    fn t() { let _ = 1; }
}
"#;

    #[test]
    fn recovers_items_and_owners() {
        let f = ParsedFile::parse("x.rs", SRC.to_string());
        let names: Vec<(String, Option<String>, bool)> = f
            .fns
            .iter()
            .map(|d| (d.name.clone(), d.owner.clone(), d.is_test))
            .collect();
        assert!(names.contains(&(
            "serve".into(),
            Some("StripeBuffer".into()),
            false
        )));
        // Trait impl attributes the *type*, not the trait.
        assert!(names.contains(&(
            "drop".into(),
            Some("LoadGuard".into()),
            false
        )));
        assert!(names.contains(&("free_helper".into(), None, false)));
        assert!(names.contains(&("t".into(), None, true)));
    }

    #[test]
    fn recovers_struct_fields_with_types() {
        let f = ParsedFile::parse("x.rs", SRC.to_string());
        let sb = f.structs.iter().find(|s| s.name == "StripeBuffer");
        let fields = &sb.expect("StripeBuffer parsed").fields;
        assert_eq!(fields[0].0, "state");
        assert!(fields[0].1.contains("Mutex"));
        let lg = f.structs.iter().find(|s| s.name == "LoadGuard").unwrap();
        assert_eq!(base_type(&lg.fields[0].1), "StripeBuffer");
    }

    #[test]
    fn params_parse_with_types() {
        let f = ParsedFile::parse("x.rs", SRC.to_string());
        let fh = f.fns.iter().find(|d| d.name == "free_helper").unwrap();
        assert_eq!(fh.params.len(), 2);
        assert_eq!(fh.params[0].0, "buf");
        assert_eq!(base_type(&fh.params[0].1), "StripeBuffer");
    }

    #[test]
    fn use_decls_carry_test_scope() {
        let f = ParsedFile::parse("x.rs", SRC.to_string());
        assert_eq!(f.uses.len(), 2);
        assert!(!f.uses[0].is_test);
        assert!(f.uses[1].is_test, "use inside mod tests is test scope");
        assert!(f.uses[1].text.contains("Mutex"));
    }

    #[test]
    fn base_type_unwraps_wrappers() {
        assert_eq!(base_type("& 'a StripeBuffer"), "StripeBuffer");
        assert_eq!(base_type("Arc < Cluster >"), "Cluster");
        assert_eq!(base_type("Vec < Arc < Node > >"), "Node");
        assert_eq!(base_type("Mutex < BufState >"), "Mutex");
        assert_eq!(base_type("crate :: broker :: MemoryBudget"), "MemoryBudget");
    }
}
