//! Convention lints enforcing the PR 8 concurrency rules, plus the
//! justification-comment allowlist shared by every v2 lint.
//!
//! A finding is suppressed by writing, directly above the offending
//! line (or trailing on it):
//!
//! ```text
//! // dsi-lint: allow(<lint-name>): <why this site is sound>
//! ```
//!
//! The justification is mandatory and allow comments must pay their
//! way: an allow that matches no finding is itself an `unused-allow`
//! finding, so suppressions cannot rot in place when the code under
//! them changes.

use super::lex::TokKind;
use super::parse::ParsedFile;
use super::Finding;

/// `std::sync` names that must come through the `dsi::sync` facade
/// instead (the facade swaps them for instrumented shims under
/// `--cfg loom`). `Arc`/`mpsc`/`Barrier` are fine: the model checker
/// does not instrument them and the facade does not wrap them.
const BANNED_STD_SYNC: &[&str] = &[
    "Mutex",
    "RwLock",
    "Condvar",
    "MutexGuard",
    "RwLockReadGuard",
    "RwLockWriteGuard",
    "atomic",
];

/// Identifiers that carry wire- or footer-derived sizes in the decode
/// paths; arithmetic on them must be `checked_*`/`saturating_*` or
/// carry an allowlist justification.
const WIRE_SIZE_IDENTS: &[&str] =
    &["len", "offset", "off", "raw_len", "flen", "foff", "footer_len"];

/// Files whose length/offset values come from untrusted bytes.
fn wire_scope(rel: &str) -> bool {
    rel.starts_with("dwrf/")
        || rel == "dpp/transport.rs"
        || rel == "dpp/codec.rs"
}

/// How far above an `Ordering::Relaxed` use its invariant comment may
/// sit (a comment at the top of a short fn covers the fn's uses).
const RELAXED_COMMENT_REACH: u32 = 20;

/// Run every convention lint over the crate.
pub fn conventions(files: &[ParsedFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        let in_sync = f.rel.starts_with("sync/");
        if !in_sync {
            std_sync_imports(f, &mut out);
            bare_lock_unwrap(f, &mut out);
            undocumented_relaxed(f, &mut out);
        }
        if wire_scope(&f.rel) {
            unchecked_wire_arith(f, &mut out);
        }
    }
    out
}

/// Lint: no `std::sync` primitive imports (or inline paths) outside
/// `dsi::sync`.
fn std_sync_imports(f: &ParsedFile, out: &mut Vec<Finding>) {
    for u in &f.uses {
        // Covers `use std::sync::X` and the nested
        // `use std::{sync::X, …}` form alike.
        let words: Vec<&str> = u.text.split(' ').collect();
        if u.is_test
            || !words.contains(&"std")
            || !words.contains(&"sync")
        {
            continue;
        }
        if let Some(bad) =
            words.iter().find(|w| BANNED_STD_SYNC.contains(*w))
        {
            out.push(Finding {
                lint: "std-sync-import".into(),
                file: f.rel.clone(),
                line: u.line,
                msg: format!(
                    "`{bad}` imported from std::sync — route it \
                     through dsi::sync so loom models instrument it"
                ),
            });
        }
    }
    // Inline fully-qualified paths: `std :: sync :: Mutex`.
    let toks = &f.toks;
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident || f.text(i) != "std" {
            continue;
        }
        if f.is_test_tok(i) {
            continue;
        }
        let mut j = f.skip_trivia(i + 1);
        let mut path = Vec::new();
        while j < toks.len() && f.text(j) == ":" {
            j = f.skip_trivia(j + 1);
            if j < toks.len() && f.text(j) == ":" {
                j = f.skip_trivia(j + 1);
                if j < toks.len() && toks[j].kind == TokKind::Ident {
                    path.push((j, f.text(j)));
                    j = f.skip_trivia(j + 1);
                    continue;
                }
            }
            break;
        }
        if path.first().map(|&(_, t)| t) == Some("sync") {
            if let Some(&(k, bad)) = path
                .iter()
                .skip(1)
                .find(|&&(_, t)| BANNED_STD_SYNC.contains(&t))
            {
                out.push(Finding {
                    lint: "std-sync-import".into(),
                    file: f.rel.clone(),
                    line: toks[k].line,
                    msg: format!(
                        "inline `std::sync::{bad}` path — use \
                         dsi::sync"
                    ),
                });
            }
        }
    }
}

/// Lint: no bare `.lock()/.read()/.write()` followed by
/// `.unwrap()/.expect()` — production code must use the
/// poison-recovering `*_or_recover` helpers.
fn bare_lock_unwrap(f: &ParsedFile, out: &mut Vec<Finding>) {
    for i in 0..f.toks.len() {
        if f.toks[i].kind != TokKind::Ident {
            continue;
        }
        let name = f.text(i);
        if !matches!(name, "lock" | "read" | "write" | "try_lock") {
            continue;
        }
        if f.is_test_tok(i) {
            continue;
        }
        let Some(prev) = prev_sig(f, i) else { continue };
        if f.text(prev) != "." {
            continue;
        }
        // name ( ) . unwrap|expect (
        let open = f.skip_trivia(i + 1);
        if at(f, open) != Some("(") {
            continue;
        }
        let close = f.skip_trivia(open + 1);
        if at(f, close) != Some(")") {
            continue;
        }
        let dot = f.skip_trivia(close + 1);
        if at(f, dot) != Some(".") {
            continue;
        }
        let m = f.skip_trivia(dot + 1);
        let Some(mname) = at(f, m) else { continue };
        if mname == "unwrap" || mname == "expect" {
            out.push(Finding {
                lint: "bare-lock-unwrap".into(),
                file: f.rel.clone(),
                line: f.toks[i].line,
                msg: format!(
                    "bare `.{name}().{mname}()` — use the \
                     poison-recovering `*_or_recover` helper from \
                     dsi::sync"
                ),
            });
        }
    }
}

/// Lint: every `Ordering::Relaxed` carries a nearby invariant comment
/// that names "Relaxed" (within [`RELAXED_COMMENT_REACH`] lines above).
fn undocumented_relaxed(f: &ParsedFile, out: &mut Vec<Finding>) {
    // Comment lines that mention Relaxed, for the proximity test.
    let comment_lines: Vec<u32> = f
        .toks
        .iter()
        .filter(|t| {
            matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)
                && t.text(&f.src).contains("Relaxed")
        })
        .map(|t| t.line)
        .collect();
    for i in 0..f.toks.len() {
        if f.toks[i].kind != TokKind::Ident || f.text(i) != "Relaxed" {
            continue;
        }
        if f.is_test_tok(i) {
            continue;
        }
        // Require the `Ordering :: Relaxed` (or `atomic::…`) shape so a
        // stray ident named Relaxed can't trip it.
        let Some(c2) = prev_sig(f, i) else { continue };
        let Some(c1) = prev_sig(f, c2) else { continue };
        let Some(q) = prev_sig(f, c1) else { continue };
        if f.text(c2) != ":" || f.text(c1) != ":" || f.text(q) != "Ordering"
        {
            continue;
        }
        let line = f.toks[i].line;
        let documented = comment_lines.iter().any(|&cl| {
            cl <= line && cl + RELAXED_COMMENT_REACH >= line
        });
        if !documented {
            out.push(Finding {
                lint: "undocumented-relaxed".into(),
                file: f.rel.clone(),
                line,
                msg: "Ordering::Relaxed without a nearby invariant \
                      comment naming Relaxed — state why unordered \
                      access is sound here"
                    .into(),
            });
        }
    }
}

/// Lint: in wire/footer decode scope, `+`/`*` on size-carrying
/// identifiers must be `checked_*`/`saturating_*` (which carry no bare
/// operator) or allowlisted.
fn unchecked_wire_arith(f: &ParsedFile, out: &mut Vec<Finding>) {
    let mut lines_flagged = std::collections::HashSet::new();
    for i in 0..f.toks.len() {
        if f.toks[i].kind != TokKind::Punct {
            continue;
        }
        let op = f.text(i);
        if op != "+" && op != "*" {
            continue;
        }
        if f.is_test_tok(i) {
            continue;
        }
        let prev = prev_sig(f, i);
        let next = f.skip_trivia(i + 1);
        // Binary operators only: a unary `*x`/`&x` deref has a
        // non-operand token (or nothing) on its left.
        let left_operand = prev.is_some_and(|p| {
            matches!(f.toks[p].kind, TokKind::Ident | TokKind::Num)
                || matches!(f.text(p), ")" | "]")
        });
        if !left_operand {
            continue;
        }
        let mut hit = prev.and_then(|p| wire_watch(f, p));
        if hit.is_none() && next < f.toks.len() {
            if let Some(w) = wire_watch(f, next) {
                // `x + len(…)` would be a call, not a value.
                let after = f.skip_trivia(next + 1);
                if at(f, after) != Some("(") {
                    hit = Some(w);
                }
            }
        }
        let Some(w) = hit else { continue };
        let line = f.toks[i].line;
        if lines_flagged.insert(line) {
            out.push(Finding {
                lint: "unchecked-wire-arith".into(),
                file: f.rel.clone(),
                line,
                msg: format!(
                    "unchecked `{op}` on wire/footer-derived `{w}` — \
                     use checked_*/saturating_* or allowlist with a \
                     justification"
                ),
            });
        }
    }
}

fn at<'a>(f: &'a ParsedFile, i: usize) -> Option<&'a str> {
    (i < f.toks.len()).then(|| f.text(i))
}

/// Token `k` when it is one of the watched wire-size identifiers.
fn wire_watch<'a>(f: &'a ParsedFile, k: usize) -> Option<&'a str> {
    (f.toks[k].kind == TokKind::Ident
        && WIRE_SIZE_IDENTS.contains(&f.text(k)))
    .then(|| f.text(k))
}

fn prev_sig(f: &ParsedFile, i: usize) -> Option<usize> {
    let mut k = i;
    while k > 0 {
        k -= 1;
        if !f.toks[k].is_trivia() {
            return Some(k);
        }
    }
    None
}

/// One parsed `dsi-lint: allow(...)` comment.
struct Allow {
    lint: String,
    has_reason: bool,
    comment_line: u32,
    /// The line of code this allow covers.
    target_line: u32,
    used: bool,
    file: String,
}

/// Apply the allowlist: drop findings covered by a justified allow
/// comment on (or directly above) their line; surface unjustified and
/// unused allows as findings of their own.
pub fn apply_allowlist(
    files: &[ParsedFile],
    findings: Vec<Finding>,
) -> Vec<Finding> {
    let mut allows: Vec<Allow> = Vec::new();
    for f in files {
        collect_allows(f, &mut allows);
    }
    let mut out = Vec::new();
    for fi in findings {
        let suppressed = allows.iter_mut().find(|a| {
            a.has_reason
                && a.file == fi.file
                && a.lint == fi.lint
                && a.target_line == fi.line
        });
        if let Some(a) = suppressed {
            a.used = true;
        } else {
            out.push(fi);
        }
    }
    for a in &allows {
        if !a.has_reason {
            out.push(Finding {
                lint: "allow-missing-justification".into(),
                file: a.file.clone(),
                line: a.comment_line,
                msg: format!(
                    "allow({}) has no justification after the colon",
                    a.lint
                ),
            });
        } else if !a.used {
            out.push(Finding {
                lint: "unused-allow".into(),
                file: a.file.clone(),
                line: a.comment_line,
                msg: format!(
                    "allow({}) suppresses nothing on line {} — remove \
                     it or move it to the offending line",
                    a.lint, a.target_line
                ),
            });
        }
    }
    out
}

fn collect_allows(f: &ParsedFile, allows: &mut Vec<Allow>) {
    for (i, t) in f.toks.iter().enumerate() {
        if !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)
        {
            continue;
        }
        let text = t.text(&f.src);
        let Some(at) = text.find("dsi-lint: allow(") else {
            continue;
        };
        let rest = &text[at + "dsi-lint: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let lint = rest[..close].trim().to_string();
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
        // Trailing allow (code earlier on the same line) targets its
        // own line; a standalone comment targets the next code line.
        let trailing = (0..i)
            .rev()
            .take_while(|&k| f.toks[k].line == t.line)
            .any(|k| !f.toks[k].is_trivia());
        let target_line = if trailing {
            t.line
        } else {
            let mut k = i + 1;
            let mut line = t.line;
            while k < f.toks.len() {
                if !f.toks[k].is_trivia() {
                    line = f.toks[k].line;
                    break;
                }
                // Another allow/comment in between: keep scanning.
                k += 1;
            }
            line
        };
        allows.push(Allow {
            lint,
            has_reason: !reason.is_empty(),
            comment_line: t.line,
            target_line,
            used: false,
            file: f.rel.clone(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, src: &str) -> ParsedFile {
        ParsedFile::parse(rel, src.to_string())
    }

    fn lints(fs: &[Finding]) -> Vec<(&str, u32)> {
        fs.iter().map(|f| (f.lint.as_str(), f.line)).collect()
    }

    #[test]
    fn flags_std_sync_imports_outside_sync() {
        let f = file(
            "broker/mod.rs",
            "use std::sync::{Arc, Mutex};\nuse std::sync::mpsc::Receiver;\n",
        );
        let out = conventions(&[f]);
        assert_eq!(lints(&out), vec![("std-sync-import", 1)]);
        // Arc/mpsc alone are fine.
        let f = file(
            "broker/mod.rs",
            "use std::sync::Arc;\nuse std::sync::mpsc::channel;\n",
        );
        assert!(conventions(&[f]).is_empty());
        // The sync facade itself is exempt.
        let f = file("sync/mod.rs", "use std::sync::Mutex;\n");
        assert!(conventions(&[f]).is_empty());
        // Test modules may import raw primitives.
        let f = file(
            "broker/mod.rs",
            "#[cfg(test)]\nmod tests {\n    use std::sync::Barrier;\n    use std::sync::atomic::AtomicU64;\n}\n",
        );
        assert!(conventions(&[f]).is_empty());
    }

    #[test]
    fn flags_inline_std_sync_paths() {
        let f = file(
            "obs/mod.rs",
            "fn f() { let m = std::sync::Mutex::new(0); }\n",
        );
        let out = conventions(&[f]);
        assert_eq!(lints(&out), vec![("std-sync-import", 1)]);
    }

    #[test]
    fn flags_bare_lock_unwrap_outside_tests() {
        let src = "fn f(m: &Mutex<u32>) {\n    let g = m.lock().unwrap();\n}\n\
                   #[cfg(test)]\nmod tests {\n    fn t(m: &Mutex<u32>) { let g = m.lock().unwrap(); }\n}\n";
        let out = conventions(&[file("broker/x.rs", src)]);
        assert_eq!(lints(&out), vec![("bare-lock-unwrap", 2)]);
    }

    #[test]
    fn relaxed_requires_nearby_comment() {
        let bad = "fn f(c: &AtomicU64) { c.load(Ordering::Relaxed); }\n";
        let out = conventions(&[file("obs/x.rs", bad)]);
        assert_eq!(lints(&out), vec![("undocumented-relaxed", 1)]);
        let good = "// Relaxed: monotone counter, no ordering needed.\n\
                    fn f(c: &AtomicU64) { c.load(Ordering::Relaxed); }\n";
        assert!(conventions(&[file("obs/x.rs", good)]).is_empty());
        // A comment 30 lines up is too far to justify anything.
        let far = format!(
            "// Relaxed: some old rationale.\n{}fn f(c: &AtomicU64) {{ c.load(Ordering::Relaxed); }}\n",
            "\n".repeat(30)
        );
        let out = conventions(&[file("obs/x.rs", &far)]);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn wire_arith_flags_only_wire_scope() {
        let src = "fn f(offset: u64, len: u64) -> u64 { offset + len }\n";
        let out = conventions(&[file("dwrf/plan.rs", src)]);
        assert_eq!(lints(&out), vec![("unchecked-wire-arith", 1)]);
        // Same code outside the wire scope: silent.
        assert!(conventions(&[file("sched/mod.rs", src)]).is_empty());
        // Method calls and checked arithmetic don't trip it.
        let ok = "fn f(b: &[u8], offset: u64, len: u64) -> Option<u64> {\n\
                  let n = b.len() + 1;\n    offset.checked_add(len)\n}\n";
        assert!(conventions(&[file("dwrf/plan.rs", ok)]).is_empty());
    }

    #[test]
    fn allowlist_suppresses_and_requires_justification() {
        let src = "fn f(offset: u64, len: u64) -> u64 {\n\
                   // dsi-lint: allow(unchecked-wire-arith): extents validated at decode.\n\
                   offset + len\n}\n";
        let f = file("dwrf/plan.rs", src);
        let out = apply_allowlist(&[f], {
            let f = file("dwrf/plan.rs", src);
            conventions(&[f])
        });
        assert!(out.is_empty(), "{out:?}");
        // No justification → the allow itself is a finding.
        let src = "fn f(offset: u64, len: u64) -> u64 {\n\
                   // dsi-lint: allow(unchecked-wire-arith)\n\
                   offset + len\n}\n";
        let out = apply_allowlist(&[file("dwrf/plan.rs", src)], {
            conventions(&[file("dwrf/plan.rs", src)])
        });
        assert!(out
            .iter()
            .any(|x| x.lint == "allow-missing-justification"));
        assert!(out.iter().any(|x| x.lint == "unchecked-wire-arith"));
    }

    #[test]
    fn unused_allow_is_a_finding() {
        let src = "// dsi-lint: allow(bare-lock-unwrap): stale reason.\n\
                   fn f() {}\n";
        let out =
            apply_allowlist(&[file("obs/x.rs", src)], Vec::new());
        assert_eq!(lints(&out), vec![("unused-allow", 1)]);
    }

    #[test]
    fn trailing_allow_targets_its_own_line() {
        let src = "fn f(offset: u64, len: u64) -> u64 {\n\
                   offset + len // dsi-lint: allow(unchecked-wire-arith): planner-validated.\n\
                   }\n";
        let out = apply_allowlist(&[file("dwrf/plan.rs", src)], {
            conventions(&[file("dwrf/plan.rs", src)])
        });
        assert!(out.is_empty(), "{out:?}");
    }
}
