//! A minimal, total Rust tokenizer for the lint engine.
//!
//! Produces a flat token stream whose spans exactly tile the input —
//! nothing is skipped or merged, so `respell` (concatenating the spans)
//! reproduces the source byte-for-byte. That round-trip is the
//! correctness contract (property-tested in this module's tests): if a
//! string literal or comment were mis-lexed, downstream passes would
//! "see" code that is really data, which is exactly the failure mode
//! the v1 string scanner lived with.
//!
//! The lexer is total: malformed input (an unterminated string, a stray
//! byte) still lexes — the broken construct runs to end-of-file as a
//! single token. A linter must never refuse to look at a file.
//!
//! Handled beyond the obvious: nested block comments, raw strings with
//! arbitrary `#` fencing (`r##"…"##`), byte and byte-raw strings, raw
//! identifiers (`r#type`), and the `'a` lifetime vs `'a'` char-literal
//! ambiguity.

/// Token class. `Trivia` covers whitespace; comments keep their own
/// kinds because the allowlist and documented-`Relaxed` lints read them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Lifetime,
    Num,
    Str,
    Char,
    LineComment,
    BlockComment,
    Punct,
    Trivia,
}

/// One token: a half-open byte span into the source plus the 1-based
/// line its first byte sits on.
#[derive(Clone, Copy, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub start: usize,
    pub end: usize,
    pub line: u32,
}

impl Tok {
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        &src[self.start..self.end]
    }

    pub fn is_trivia(&self) -> bool {
        matches!(
            self.kind,
            TokKind::Trivia | TokKind::LineComment | TokKind::BlockComment
        )
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Lexer<'a> {
    src: &'a str,
    bytes: &'a [u8],
    pos: usize,
    line: u32,
}

impl<'a> Lexer<'a> {
    fn peek_char(&self) -> Option<char> {
        self.src[self.pos..].chars().next()
    }

    fn peek_at(&self, off: usize) -> Option<u8> {
        self.bytes.get(self.pos + off).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek_char()?;
        if c == '\n' {
            self.line += 1;
        }
        self.pos += c.len_utf8();
        Some(c)
    }

    /// Consume chars while `f` holds.
    fn eat_while(&mut self, f: impl Fn(char) -> bool) {
        while let Some(c) = self.peek_char() {
            if f(c) {
                self.bump();
            } else {
                break;
            }
        }
    }

    /// Consume a `"…"` body (opening quote already consumed), honoring
    /// `\` escapes. Unterminated strings run to EOF.
    fn eat_quoted(&mut self) {
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => return,
                _ => {}
            }
        }
    }

    /// Consume a raw-string body: `#…#"…"#…#` with `hashes` fence marks
    /// (the leading hashes and opening quote already consumed).
    fn eat_raw(&mut self, hashes: usize) {
        while let Some(c) = self.bump() {
            if c == '"' {
                let mut n = 0;
                while n < hashes && self.peek_char() == Some('#') {
                    self.bump();
                    n += 1;
                }
                if n == hashes {
                    return;
                }
            }
        }
    }

    /// True when the bytes at `pos + off` open a raw string: zero or
    /// more `#` then `"`.
    fn raw_string_ahead(&self, off: usize) -> Option<usize> {
        let mut hashes = 0;
        while self.peek_at(off + hashes) == Some(b'#') {
            hashes += 1;
        }
        (self.peek_at(off + hashes) == Some(b'"')).then_some(hashes)
    }

    fn next_token(&mut self) -> Option<Tok> {
        let start = self.pos;
        let line = self.line;
        let c = self.peek_char()?;
        let kind = match c {
            c if c.is_whitespace() => {
                self.eat_while(char::is_whitespace);
                TokKind::Trivia
            }
            '/' if self.peek_at(1) == Some(b'/') => {
                self.eat_while(|c| c != '\n');
                TokKind::LineComment
            }
            '/' if self.peek_at(1) == Some(b'*') => {
                self.bump();
                self.bump();
                let mut depth = 1usize;
                while depth > 0 {
                    match self.bump() {
                        None => break,
                        Some('*') if self.peek_char() == Some('/') => {
                            self.bump();
                            depth -= 1;
                        }
                        Some('/') if self.peek_char() == Some('*') => {
                            self.bump();
                            depth += 1;
                        }
                        Some(_) => {}
                    }
                }
                TokKind::BlockComment
            }
            '"' => {
                self.bump();
                self.eat_quoted();
                TokKind::Str
            }
            'r' | 'b' if self.string_prefix_ahead() => {
                // r"…" / r#"…"# / b"…" / br#"…"# / b'…'
                if c == 'b' && self.peek_at(1) == Some(b'\'') {
                    self.bump();
                    self.bump();
                    self.eat_char_body();
                    TokKind::Char
                } else {
                    let mut off = 1;
                    if c == 'b' && self.peek_at(1) == Some(b'r') {
                        off = 2;
                    }
                    let hashes = self.raw_string_ahead(off).unwrap_or(0);
                    for _ in 0..off + hashes + 1 {
                        self.bump();
                    }
                    self.eat_raw(hashes);
                    TokKind::Str
                }
            }
            'r' if self.peek_at(1) == Some(b'#')
                && self
                    .src[self.pos + 2..]
                    .chars()
                    .next()
                    .is_some_and(is_ident_start) =>
            {
                // Raw identifier r#type.
                self.bump();
                self.bump();
                self.eat_while(is_ident_continue);
                TokKind::Ident
            }
            '\'' => {
                self.bump();
                self.lifetime_or_char()
            }
            c if is_ident_start(c) => {
                self.eat_while(is_ident_continue);
                TokKind::Ident
            }
            c if c.is_ascii_digit() => {
                self.eat_number();
                TokKind::Num
            }
            _ => {
                self.bump();
                TokKind::Punct
            }
        };
        Some(Tok {
            kind,
            start,
            end: self.pos,
            line,
        })
    }

    /// At an `r` or `b`: does a string (or byte-char) literal start
    /// here, as opposed to an ordinary identifier like `rows`?
    fn string_prefix_ahead(&self) -> bool {
        match self.peek_at(0) {
            Some(b'r') => self.raw_string_ahead(1).is_some(),
            Some(b'b') => match self.peek_at(1) {
                Some(b'"') | Some(b'\'') => true,
                Some(b'r') => self.raw_string_ahead(2).is_some(),
                _ => false,
            },
            _ => false,
        }
    }

    /// After a consumed `'`: disambiguate `'a` (lifetime) from `'a'`
    /// (char). A lifetime is ident-shaped with no closing quote.
    fn lifetime_or_char(&mut self) -> TokKind {
        match self.peek_char() {
            Some('\\') => {
                self.bump();
                self.bump();
                self.eat_char_body();
                TokKind::Char
            }
            Some(c) if is_ident_start(c) => {
                // Could be 'a (lifetime) or 'a' (char) or 'static.
                let mut probe = self.pos + c.len_utf8();
                while let Some(n) = self.src[probe..].chars().next() {
                    if is_ident_continue(n) {
                        probe += n.len_utf8();
                    } else {
                        break;
                    }
                }
                if self.src[probe..].starts_with('\'') {
                    self.eat_while(is_ident_continue);
                    self.bump(); // closing quote
                    TokKind::Char
                } else {
                    self.eat_while(is_ident_continue);
                    TokKind::Lifetime
                }
            }
            Some(_) => {
                self.bump();
                self.eat_char_body();
                TokKind::Char
            }
            None => TokKind::Punct,
        }
    }

    /// Consume up to and including the closing `'` of a char literal
    /// whose first content char was already consumed (covers multi-byte
    /// escapes like `'\u{1F600}'`).
    fn eat_char_body(&mut self) {
        while let Some(c) = self.peek_char() {
            self.bump();
            if c == '\'' {
                return;
            }
            if c == '\\' {
                self.bump();
            }
        }
    }

    fn eat_number(&mut self) {
        self.eat_while(|c| c.is_ascii_alphanumeric() || c == '_');
        // A fraction: `.` followed by a digit (so `0..10` stays three
        // tokens and `x.1` tuple indexing is untouched).
        if self.peek_char() == Some('.')
            && self.src[self.pos + 1..]
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_digit())
        {
            self.bump();
            self.eat_while(|c| c.is_ascii_alphanumeric() || c == '_');
        }
    }
}

/// Tokenize `src`. Total: every byte of the input lands in exactly one
/// token, in order.
pub fn lex(src: &str) -> Vec<Tok> {
    let mut lx = Lexer {
        src,
        bytes: src.as_bytes(),
        pos: 0,
        line: 1,
    };
    let mut out = Vec::with_capacity(src.len() / 4);
    while let Some(t) = lx.next_token() {
        out.push(t);
    }
    out
}

/// Reassemble the exact source from its tokens — the inverse of [`lex`].
pub fn respell(src: &str, toks: &[Tok]) -> String {
    toks.iter().map(|t| t.text(src)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .iter()
            .filter(|t| !t.is_trivia())
            .map(|t| (t.kind, t.text(src).to_string()))
            .collect()
    }

    /// Spans must tile the input: contiguous, in order, covering.
    fn assert_tiles(src: &str) {
        let toks = lex(src);
        let mut at = 0;
        for t in &toks {
            assert_eq!(t.start, at, "gap or overlap in {src:?}");
            assert!(t.end > t.start, "empty token in {src:?}");
            at = t.end;
        }
        assert_eq!(at, src.len(), "tokens do not cover {src:?}");
        assert_eq!(respell(src, &toks), src);
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r#"let s = "a // not a comment {"; // real
let t = 1;"#;
        assert_tiles(src);
        let ks = kinds(src);
        assert!(ks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t.contains("not a comment")));
        assert!(!ks.iter().any(|(_, t)| t == "real"));
        // The `{` inside the string must not surface as punctuation.
        assert_eq!(ks.iter().filter(|(_, t)| t == "{").count(), 0);
    }

    #[test]
    fn raw_and_byte_strings() {
        for src in [
            r##"x(r"a\") ; "##,
            r###"x(r#"quote " inside"# )"###,
            r#"x(b"bytes\xff")"#,
            r###"x(br#"raw " bytes"#)"###,
        ] {
            assert_tiles(src);
            assert_eq!(
                kinds(src)
                    .iter()
                    .filter(|(k, _)| *k == TokKind::Str)
                    .count(),
                1,
                "in {src:?}"
            );
        }
        // `r` and `b` as plain identifiers are untouched.
        assert_eq!(kinds("r + b")[0].0, TokKind::Ident);
    }

    #[test]
    fn lifetimes_vs_chars() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'a'; let n = '\\n'; }");
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count(),
            2
        );
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::Char).count(),
            2
        );
        assert_tiles("let s: &'static str = \"x\"; let q = '\\u{1F600}';");
    }

    #[test]
    fn nested_block_comments() {
        let src = "a /* outer /* inner */ still */ b";
        assert_tiles(src);
        let ks = kinds(src);
        assert_eq!(ks.len(), 2, "{ks:?}");
        assert_eq!(ks[1].1, "b");
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let ks = kinds("0..10");
        assert_eq!(
            ks.iter().map(|(_, t)| t.as_str()).collect::<Vec<_>>(),
            vec!["0", ".", ".", "10"]
        );
        assert_eq!(kinds("1.5e-3")[0].1, "1.5e");
        assert_tiles("let x = 0xff_u64 + 1.25 + 2e9;");
    }

    #[test]
    fn unterminated_constructs_lex_to_eof() {
        for src in ["\"never closed", "/* never closed", "r#\"open", "'\\"] {
            assert_tiles(src);
        }
    }

    /// Property: for arbitrary token soup — including adversarial
    /// string/comment content — spans tile the source, `respell` is the
    /// identity, and content hidden in strings/line-comments never
    /// leaks out as code tokens.
    #[test]
    fn prop_lex_respell_round_trip() {
        check("lex round trip", 128, |g| {
            let (src, marker_in_data) = gen_source(g);
            let toks = lex(&src);
            let mut at = 0;
            for t in &toks {
                if t.start != at || t.end <= t.start {
                    return Err(format!("span break at {at} in {src:?}"));
                }
                at = t.end;
            }
            if at != src.len() {
                return Err(format!("coverage stops at {at} in {src:?}"));
            }
            if respell(&src, &toks) != src {
                return Err(format!("respell mismatch for {src:?}"));
            }
            // The marker ident was only ever written inside string or
            // comment bodies; it must not appear as an Ident token.
            if marker_in_data
                && toks.iter().any(|t| {
                    t.kind == TokKind::Ident && t.text(&src) == "NEEDLE"
                })
            {
                return Err(format!("data leaked as code in {src:?}"));
            }
            Ok(())
        });
    }

    /// Random source: a mix of plain code atoms and data atoms (strings
    /// and comments) whose bodies contain code-shaped text, quotes, and
    /// the `NEEDLE` marker. Returns whether any data atom was emitted.
    fn gen_source(g: &mut Gen) -> (String, bool) {
        let mut out = String::new();
        let mut data = false;
        let n = g.usize(1..20);
        for _ in 0..n {
            match g.usize(0..10) {
                0 => {
                    let body = gen_payload(g, false);
                    out.push_str(&format!("\"{body}\" "));
                    data = true;
                }
                1 => {
                    let hashes = "#".repeat(g.usize(0..3));
                    // Raw-string payload must not contain the fence.
                    let body = gen_payload(g, true)
                        .replace('"', "q")
                        .replace('\\', "s");
                    out.push_str(&format!("r{hashes}\"{body}\"{hashes} "));
                    data = true;
                }
                2 => {
                    let body = gen_payload(g, true).replace('\n', " ");
                    out.push_str(&format!("// {body}\n"));
                    data = true;
                }
                3 => {
                    let body = gen_payload(g, true)
                        .replace('*', "x")
                        .replace('/', "y");
                    out.push_str(&format!("/* {body} */ "));
                    data = true;
                }
                4 => out.push_str("'x' "),
                5 => out.push_str("&'a x "),
                6 => out.push_str(&format!("{} ", g.u64(0..1000))),
                7 => out.push_str("{ x.y(z) } "),
                8 => out.push_str("let v = w; "),
                _ => out.push_str(&g.string(8)),
            }
        }
        (out, data)
    }

    /// String/comment body text laced with code-shaped fragments. When
    /// `raw` is false the result is escape-valid for a `"…"` literal.
    fn gen_payload(g: &mut Gen, raw: bool) -> String {
        let mut s = String::new();
        for _ in 0..g.usize(0..4) {
            match g.usize(0..6) {
                0 => s.push_str("NEEDLE"),
                1 => s.push_str("// nested"),
                2 => s.push_str(if raw { "'" } else { "\\\"" }),
                3 => s.push_str("{ } ( )"),
                4 => s.push_str(&g.string(6)),
                _ => s.push_str("lock_or_recover"),
            }
            s.push(' ');
        }
        s
    }
}
