//! Power model: the Fig 1 storage/preprocessing/training split and the
//! §7.5 co-designed-optimization power accounting (2.59× DSI reduction).
//!
//! Fleet power per training node is the sum of
//! * trainer node power (8 GPUs + host),
//! * DPP worker power (workers-per-trainer × node watts),
//! * storage power: the *larger* of capacity-provisioned and
//!   IOPS-provisioned HDD counts (the paper's §7.1 throughput-to-storage
//!   gap means IOPS usually dominates).

use crate::config::{DeviceSpec, NodeSpec, RmConfig, TrainerNodeSpec};

/// Storage-node provisioning for one model's training demand.
#[derive(Clone, Copy, Debug)]
pub struct StorageProvision {
    pub capacity_nodes: f64,
    pub iops_nodes: f64,
    /// The gap the paper calls out (>8×): IOPS-driven over capacity-driven.
    pub throughput_to_storage_gap: f64,
}

/// HDDs per storage node (typical storage sled).
pub const HDDS_PER_NODE: f64 = 36.0;
/// Storage node host overhead (watts) on top of its disks.
pub const STORAGE_HOST_WATTS: f64 = 200.0;

/// Provision storage nodes for a dataset + read demand.
///
/// * `dataset_pb` — compressed dataset size (× replication on disk).
/// * `read_gbps` — aggregate storage read demand for this model's
///   training jobs.
/// * `avg_io_bytes` — observed average I/O size (drives achievable
///   per-disk throughput through the seek model).
pub fn provision_storage(
    dataset_pb: f64,
    replication: f64,
    read_gbps: f64,
    avg_io_bytes: f64,
    disk: &DeviceSpec,
) -> StorageProvision {
    let bytes = dataset_pb * 1e15 * replication;
    let capacity_nodes = bytes / (disk.capacity_tb * 1e12) / HDDS_PER_NODE;
    // Achievable MB/s per disk at this I/O size (seek + transfer).
    let per_io_secs = disk.service_time(avg_io_bytes as u64, false);
    let disk_mbps = avg_io_bytes / 1e6 / per_io_secs;
    let demand_mbps = read_gbps * 1e9 / 8.0 / 1e6;
    let iops_nodes = demand_mbps / disk_mbps / HDDS_PER_NODE;
    StorageProvision {
        capacity_nodes,
        iops_nodes,
        throughput_to_storage_gap: iops_nodes / capacity_nodes.max(1e-12),
    }
}

impl StorageProvision {
    pub fn nodes(&self) -> f64 {
        self.capacity_nodes.max(self.iops_nodes)
    }

    pub fn watts(&self, disk: &DeviceSpec) -> f64 {
        self.nodes() * (HDDS_PER_NODE * disk.watts + STORAGE_HOST_WATTS)
    }
}

/// Power split for one model's training footprint (Fig 1).
#[derive(Clone, Copy, Debug)]
pub struct PowerSplit {
    pub storage_w: f64,
    pub preproc_w: f64,
    pub training_w: f64,
}

impl PowerSplit {
    pub fn total(&self) -> f64 {
        self.storage_w + self.preproc_w + self.training_w
    }

    pub fn dsi_frac(&self) -> f64 {
        (self.storage_w + self.preproc_w) / self.total()
    }

    pub fn fracs(&self) -> (f64, f64, f64) {
        let t = self.total();
        (
            self.storage_w / t,
            self.preproc_w / t,
            self.training_w / t,
        )
    }
}

/// Fig 1: per-trainer-node power split for an RM.
///
/// * `workers_per_trainer` — measured DPP workers needed per trainer
///   node (Table 9).
/// * `storage` — storage provisioning for this model **per trainer
///   node's share** of the dataset demand.
pub fn power_split(
    trainer: &TrainerNodeSpec,
    worker_node: &NodeSpec,
    workers_per_trainer: f64,
    storage_watts_per_trainer: f64,
) -> PowerSplit {
    PowerSplit {
        storage_w: storage_watts_per_trainer,
        preproc_w: workers_per_trainer * worker_node.watts,
        training_w: trainer.total_watts(),
    }
}

/// §7.5: DSI power reduction when DPP throughput improves `dpp_gain`×
/// and storage throughput improves `storage_gain`× (same demand ⇒
/// proportionally fewer nodes).
pub fn dsi_power_reduction(
    split: &PowerSplit,
    dpp_gain: f64,
    storage_gain: f64,
) -> f64 {
    let before = split.storage_w + split.preproc_w;
    let after = split.storage_w / storage_gain + split.preproc_w / dpp_gain;
    before / after
}

/// Convenience: the paper's Fig 1 reproduction inputs for an RM, using
/// Table 9 workers-per-trainer and Table 3 dataset sizes.
pub fn paper_inputs(rm: &RmConfig) -> (f64, f64) {
    (rm.paper_workers_per_trainer, rm.used_partitions_pb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RmId;

    #[test]
    fn iops_provisioning_dominates_at_small_io() {
        // Table 6-ish 23 KB average I/O on HDDs → big gap (§7.1: >8×).
        // Demand: ~30 trainer nodes' worth of RM1 storage reads.
        let p = provision_storage(10.0, 3.0, 450.0, 23_000.0, &DeviceSpec::hdd());
        assert!(
            p.throughput_to_storage_gap > 8.0,
            "gap {}",
            p.throughput_to_storage_gap
        );
        assert!(p.nodes() == p.iops_nodes);
    }

    #[test]
    fn large_io_closes_the_gap() {
        let small = provision_storage(10.0, 3.0, 300.0, 23_000.0, &DeviceSpec::hdd());
        let large = provision_storage(10.0, 3.0, 300.0, 1_250_000.0, &DeviceSpec::hdd());
        assert!(large.throughput_to_storage_gap < small.throughput_to_storage_gap / 5.0);
    }

    #[test]
    fn fig1_dsi_can_exceed_half() {
        // RM1-shaped: 24 workers/trainer on C-v1 + IOPS-heavy storage.
        let rm = RmConfig::get(RmId::Rm1);
        let storage = provision_storage(
            rm.used_partitions_pb,
            3.0,
            rm.paper_storage_rx_gbps * rm.paper_workers_per_trainer * 8.0,
            23_000.0,
            &DeviceSpec::hdd(),
        );
        // Storage watts spread across ~100 trainer nodes sharing the
        // dataset.
        let split = power_split(
            &TrainerNodeSpec::zionex(),
            &NodeSpec::c_v1(),
            rm.paper_workers_per_trainer,
            storage.watts(&DeviceSpec::hdd()) / 100.0,
        );
        assert!(
            split.dsi_frac() > 0.5,
            "RM1 DSI fraction {}",
            split.dsi_frac()
        );
    }

    #[test]
    fn dsi_reduction_matches_paper_shape() {
        // With the paper's 2.94x / 2.41x gains, reduction lands near
        // 2.59x when preproc is ~38% of DSI power.
        let split = PowerSplit {
            storage_w: 615.0,
            preproc_w: 385.0,
            training_w: 4100.0,
        };
        let r = dsi_power_reduction(&split, 2.94, 2.41);
        assert!((r - 2.59).abs() < 0.05, "reduction {r}");
    }

    #[test]
    fn power_split_fracs_sum_to_one() {
        let split = PowerSplit {
            storage_w: 1.0,
            preproc_w: 2.0,
            training_w: 3.0,
        };
        let (a, b, c) = split.fracs();
        assert!((a + b + c - 1.0).abs() < 1e-12);
    }
}
