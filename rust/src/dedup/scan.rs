//! Duplicate-run detection over warehouse sessions: scan a catalog
//! table's DWRF partitions and report how much of the stored sample
//! mass is payload-duplicated — the measurement that motivates (and
//! sizes) the DedupDWRF encoding and the dedup-aware DPP path.

use super::{sample_payload_fingerprint, same_payload, DedupIndex, DedupStats};
use crate::data::Sample;
use crate::dwrf::{DecodeMode, DwrfReader, IoRange, Projection};
use crate::tectonic::Cluster;
use crate::warehouse::Catalog;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// Per-partition duplication report.
#[derive(Clone, Debug)]
pub struct PartitionDedup {
    pub day: u32,
    pub stats: DedupStats,
    /// Stored (compressed) bytes of the partition file.
    pub bytes: u64,
}

/// Whole-table duplication report.
#[derive(Clone, Debug, Default)]
pub struct TableDedupReport {
    pub table: String,
    /// Within-partition duplication, per partition.
    pub partitions: Vec<PartitionDedup>,
    /// Duplication counting repeats *across* partitions too (a payload
    /// first seen on day 0 re-logged on day 1 counts as a duplicate).
    pub global: DedupStats,
    pub bytes: u64,
}

impl TableDedupReport {
    /// Within-partition duplication aggregated over all partitions.
    pub fn within_partition(&self) -> DedupStats {
        let mut st = DedupStats::default();
        for p in &self.partitions {
            st.merge(&p.stats);
        }
        st
    }
}

/// Scan every partition of `table`: decode all rows (full projection)
/// and fingerprint their payloads. Partition files are fetched through
/// the same storage path training reads use.
pub fn scan_table(
    cluster: &Cluster,
    catalog: &Catalog,
    table: &str,
) -> Result<TableDedupReport> {
    let t = catalog
        .get(table)
        .with_context(|| format!("unknown table {table}"))?;
    let projection = Projection::new(t.schema.features.iter().map(|f| f.id));
    let mut report = TableDedupReport {
        table: table.to_string(),
        ..Default::default()
    };
    // Cross-partition content store: fingerprint → representatives.
    let mut seen: HashMap<u64, Vec<Sample>> = HashMap::new();
    for p in &t.partitions {
        let len = cluster
            .file_len(p.file)
            .with_context(|| format!("partition day {} missing", p.day))?;
        let bytes = cluster.read_range(p.file, IoRange { offset: 0, len })?;
        let reader = DwrfReader::open_table(&bytes, table)?;
        let plan = reader.plan(&projection, None);
        let bufs = reader.fetch_local(&bytes, &plan);
        let mut rows = Vec::new();
        for s in 0..reader.meta.stripes.len() {
            rows.extend(reader.decode_stripe_rows(
                s,
                &bufs,
                &projection,
                DecodeMode::default(),
            )?);
        }
        let idx = DedupIndex::analyze(&rows);
        let mut stats = DedupStats::default();
        stats.record(&idx);
        report.partitions.push(PartitionDedup {
            day: p.day,
            stats,
            bytes: p.bytes,
        });
        report.bytes += p.bytes;
        // Global (cross-partition) accounting.
        for s in &rows {
            report.global.rows += 1;
            let fp = sample_payload_fingerprint(s);
            let reps = seen.entry(fp).or_default();
            if !reps.iter().any(|r| same_payload(r, s)) {
                reps.push(s.clone());
                report.global.unique_rows += 1;
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset_dup;
    use crate::dwrf::WriterOptions;
    use crate::tectonic::ClusterConfig;

    #[test]
    fn scan_reports_injected_duplication() {
        let cluster = Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let scale = SimScale::tiny();
        let h = build_dataset_dup(
            &cluster,
            &catalog,
            &rm,
            &scale,
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            11,
            4,
        )
        .unwrap();
        let rep = scan_table(&cluster, &catalog, &h.table_name).unwrap();
        assert_eq!(rep.partitions.len(), scale.partitions);
        assert_eq!(rep.global.rows, 128);
        // Mean copies-per-session is 4; the realized factor fluctuates but
        // must show substantial duplication at tiny scale.
        assert!(
            rep.global.factor() > 1.8,
            "global factor {}",
            rep.global.factor()
        );
        assert!(rep.within_partition().factor() > 1.5);
        assert!(rep.bytes > 0);
    }

    #[test]
    fn scan_without_duplication_is_flat() {
        let cluster = Cluster::new(ClusterConfig {
            chunk_bytes: 64 << 10,
            ..Default::default()
        });
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm3);
        let h = build_dataset_dup(
            &cluster,
            &catalog,
            &rm,
            &SimScale::tiny(),
            WriterOptions {
                stripe_rows: 16,
                ..Default::default()
            },
            12,
            1,
        )
        .unwrap();
        let rep = scan_table(&cluster, &catalog, &h.table_name).unwrap();
        // Random payloads essentially never collide.
        assert!(
            rep.global.factor() < 1.05,
            "unexpected duplication {}",
            rep.global.factor()
        );
    }

    #[test]
    fn unknown_table_errors() {
        let cluster = Cluster::new(ClusterConfig::default());
        let catalog = Catalog::new();
        assert!(scan_table(&cluster, &catalog, "nope").is_err());
    }
}
