//! Sample deduplication across the DSI pipeline (RecD-style).
//!
//! The paper's workload characterization shows training jobs "read and
//! heavily filter massive and evolving datasets, resulting in popular
//! features and samples used across training jobs". Production feature
//! logs amplify this *within* a dataset too: one user session fans out
//! into many impression samples that share an identical feature payload
//! and differ only in label and timestamp. RecD (see PAPERS.md) exploits
//! that duplication end-to-end; this module is the shared foundation:
//!
//! * content-addressed **payload fingerprinting** ([`Fnv64`],
//!   [`sample_payload_fingerprint`]) — label- and timestamp-blind, so
//!   "same session, different outcome" rows are recognized as duplicates;
//! * **duplicate-run detection** ([`DedupIndex::analyze`]) — the inverse
//!   index (row → unique payload) that the DedupDWRF encoding stores and
//!   the dedup-aware DPP worker preprocesses by;
//! * duplication **accounting** ([`DedupStats`]) and whole-warehouse
//!   [`scan`]ning used by the paper-style dedup tables.
//!
//! Consumers:
//! * [`crate::dwrf`] — `Encoding::Dedup` clusters duplicate sessions into
//!   stripes and stores each unique payload once plus the inverse index;
//! * [`crate::dpp`] — workers transform each unique payload once and ship
//!   inverse-keyed wire batches; clients expand them back to full batches;
//! * [`crate::datagen`] — generates warehouses with a configurable
//!   duplication factor so the savings are measurable end-to-end.

pub mod scan;

pub use scan::{scan_table, PartitionDedup, TableDedupReport};

use crate::data::Sample;
use std::collections::HashMap;

/// Minimal streaming FNV-1a 64-bit hasher. Used for content fingerprints
/// (samples, session specs) where we need determinism across processes —
/// `std::hash` makes no such guarantee.
#[derive(Clone, Debug)]
pub struct Fnv64 {
    h: u64,
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64 {
            h: 0xcbf2_9ce4_8422_2325,
        }
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(0x100_0000_01b3);
        }
    }

    #[inline]
    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Hash the bit pattern (stable for -0.0/NaN payloads, unlike `==`).
    #[inline]
    pub fn write_f32(&mut self, v: f32) {
        self.write_u32(v.to_bits());
    }

    #[inline]
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write(s.as_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

/// Content fingerprint of a sample's *feature payload*: dense + sparse
/// maps only. Label and timestamp are deliberately excluded — duplicate
/// sessions produce distinct outcomes/times, and the DedupDWRF encoding
/// stores those per-row anyway.
pub fn sample_payload_fingerprint(s: &Sample) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(s.dense.len() as u64);
    for (fid, v) in &s.dense {
        h.write_u32(fid.0);
        h.write_f32(*v);
    }
    h.write_u64(s.sparse.len() as u64);
    for (fid, v) in &s.sparse {
        h.write_u32(fid.0);
        h.write_u64(v.ids.len() as u64);
        for &id in &v.ids {
            h.write_u64(id);
        }
        match &v.scores {
            Some(sc) => {
                h.write_u8(1);
                for &x in sc {
                    h.write_f32(x);
                }
            }
            None => h.write_u8(0),
        }
    }
    h.finish()
}

/// Exact payload equality (the fingerprint is only a filter: matches are
/// verified so a 64-bit collision can never conflate distinct payloads).
pub fn same_payload(a: &Sample, b: &Sample) -> bool {
    a.dense == b.dense && a.sparse == b.sparse
}

/// The duplicate-run structure of a run of samples: `inverse[row]` names
/// the unique payload the row carries; `unique_rows[u]` is the original
/// index of unique payload `u`'s first occurrence.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DedupIndex {
    pub inverse: Vec<u32>,
    pub unique_rows: Vec<usize>,
}

impl DedupIndex {
    /// Detect duplicate payloads in `samples` (fingerprint + verified
    /// equality), preserving first-occurrence order of uniques.
    pub fn analyze(samples: &[Sample]) -> DedupIndex {
        let mut by_fp: HashMap<u64, Vec<u32>> = HashMap::new();
        let mut inverse = Vec::with_capacity(samples.len());
        let mut unique_rows = Vec::new();
        for (row, s) in samples.iter().enumerate() {
            let fp = sample_payload_fingerprint(s);
            let candidates = by_fp.entry(fp).or_default();
            let found = candidates
                .iter()
                .copied()
                .find(|&u| same_payload(&samples[unique_rows[u as usize]], s));
            match found {
                Some(u) => inverse.push(u),
                None => {
                    let u = unique_rows.len() as u32;
                    unique_rows.push(row);
                    candidates.push(u);
                    inverse.push(u);
                }
            }
        }
        DedupIndex {
            inverse,
            unique_rows,
        }
    }

    pub fn rows(&self) -> usize {
        self.inverse.len()
    }

    pub fn unique_count(&self) -> usize {
        self.unique_rows.len()
    }

    /// rows / unique payloads (1.0 = no duplication).
    pub fn factor(&self) -> f64 {
        if self.unique_rows.is_empty() {
            1.0
        } else {
            self.inverse.len() as f64 / self.unique_rows.len() as f64
        }
    }
}

/// Aggregated duplication accounting (per partition, table, or fleet).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DedupStats {
    pub rows: u64,
    pub unique_rows: u64,
}

impl DedupStats {
    pub fn record(&mut self, idx: &DedupIndex) {
        self.rows += idx.rows() as u64;
        self.unique_rows += idx.unique_count() as u64;
    }

    pub fn merge(&mut self, o: &DedupStats) {
        self.rows += o.rows;
        self.unique_rows += o.unique_rows;
    }

    pub fn factor(&self) -> f64 {
        if self.unique_rows == 0 {
            1.0
        } else {
            self.rows as f64 / self.unique_rows as f64
        }
    }

    /// Fraction of per-row work a dedup-aware stage avoids.
    pub fn saved_frac(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            1.0 - self.unique_rows as f64 / self.rows as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SparseValue;
    use crate::schema::FeatureId;

    fn sample(i: u64, label: f32, ts: u64) -> Sample {
        let mut s = Sample {
            dense: vec![(FeatureId(0), i as f32)],
            sparse: vec![(FeatureId(10), SparseValue::ids(vec![i, i + 1]))],
            label,
            timestamp: ts,
        };
        s.sort_features();
        s
    }

    #[test]
    fn fingerprint_ignores_label_and_timestamp() {
        let a = sample(3, 0.0, 100);
        let b = sample(3, 1.0, 999);
        assert_eq!(
            sample_payload_fingerprint(&a),
            sample_payload_fingerprint(&b)
        );
        assert!(same_payload(&a, &b));
        let c = sample(4, 0.0, 100);
        assert_ne!(
            sample_payload_fingerprint(&a),
            sample_payload_fingerprint(&c)
        );
    }

    #[test]
    fn fingerprint_sensitive_to_scores() {
        let mut a = sample(1, 0.0, 0);
        let mut b = a.clone();
        b.sparse[0].1.scores = Some(vec![0.5, 0.25]);
        assert_ne!(
            sample_payload_fingerprint(&a),
            sample_payload_fingerprint(&b)
        );
        assert!(!same_payload(&a, &b));
        a.sparse[0].1.scores = Some(vec![0.5, 0.25]);
        assert_eq!(
            sample_payload_fingerprint(&a),
            sample_payload_fingerprint(&b)
        );
    }

    #[test]
    fn analyze_builds_inverse_index() {
        let rows = vec![
            sample(7, 0.0, 1),
            sample(9, 1.0, 2),
            sample(7, 1.0, 3), // dup of row 0
            sample(9, 0.0, 4), // dup of row 1
            sample(7, 0.0, 5), // dup of row 0
        ];
        let idx = DedupIndex::analyze(&rows);
        assert_eq!(idx.inverse, vec![0, 1, 0, 1, 0]);
        assert_eq!(idx.unique_rows, vec![0, 1]);
        assert_eq!(idx.unique_count(), 2);
        assert!((idx.factor() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn analyze_no_duplicates_is_identity() {
        let rows: Vec<Sample> = (0..6).map(|i| sample(i, 0.0, i)).collect();
        let idx = DedupIndex::analyze(&rows);
        assert_eq!(idx.inverse, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(idx.unique_count(), 6);
        assert!((idx.factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let mut st = DedupStats::default();
        st.record(&DedupIndex::analyze(&[
            sample(1, 0.0, 0),
            sample(1, 1.0, 1),
            sample(2, 0.0, 2),
            sample(1, 0.0, 3),
        ]));
        assert_eq!(st.rows, 4);
        assert_eq!(st.unique_rows, 2);
        assert!((st.factor() - 2.0).abs() < 1e-12);
        assert!((st.saved_frac() - 0.5).abs() < 1e-12);
        let mut other = DedupStats::default();
        other.merge(&st);
        assert_eq!(other, st);
    }

    #[test]
    fn empty_input_is_sane() {
        let idx = DedupIndex::analyze(&[]);
        assert_eq!(idx.rows(), 0);
        assert!((idx.factor() - 1.0).abs() < 1e-12);
        assert_eq!(DedupStats::default().saved_frac(), 0.0);
    }
}
