//! PJRT runtime: loads the AOT-compiled JAX/Pallas DLRM artifacts
//! (HLO text, see `python/compile/aot.py`) and executes them from the
//! Rust hot path. Python never runs at request time — after
//! `make artifacts` the binary is self-contained.

use crate::dpp::TensorBatch;
use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The positional interface exported by `aot.py` (manifest.txt).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub n_dense: usize,
    pub n_sparse: usize,
    pub ids_per_feature: usize,
    pub vocab: usize,
    pub emb_dim: usize,
    pub hidden: usize,
    pub lr: f64,
    pub num_params: usize,
    /// (name, shape) in positional order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}"))?;
        let mut kv = HashMap::new();
        let mut params = Vec::new();
        for line in text.lines() {
            let Some((k, v)) = line.split_once('=') else { continue };
            if let Some(name) = k.strip_prefix("param.") {
                let shape: Vec<usize> = v
                    .split(',')
                    .map(|d| d.parse().context("param dim"))
                    .collect::<Result<_>>()?;
                params.push((name.to_string(), shape));
            } else {
                kv.insert(k.to_string(), v.to_string());
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .with_context(|| format!("manifest missing {k}"))?
                .parse()
                .with_context(|| format!("manifest {k}"))
        };
        Ok(Manifest {
            batch: get("batch")?,
            n_dense: get("n_dense")?,
            n_sparse: get("n_sparse")?,
            ids_per_feature: get("ids_per_feature")?,
            vocab: get("vocab")?,
            emb_dim: get("emb_dim")?,
            hidden: get("hidden")?,
            lr: kv
                .get("lr")
                .context("manifest missing lr")?
                .parse()
                .context("lr")?,
            num_params: get("num_params")?,
            params,
        })
    }
}

/// One fixed-shape model input batch (the manifest's layout).
#[derive(Clone, Debug)]
pub struct DlrmBatch {
    pub dense: Vec<f32>, // [B * D]
    pub ids: Vec<i32>,   // [B * S * L]
    pub mask: Vec<f32>,  // [B * S * L]
    pub labels: Vec<f32>, // [B]
}

impl DlrmBatch {
    /// Adapt a DPP [`TensorBatch`] to the model's fixed shapes: first
    /// `n_dense` dense columns (zero-padded), first `n_sparse` sparse
    /// features truncated/padded to `ids_per_feature` with a mask, ids
    /// hashed into the vocab. Rows beyond `batch` are dropped; missing
    /// rows are zero-padded with label 0 and mask 0.
    pub fn from_tensor_batch(tb: &TensorBatch, m: &Manifest) -> DlrmBatch {
        let b = m.batch;
        let rows = tb.rows.min(b);
        let d_have = tb.dense_names.len();
        let mut dense = vec![0f32; b * m.n_dense];
        for r in 0..rows {
            for j in 0..m.n_dense.min(d_have) {
                dense[r * m.n_dense + j] = tb.dense[r * d_have + j];
            }
        }
        let l = m.ids_per_feature;
        let mut ids = vec![0i32; b * m.n_sparse * l];
        let mut mask = vec![0f32; b * m.n_sparse * l];
        for (s, (_, offsets, idv)) in
            tb.sparse.iter().take(m.n_sparse).enumerate()
        {
            for r in 0..rows {
                let (lo, hi) = (offsets[r] as usize, offsets[r + 1] as usize);
                for (k, &id) in idv[lo..hi].iter().take(l).enumerate() {
                    let at = (r * m.n_sparse + s) * l + k;
                    ids[at] = (id % m.vocab as u64) as i32;
                    mask[at] = 1.0;
                }
            }
        }
        let mut labels = vec![0f32; b];
        labels[..rows].copy_from_slice(&tb.labels[..rows]);
        DlrmBatch {
            dense,
            ids,
            mask,
            labels,
        }
    }

    /// Synthetic batch for tests/benches.
    pub fn synthetic(m: &Manifest, rng: &mut Pcg32) -> DlrmBatch {
        let b = m.batch;
        let dense: Vec<f32> = (0..b * m.n_dense)
            .map(|_| rng.normal_ms(0.0, 2.0) as f32)
            .collect();
        let n_ids = b * m.n_sparse * m.ids_per_feature;
        let ids: Vec<i32> =
            (0..n_ids).map(|_| rng.below(m.vocab as u64) as i32).collect();
        let mask: Vec<f32> = (0..n_ids)
            .map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 })
            .collect();
        // Learnable labels: depend on the first dense feature.
        let labels: Vec<f32> = (0..b)
            .map(|r| {
                let x = dense[r * m.n_dense];
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        DlrmBatch {
            dense,
            ids,
            mask,
            labels,
        }
    }
}

/// Loaded + compiled DLRM executables.
#[cfg(feature = "xla")]
pub struct DlrmRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    fwd: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    dense_xform: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
}

/// Stub runtime for builds without the (vendored, offline-only) `xla`
/// PJRT bindings: every entry point reports the missing feature instead
/// of executing. Keeps the `train` subcommand and the runtime
/// integration tests compiling; those tests skip when artifacts are
/// absent, and `load` explains itself when they are present.
#[cfg(not(feature = "xla"))]
pub struct DlrmRuntime {
    pub manifest: Manifest,
}

/// Opaque parameter handle for the stub runtime (mirrors
/// `Vec<xla::Literal>` in the real one).
#[cfg(not(feature = "xla"))]
#[derive(Clone, Debug)]
pub struct StubParam;

#[cfg(not(feature = "xla"))]
impl StubParam {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!("xla feature disabled")
    }
}

#[cfg(not(feature = "xla"))]
impl DlrmRuntime {
    pub fn load(dir: &Path) -> Result<DlrmRuntime> {
        let _ = Manifest::load(&dir.join("manifest.txt"))?;
        bail!(
            "built without the `xla` feature — rebuild with \
             `--features xla` (requires the vendored xla crate) to run \
             the PJRT DLRM artifacts"
        );
    }

    pub fn init_params(&self, _seed: u64) -> Result<Vec<StubParam>> {
        bail!("xla feature disabled")
    }

    pub fn fwd_loss(
        &self,
        _params: &[StubParam],
        _batch: &DlrmBatch,
    ) -> Result<(f32, Vec<f32>)> {
        bail!("xla feature disabled")
    }

    pub fn train_step(
        &self,
        _params: Vec<StubParam>,
        _batch: &DlrmBatch,
    ) -> Result<(Vec<StubParam>, f32)> {
        bail!("xla feature disabled")
    }

    pub fn dense_xform(
        &self,
        _x: &[f32],
        _mean: &[f32],
        _std: &[f32],
    ) -> Result<Vec<f32>> {
        bail!("xla feature disabled")
    }
}

/// Default artifacts dir: `$DSI_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("DSI_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when `make artifacts` has produced the HLO files.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}

#[cfg(feature = "xla")]
impl DlrmRuntime {
    pub fn load(dir: &Path) -> Result<DlrmRuntime> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("path utf8")?,
            )
            .map_err(anyhow_xla)
            .with_context(|| format!("parse {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(anyhow_xla)
                .with_context(|| format!("compile {name}"))
        };
        Ok(DlrmRuntime {
            fwd: compile("dlrm_fwd.hlo.txt")?,
            train: compile("dlrm_train_step.hlo.txt")?,
            dense_xform: compile("dense_xform.hlo.txt")?,
            client,
            manifest,
        })
    }

    /// Glorot-style parameter init on the Rust side (so training runs
    /// without any Python at runtime).
    pub fn init_params(&self, seed: u64) -> Result<Vec<xla::Literal>> {
        let mut rng = Pcg32::new(seed);
        let mut out = Vec::new();
        for (_, shape) in &self.manifest.params {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = if shape.len() == 2 {
                let scale = (2.0 / (shape[0] + shape[1]) as f64).sqrt();
                (0..n)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect()
            } else {
                vec![0f32; n]
            };
            out.push(literal_f32(&data, shape)?);
        }
        Ok(out)
    }

    fn batch_literals(&self, batch: &DlrmBatch) -> Result<Vec<xla::Literal>> {
        let m = &self.manifest;
        Ok(vec![
            literal_f32(&batch.dense, &[m.batch, m.n_dense])?,
            literal_i32(
                &batch.ids,
                &[m.batch, m.n_sparse, m.ids_per_feature],
            )?,
            literal_f32(
                &batch.mask,
                &[m.batch, m.n_sparse, m.ids_per_feature],
            )?,
            literal_f32(&batch.labels, &[m.batch])?,
        ])
    }

    /// Evaluate loss + logits without updating parameters.
    pub fn fwd_loss(
        &self,
        params: &[xla::Literal],
        batch: &DlrmBatch,
    ) -> Result<(f32, Vec<f32>)> {
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        let batch_lits = self.batch_literals(batch)?;
        args.extend(batch_lits.iter());
        let result = self.fwd.execute::<&xla::Literal>(&args).map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let mut outs = lit.to_tuple().map_err(anyhow_xla)?;
        if outs.len() != 2 {
            bail!("fwd returned {} outputs", outs.len());
        }
        let logits = outs.pop().unwrap().to_vec::<f32>().map_err(anyhow_xla)?;
        let loss = outs.pop().unwrap().to_vec::<f32>().map_err(anyhow_xla)?[0];
        Ok((loss, logits))
    }

    /// One fused fwd+bwd+SGD step; returns updated params and the loss.
    pub fn train_step(
        &self,
        params: Vec<xla::Literal>,
        batch: &DlrmBatch,
    ) -> Result<(Vec<xla::Literal>, f32)> {
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        let batch_lits = self.batch_literals(batch)?;
        args.extend(batch_lits.iter());
        let result =
            self.train.execute::<&xla::Literal>(&args).map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let mut outs = lit.to_tuple().map_err(anyhow_xla)?;
        let expect = self.manifest.params.len() + 1;
        if outs.len() != expect {
            bail!("train step returned {} outputs, want {expect}", outs.len());
        }
        let loss = outs.pop().unwrap().to_vec::<f32>().map_err(anyhow_xla)?[0];
        Ok((outs, loss))
    }

    /// Run the standalone L1 dense-normalization kernel artifact.
    pub fn dense_xform(
        &self,
        x: &[f32],
        mean: &[f32],
        std: &[f32],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let args = vec![
            literal_f32(x, &[m.batch, m.n_dense])?,
            literal_f32(mean, &[m.n_dense])?,
            literal_f32(std, &[m.n_dense])?,
        ];
        let result = self
            .dense_xform
            .execute::<xla::Literal>(&args)
            .map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        let out = lit.to_tuple1().map_err(anyhow_xla)?;
        out.to_vec::<f32>().map_err(anyhow_xla)
    }
}

#[cfg(feature = "xla")]
fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(anyhow_xla)
}

#[cfg(feature = "xla")]
fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(anyhow_xla)
}

#[cfg(feature = "xla")]
fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir().join("manifest.txt")).unwrap();
        assert_eq!(m.batch, 32);
        assert_eq!(m.params.len(), 9);
        assert_eq!(m.params[0].0, "emb");
        assert_eq!(m.params[0].1, vec![m.vocab, m.emb_dim]);
        let n: usize = m
            .params
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        assert_eq!(n, m.num_params);
    }

    #[test]
    fn tensor_batch_adapter_shapes() {
        let m = Manifest {
            batch: 4,
            n_dense: 3,
            n_sparse: 2,
            ids_per_feature: 2,
            vocab: 100,
            emb_dim: 4,
            hidden: 8,
            lr: 0.1,
            num_params: 0,
            params: vec![],
        };
        let tb = TensorBatch {
            rows: 3,
            dense: vec![1.0; 3 * 5], // 5 dense features available
            dense_names: (0..5)
                .map(crate::schema::FeatureId)
                .collect(),
            sparse: vec![(
                crate::schema::FeatureId(9),
                vec![0, 3, 3, 4],
                vec![500, 501, 502, 7],
            )],
            labels: vec![1.0, 0.0, 1.0],
        };
        let b = DlrmBatch::from_tensor_batch(&tb, &m);
        assert_eq!(b.dense.len(), 4 * 3);
        assert_eq!(b.ids.len(), 4 * 2 * 2);
        // Row 0 of sparse feature 0: first 2 of [500,501,502] mod 100.
        assert_eq!(&b.ids[..2], &[0, 1]);
        assert_eq!(&b.mask[..2], &[1.0, 1.0]);
        // Row 1 empty.
        assert_eq!(b.mask[4], 0.0);
        // Padded row 3: label 0.
        assert_eq!(b.labels[3], 0.0);
    }
}
