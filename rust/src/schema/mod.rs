//! Feature schema and catalog.
//!
//! §3.1.2: samples are structured rows whose features live in *map columns*
//! — a dense map (feature id → float) and a sparse map (feature id →
//! variable-length id list), with an optional score column. §4.3/Table 2:
//! the feature set evolves rapidly (beta → experimental → active →
//! deprecated), which the [`FeatureCatalog`] models.

use crate::util::rng::{Pcg32, Zipf};

/// Stable feature identifier (the map key in the warehouse schema).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FeatureId(pub u32);

/// Storage type of a feature (paper §3.1.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FeatureKind {
    /// feature id → continuous value (e.g. current time).
    Dense,
    /// feature id → variable-length list of categorical ids.
    Sparse,
    /// Sparse with an extra float score per id (used for weighing).
    ScoredSparse,
}

/// Lifecycle status (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FeatureStatus {
    /// Not actively logged; may be back-filled/injected per job.
    Beta,
    /// Used by combo / release-candidate jobs; actively written.
    Experimental,
    /// Part of the production model; actively written.
    Active,
    /// Kept for compatibility pending review/reaping; actively written.
    Deprecated,
}

impl FeatureStatus {
    /// Whether samples for this feature land in the dataset.
    pub fn is_logged(&self) -> bool {
        !matches!(self, FeatureStatus::Beta)
    }
}

/// Definition of one feature in a table's schema.
#[derive(Clone, Debug)]
pub struct FeatureDef {
    pub id: FeatureId,
    pub kind: FeatureKind,
    pub status: FeatureStatus,
    /// Fraction of samples that log this feature (paper Table 5 coverage).
    pub coverage: f64,
    /// Mean id-list length for sparse features (1.0 for dense).
    pub avg_len: f64,
    /// Popularity rank across training jobs (0 = most popular). Drives
    /// reuse (Fig 7) and feature reordering (§7.5).
    pub popularity_rank: usize,
}

impl FeatureDef {
    /// Expected encoded bytes per *logging* row for this feature, used for
    /// sizing math (4 bytes/float; 8 bytes/sparse id + ~1 byte framing).
    pub fn bytes_per_logging_row(&self) -> f64 {
        match self.kind {
            FeatureKind::Dense => 4.0 + 1.0,
            FeatureKind::Sparse => self.avg_len * 8.0 + 2.0,
            FeatureKind::ScoredSparse => self.avg_len * 12.0 + 2.0,
        }
    }

    pub fn expected_bytes_per_row(&self) -> f64 {
        self.coverage * self.bytes_per_logging_row()
    }
}

/// A table schema: the full set of logged features + the label column.
#[derive(Clone, Debug)]
pub struct Schema {
    pub features: Vec<FeatureDef>,
}

impl Schema {
    pub fn by_id(&self, id: FeatureId) -> Option<&FeatureDef> {
        self.features.iter().find(|f| f.id == id)
    }

    pub fn dense(&self) -> impl Iterator<Item = &FeatureDef> {
        self.features
            .iter()
            .filter(|f| matches!(f.kind, FeatureKind::Dense))
    }

    pub fn sparse(&self) -> impl Iterator<Item = &FeatureDef> {
        self.features
            .iter()
            .filter(|f| !matches!(f.kind, FeatureKind::Dense))
    }

    pub fn expected_bytes_per_row(&self) -> f64 {
        self.features
            .iter()
            .map(|f| f.expected_bytes_per_row())
            .sum()
    }

    /// Build a synthetic schema with `n_dense`/`n_sparse` features whose
    /// coverage averages `avg_coverage` and whose sparse lengths average
    /// `avg_sparse_len`. Popularity ranks are a random permutation; actual
    /// reuse skew comes from sampling jobs' projections with a Zipf over
    /// ranks.
    pub fn synthetic(
        rng: &mut Pcg32,
        n_dense: usize,
        n_sparse: usize,
        avg_coverage: f64,
        avg_sparse_len: f64,
    ) -> Schema {
        let n = n_dense + n_sparse;
        let mut ranks: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut ranks);
        let mut features = Vec::with_capacity(n);
        for i in 0..n {
            let kind = if i < n_dense {
                FeatureKind::Dense
            } else if rng.chance(0.15) {
                FeatureKind::ScoredSparse
            } else {
                FeatureKind::Sparse
            };
            // Per-feature coverage: Beta-like around the target mean; popular
            // features (low rank) get higher coverage — the paper notes read
            // features exhibit larger coverage because stronger signals are
            // favored (§5.1).
            let rank_boost = 1.0 - ranks[i] as f64 / n as f64; // 1.0 = most popular
            let noise = (rng.f64() - 0.5) * 0.4;
            let coverage = (avg_coverage * (0.6 + 0.8 * rank_boost) + noise)
                .clamp(0.02, 0.98);
            let avg_len = if matches!(kind, FeatureKind::Dense) {
                1.0
            } else {
                // Skewed lengths; popular sparse features are longer (§5.1).
                rng.lognormal_mean(avg_sparse_len * (0.7 + 0.6 * rank_boost), 0.6)
                    .clamp(1.0, 400.0)
            };
            features.push(FeatureDef {
                id: FeatureId(i as u32),
                kind,
                status: FeatureStatus::Active,
                coverage,
                avg_len,
                popularity_rank: ranks[i],
            });
        }
        Schema { features }
    }

    /// The projection a training job reads: features sampled by popularity
    /// (Zipf over ranks) without replacement, `n_take` of them.
    pub fn sample_projection(
        &self,
        rng: &mut Pcg32,
        n_take: usize,
        zipf_s: f64,
    ) -> Vec<FeatureId> {
        let n = self.features.len();
        let zipf = Zipf::new(n, zipf_s);
        let mut by_rank: Vec<FeatureId> = vec![FeatureId(0); n];
        for f in &self.features {
            by_rank[f.popularity_rank] = f.id;
        }
        let mut taken = vec![false; n];
        let mut out = Vec::with_capacity(n_take);
        let mut guard = 0;
        while out.len() < n_take.min(n) && guard < n_take * 1000 {
            guard += 1;
            let rank = zipf.sample(rng);
            if !taken[rank] {
                taken[rank] = true;
                out.push(by_rank[rank]);
            }
        }
        // Fill any remainder deterministically from the most popular ranks.
        for rank in 0..n {
            if out.len() >= n_take.min(n) {
                break;
            }
            if !taken[rank] {
                taken[rank] = true;
                out.push(by_rank[rank]);
            }
        }
        out
    }
}

/// Catalog of feature lifecycle over time — reproduces the Table 2 flow:
/// features proposed in a 6-month window classified 6 months later.
#[derive(Clone, Debug, Default)]
pub struct FeatureCatalog {
    pub entries: Vec<(FeatureId, FeatureStatus)>,
    next_id: u32,
}

/// Table 2 outcome proportions (10148/883/1650/1933 of 14614).
const P_BETA: f64 = 10148.0 / 14614.0;
const P_EXPERIMENTAL: f64 = 883.0 / 14614.0;
const P_ACTIVE: f64 = 1650.0 / 14614.0;

impl FeatureCatalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Propose `n` new features; classify each according to the empirical
    /// lifecycle distribution.
    pub fn propose(&mut self, rng: &mut Pcg32, n: usize) {
        for _ in 0..n {
            let u = rng.f64();
            let status = if u < P_BETA {
                FeatureStatus::Beta
            } else if u < P_BETA + P_EXPERIMENTAL {
                FeatureStatus::Experimental
            } else if u < P_BETA + P_EXPERIMENTAL + P_ACTIVE {
                FeatureStatus::Active
            } else {
                FeatureStatus::Deprecated
            };
            self.entries.push((FeatureId(self.next_id), status));
            self.next_id += 1;
        }
    }

    pub fn count(&self, s: FeatureStatus) -> usize {
        self.entries.iter().filter(|(_, st)| *st == s).count()
    }

    pub fn total(&self) -> usize {
        self.entries.len()
    }

    /// Count of features that are actively written to the dataset
    /// (experimental + active + deprecated; §4.3).
    pub fn actively_written(&self) -> usize {
        self.entries.iter().filter(|(_, s)| s.is_logged()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_schema() -> (Pcg32, Schema) {
        let mut rng = Pcg32::new(101);
        let s = Schema::synthetic(&mut rng, 120, 40, 0.45, 26.0);
        (rng, s)
    }

    #[test]
    fn synthetic_schema_counts() {
        let (_, s) = test_schema();
        assert_eq!(s.features.len(), 160);
        assert_eq!(s.dense().count(), 120);
        assert_eq!(s.sparse().count(), 40);
    }

    #[test]
    fn synthetic_schema_hits_coverage_target() {
        let (_, s) = test_schema();
        let mean: f64 = s.features.iter().map(|f| f.coverage).sum::<f64>()
            / s.features.len() as f64;
        assert!((mean - 0.45).abs() < 0.08, "coverage mean {mean}");
    }

    #[test]
    fn sparse_lengths_are_skewed_positive() {
        let (_, s) = test_schema();
        let lens: Vec<f64> = s.sparse().map(|f| f.avg_len).collect();
        let mean = lens.iter().sum::<f64>() / lens.len() as f64;
        assert!(mean > 10.0 && mean < 60.0, "sparse len mean {mean}");
        assert!(lens.iter().all(|&l| l >= 1.0));
    }

    #[test]
    fn projection_prefers_popular_features() {
        let (mut rng, s) = test_schema();
        // Take 20% of features many times; popular ranks should dominate.
        let mut hits = vec![0usize; s.features.len()];
        for _ in 0..200 {
            for id in s.sample_projection(&mut rng, 32, 1.0) {
                hits[s.by_id(id).unwrap().popularity_rank] += 1;
            }
        }
        let top: usize = hits[..16].iter().sum();
        let bottom: usize = hits[hits.len() - 16..].iter().sum();
        assert!(top > bottom * 3, "top {top} bottom {bottom}");
    }

    #[test]
    fn projection_has_no_duplicates_and_exact_size() {
        let (mut rng, s) = test_schema();
        let p = s.sample_projection(&mut rng, 40, 1.2);
        assert_eq!(p.len(), 40);
        let mut q = p.clone();
        q.sort();
        q.dedup();
        assert_eq!(q.len(), 40);
    }

    #[test]
    fn catalog_reproduces_table2_proportions() {
        let mut rng = Pcg32::new(7);
        let mut cat = FeatureCatalog::new();
        cat.propose(&mut rng, 14614);
        let beta = cat.count(FeatureStatus::Beta);
        // Expect ~10148 ± a few hundred.
        assert!((beta as f64 - 10148.0).abs() < 500.0, "beta {beta}");
        assert_eq!(cat.total(), 14614);
        assert_eq!(
            cat.actively_written(),
            cat.total() - beta,
            "beta features are not logged"
        );
    }

    #[test]
    fn expected_bytes_dominated_by_sparse() {
        // Paper: features are >99% of stored bytes and sparse lists carry
        // most of it.
        let (_, s) = test_schema();
        let dense: f64 = s.dense().map(|f| f.expected_bytes_per_row()).sum();
        let sparse: f64 = s.sparse().map(|f| f.expected_bytes_per_row()).sum();
        assert!(sparse > dense);
    }
}
