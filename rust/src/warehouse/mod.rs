//! Hive-like data warehouse catalog (§3.1.2): tables of structured
//! samples, partitioned by date, stored as DWRF files in Tectonic.
//!
//! Training jobs select data along two dimensions (§5.1): a set of
//! partitions (row filter) and a feature projection (column filter).

use crate::dwrf::Projection;
use crate::schema::Schema;
use crate::sync::{read_or_recover, write_or_recover, RwLock};
use crate::tectonic::FileId;
use std::collections::HashMap;

/// One date partition of a table.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Day index (e.g. days since dataset epoch).
    pub day: u32,
    pub file: FileId,
    pub rows: u64,
    /// Stored (compressed) bytes of the partition file.
    pub bytes: u64,
}

/// A warehouse table: schema + partitions.
#[derive(Clone, Debug)]
pub struct Table {
    pub name: String,
    pub schema: Schema,
    pub partitions: Vec<Partition>,
}

impl Table {
    /// Row filter: partitions within `[from_day, to_day]`.
    pub fn select_partitions(&self, from_day: u32, to_day: u32) -> Vec<&Partition> {
        self.partitions
            .iter()
            .filter(|p| p.day >= from_day && p.day <= to_day)
            .collect()
    }

    pub fn total_rows(&self) -> u64 {
        self.partitions.iter().map(|p| p.rows).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.partitions.iter().map(|p| p.bytes).sum()
    }
}

/// A training job's dataset selection: table + row filter + column filter
/// (the "session specification" core, §3.2.1).
#[derive(Clone, Debug)]
pub struct DatasetSelection {
    pub table: String,
    pub from_day: u32,
    pub to_day: u32,
    pub projection: Projection,
}

/// The central catalog (one per region in production; one here).
#[derive(Default)]
pub struct Catalog {
    tables: RwLock<HashMap<String, Table>>,
}

impl Catalog {
    pub fn new() -> Catalog {
        Catalog::default()
    }

    pub fn register(&self, table: Table) {
        write_or_recover(&self.tables, "catalog tables")
            .insert(table.name.clone(), table);
    }

    pub fn get(&self, name: &str) -> Option<Table> {
        read_or_recover(&self.tables, "catalog tables")
            .get(name)
            .cloned()
    }

    pub fn add_partition(&self, table: &str, p: Partition) {
        if let Some(t) =
            write_or_recover(&self.tables, "catalog tables").get_mut(table)
        {
            t.partitions.push(p);
            t.partitions.sort_by_key(|p| p.day);
        }
    }

    pub fn table_names(&self) -> Vec<String> {
        let mut v: Vec<String> = read_or_recover(&self.tables, "catalog tables")
            .keys()
            .cloned()
            .collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::{FeatureDef, FeatureId, FeatureKind, FeatureStatus};

    fn schema() -> Schema {
        Schema {
            features: vec![FeatureDef {
                id: FeatureId(0),
                kind: FeatureKind::Dense,
                status: FeatureStatus::Active,
                coverage: 1.0,
                avg_len: 1.0,
                popularity_rank: 0,
            }],
        }
    }

    fn table() -> Table {
        Table {
            name: "rm1".into(),
            schema: schema(),
            partitions: (0..10)
                .map(|d| Partition {
                    day: d,
                    file: FileId(d as u64 + 1),
                    rows: 100,
                    bytes: 1000,
                })
                .collect(),
        }
    }

    #[test]
    fn partition_pruning_by_day() {
        let t = table();
        let sel = t.select_partitions(3, 5);
        assert_eq!(sel.len(), 3);
        assert!(sel.iter().all(|p| (3..=5).contains(&p.day)));
        assert_eq!(t.select_partitions(100, 200).len(), 0);
    }

    #[test]
    fn totals() {
        let t = table();
        assert_eq!(t.total_rows(), 1000);
        assert_eq!(t.total_bytes(), 10_000);
    }

    #[test]
    fn catalog_register_and_extend() {
        let c = Catalog::new();
        c.register(table());
        assert!(c.get("rm1").is_some());
        assert!(c.get("rm2").is_none());
        c.add_partition(
            "rm1",
            Partition {
                day: 2,
                file: FileId(99),
                rows: 5,
                bytes: 50,
            },
        );
        let t = c.get("rm1").unwrap();
        assert_eq!(t.partitions.len(), 11);
        // Sorted by day after insert.
        assert!(t.partitions.windows(2).all(|w| w[0].day <= w[1].day));
        assert_eq!(c.table_names(), vec!["rm1".to_string()]);
    }
}
