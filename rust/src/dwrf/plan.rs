//! Read planning: which file extents a projection needs, and how they are
//! grouped into physical I/Os.
//!
//! This is where two of the paper's optimizations live:
//! * **Coalesced reads (§7.5)** — group selected feature streams within a
//!   window (paper: 1.25 MiB) into one I/O, amortizing HDD seeks at the
//!   cost of over-reading the gap bytes between wanted streams.
//! * The plan's `useful_bytes` vs `read_bytes` vs `num_ios` accounting is
//!   what the storage device model (tectonic) consumes, and what Table 6
//!   and Table 12's storage rows are computed from.

/// The paper's coalescing window.
pub const COALESCE_WINDOW: u64 = 1_310_720; // 1.25 MiB

/// One physical I/O against a file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IoRange {
    pub offset: u64,
    pub len: u64,
}

impl IoRange {
    pub fn end(&self) -> u64 {
        // Offsets/lengths come from footers; saturate rather than wrap
        // so a corrupt extent can only shrink comparisons, not alias.
        self.offset.saturating_add(self.len)
    }
}

/// Plan for one stripe: the wanted stream indices and the physical I/Os
/// that cover them.
#[derive(Clone, Debug)]
pub struct StripePlan {
    pub stripe: usize,
    /// Indices into `StripeInfo::streams` that the projection needs.
    pub wanted_streams: Vec<usize>,
    pub ios: Vec<IoRange>,
    /// Pre-seeded row-group survival mask (`true` = group must decode),
    /// present only when the footer carries row-group zone maps and the
    /// predicate proved at least one group row-free. The decode paths
    /// honor it: pruned groups are never materialized into batch rows,
    /// and — where the stream layout is row-group-split — their byte
    /// ranges were already excluded from `ios`.
    pub group_mask: Option<Vec<bool>>,
}

/// Plan for a whole file.
#[derive(Clone, Debug, Default)]
pub struct ReadPlan {
    pub stripes: Vec<StripePlan>,
    /// Bytes belonging to wanted streams.
    pub useful_bytes: u64,
    /// Bytes actually fetched (>= useful when coalescing over-reads gaps).
    pub read_bytes: u64,
    /// Stripes the predicate proved row-free from footer stats: no
    /// [`StripePlan`] entry exists for them and no I/O is issued.
    pub skipped_stripes: Vec<usize>,
    /// Wanted-stream bytes the projection would have fetched from the
    /// skipped stripes (the pushdown's saved I/O volume).
    pub skipped_bytes: u64,
    /// Row groups pruned inside surviving stripes (sub-stripe zone-map
    /// hits; fully-pruned stripes count under `skipped_stripes` instead).
    pub pruned_groups: u64,
    /// Rows inside those pruned groups — rows that will never be
    /// decoded or materialized.
    pub pruned_group_rows: u64,
    /// Stream bytes the pruned groups' row-group-scoped streams would
    /// have cost (zero when the layout is whole-stripe and pruning can
    /// only save decode, not I/O).
    pub pruned_group_bytes: u64,
}

impl ReadPlan {
    pub fn num_ios(&self) -> usize {
        self.stripes.iter().map(|s| s.ios.len()).sum()
    }

    pub fn io_sizes(&self) -> Vec<u64> {
        self.stripes
            .iter()
            .flat_map(|s| s.ios.iter().map(|io| io.len))
            .collect()
    }

    /// Over-read ratio: fetched / useful.
    pub fn overread(&self) -> f64 {
        if self.useful_bytes == 0 {
            1.0
        } else {
            self.read_bytes as f64 / self.useful_bytes as f64
        }
    }
}

/// Merge sorted extents into physical I/Os.
///
/// `window = None` → one I/O per extent (no coalescing — post-FF baseline).
/// `window = Some(w)` → greedy merge while the coalesced I/O stays ≤ `w`.
/// Gaps between merged extents are over-read.
pub fn coalesce(mut extents: Vec<IoRange>, window: Option<u64>) -> Vec<IoRange> {
    extents.sort_by_key(|e| e.offset);
    let Some(w) = window else {
        return extents;
    };
    let mut out: Vec<IoRange> = Vec::with_capacity(extents.len());
    for e in extents {
        match out.last_mut() {
            Some(cur) if e.end().saturating_sub(cur.offset) <= w && e.offset <= cur.end() + w => {
                // Extend the current I/O through this extent (absorbing any
                // gap) as long as the total stays within the window.
                let new_end = cur.end().max(e.end());
                if new_end - cur.offset <= w {
                    cur.len = new_end - cur.offset;
                    continue;
                }
                out.push(e);
            }
            _ => out.push(e),
        }
    }
    out
}

/// Buffers produced by executing a plan's I/Os; lets the decoder slice out
/// stream extents (streams may sit inside larger coalesced reads).
#[derive(Clone, Debug, Default)]
pub struct IoBuffers {
    /// Sorted by offset, non-overlapping.
    bufs: Vec<(IoRange, Vec<u8>)>,
}

impl IoBuffers {
    pub fn new() -> IoBuffers {
        IoBuffers::default()
    }

    pub fn insert(&mut self, range: IoRange, data: Vec<u8>) {
        debug_assert_eq!(range.len as usize, data.len());
        self.bufs.push((range, data));
        self.bufs.sort_by_key(|(r, _)| r.offset);
    }

    /// Total fetched bytes held.
    pub fn bytes(&self) -> u64 {
        self.bufs.iter().map(|(r, _)| r.len).sum()
    }

    /// Slice out `[offset, offset+len)`; the extent must be fully inside
    /// one fetched I/O.
    pub fn slice(&self, offset: u64, len: u64) -> Option<&[u8]> {
        let idx = match self
            .bufs
            .binary_search_by_key(&offset, |(r, _)| r.offset)
        {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let (r, data) = &self.bufs[idx];
        // Footer-derived extent: reject on overflow instead of wrapping.
        let end = offset.checked_add(len)?;
        if offset >= r.offset && end <= r.end() {
            let start = (offset - r.offset) as usize;
            data.get(start..start.checked_add(len as usize)?)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ext(offset: u64, len: u64) -> IoRange {
        IoRange { offset, len }
    }

    #[test]
    fn no_window_means_one_io_per_extent() {
        let ios = coalesce(vec![ext(100, 10), ext(0, 10)], None);
        assert_eq!(ios, vec![ext(0, 10), ext(100, 10)]);
    }

    #[test]
    fn adjacent_extents_merge() {
        let ios = coalesce(vec![ext(0, 10), ext(10, 10)], Some(1024));
        assert_eq!(ios, vec![ext(0, 20)]);
    }

    #[test]
    fn gap_within_window_is_absorbed() {
        let ios = coalesce(vec![ext(0, 10), ext(50, 10)], Some(1024));
        assert_eq!(ios, vec![ext(0, 60)]);
    }

    #[test]
    fn window_limits_coalescing() {
        // Total would be 2000 bytes > window of 100.
        let ios = coalesce(vec![ext(0, 10), ext(1990, 10)], Some(100));
        assert_eq!(ios.len(), 2);
    }

    #[test]
    fn chain_respects_window() {
        // Extents every 40 bytes of 10; window 100 → groups of ~3.
        let extents: Vec<IoRange> = (0..6).map(|i| ext(i * 40, 10)).collect();
        let ios = coalesce(extents, Some(100));
        assert!(ios.len() >= 2);
        for io in &ios {
            assert!(io.len <= 100);
        }
        // Coverage: every original extent inside some I/O.
        for i in 0..6u64 {
            let (o, l) = (i * 40, 10);
            assert!(
                ios.iter().any(|io| o >= io.offset && o + l <= io.end()),
                "extent {o} uncovered"
            );
        }
    }

    #[test]
    fn io_buffers_slice_inside_coalesced_read() {
        let mut bufs = IoBuffers::new();
        bufs.insert(ext(100, 50), (0..50u8).collect());
        assert_eq!(bufs.slice(110, 5), Some(&[10u8, 11, 12, 13, 14][..]));
        assert_eq!(bufs.slice(100, 50).unwrap().len(), 50);
        assert!(bufs.slice(95, 10).is_none());
        assert!(bufs.slice(140, 20).is_none());
        assert!(bufs.slice(0, 1).is_none());
    }

    #[test]
    fn overread_accounting() {
        let mut p = ReadPlan {
            useful_bytes: 100,
            read_bytes: 150,
            ..Default::default()
        };
        assert!((p.overread() - 1.5).abs() < 1e-12);
        p.useful_bytes = 0;
        assert_eq!(p.overread(), 1.0);
    }
}
