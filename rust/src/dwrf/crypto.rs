//! Stream encryption for DWRF (paper §3.1.2: stripes are divided into
//! *compressed and encrypted* streams; §6.2 counts decryption as part of
//! the "datacenter tax").
//!
//! AES-128-CTR built from the `aes` block cipher (the vendored crate set
//! has no stream-cipher crate). CTR gives us a real, measurable decrypt
//! cost on the extract path with cheap random access.

use aes::cipher::{BlockEncrypt, KeyInit};
use aes::Aes128;

#[derive(Clone)]
pub struct StreamCipher {
    cipher: Aes128,
}

impl StreamCipher {
    pub fn new(key: &[u8; 16]) -> StreamCipher {
        StreamCipher {
            cipher: Aes128::new(key.into()),
        }
    }

    /// Deterministic table key (simulation; production would use KMS).
    pub fn for_table(table: &str) -> StreamCipher {
        use sha2::{Digest, Sha256};
        let d = Sha256::digest(table.as_bytes());
        let mut key = [0u8; 16];
        key.copy_from_slice(&d[..16]);
        StreamCipher::new(&key)
    }

    /// XOR `data` with the AES-CTR keystream for (`nonce`, counter=0..).
    /// Encryption and decryption are the same operation.
    pub fn apply(&self, nonce: u64, data: &mut [u8]) {
        let mut block = [0u8; 16];
        for (i, chunk) in data.chunks_mut(16).enumerate() {
            block[..8].copy_from_slice(&nonce.to_le_bytes());
            block[8..].copy_from_slice(&(i as u64).to_le_bytes());
            let mut b = block.into();
            self.cipher.encrypt_block(&mut b);
            for (d, k) in chunk.iter_mut().zip(b.iter()) {
                *d ^= k;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let c = StreamCipher::for_table("rm1_table");
        let mut data: Vec<u8> = (0..1000u32).map(|i| i as u8).collect();
        let orig = data.clone();
        c.apply(42, &mut data);
        assert_ne!(data, orig, "ciphertext must differ");
        c.apply(42, &mut data);
        assert_eq!(data, orig);
    }

    #[test]
    fn nonce_separates_streams() {
        let c = StreamCipher::for_table("t");
        let mut a = vec![0u8; 64];
        let mut b = vec![0u8; 64];
        c.apply(1, &mut a);
        c.apply(2, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn different_tables_different_keys() {
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        StreamCipher::for_table("t1").apply(0, &mut a);
        StreamCipher::for_table("t2").apply(0, &mut b);
        assert_ne!(a, b);
    }
}
