//! Per-stream encoding/decoding for DWRF.
//!
//! A *stream* is the unit of on-disk storage inside a stripe (§3.1.2):
//! either a whole-map column chunk (baseline encoding: every feature of
//! every row, serialized row-major) or a single flattened feature column
//! chunk (the paper's feature-flattening optimization). Streams are
//! zstd-compressed then AES-CTR-encrypted.
//!
//! Two decode paths exist for flattened columns: a `checked` path with
//! per-value validation (the baseline) and a `fast` path (the paper's
//! "+LO localized optimizations": removing unnecessary null checks and
//! branchy validation from the inner loop).

use crate::data::{Bitmap, DenseColumn, Sample, SparseColumn, SparseValue};
use crate::schema::FeatureId;
use crate::util::bytes::{put_f32, put_varint, ByteReader};
use anyhow::{bail, Context, Result};

/// What a stream contains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKind {
    /// Labels + timestamps for the stripe's rows.
    RowMeta = 0,
    /// Row-major dense feature map for every row (baseline encoding).
    MapDense = 1,
    /// Row-major sparse feature map for every row (baseline encoding).
    MapSparse = 2,
    /// One flattened dense feature column.
    FlatDense = 3,
    /// One flattened sparse feature column.
    FlatSparse = 4,
    /// Row → unique-payload inverse index (Dedup encoding). Flattened
    /// feature streams in a dedup stripe cover *unique* payloads only.
    DedupIndex = 5,
}

impl StreamKind {
    pub fn from_u8(v: u8) -> Result<StreamKind> {
        Ok(match v {
            0 => StreamKind::RowMeta,
            1 => StreamKind::MapDense,
            2 => StreamKind::MapSparse,
            3 => StreamKind::FlatDense,
            4 => StreamKind::FlatSparse,
            5 => StreamKind::DedupIndex,
            _ => bail!("bad stream kind {v}"),
        })
    }
}

// ---------------------------------------------------------------------
// Row-meta stream: labels + timestamps.
// ---------------------------------------------------------------------

pub fn encode_row_meta(labels: &[f32], timestamps: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(labels.len() * 8);
    put_varint(&mut out, labels.len() as u64);
    for &l in labels {
        put_f32(&mut out, l);
    }
    let mut prev = 0u64;
    for &t in timestamps {
        // Delta varint: timestamps are near-monotonic within a stripe.
        put_varint(&mut out, t.wrapping_sub(prev));
        prev = t;
    }
    out
}

pub fn decode_row_meta(buf: &[u8]) -> Result<(Vec<f32>, Vec<u64>)> {
    let mut r = ByteReader::new(buf);
    let n = r.varint().context("row_meta count")? as usize;
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        labels.push(r.f32().context("label")?);
    }
    let mut ts = Vec::with_capacity(n);
    let mut prev = 0u64;
    for _ in 0..n {
        prev = prev.wrapping_add(r.varint().context("timestamp")?);
        ts.push(prev);
    }
    Ok((labels, ts))
}

// ---------------------------------------------------------------------
// Dedup index stream: row → unique-payload inverse index (the RecD-style
// encoding's glue; see `crate::dedup`).
// ---------------------------------------------------------------------

pub fn encode_dedup_index(inverse: &[u32], unique_rows: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(inverse.len() + 8);
    put_varint(&mut out, inverse.len() as u64);
    put_varint(&mut out, unique_rows as u64);
    for &u in inverse {
        put_varint(&mut out, u as u64);
    }
    out
}

/// Decode `(inverse, unique_rows)` and validate every entry is in range.
pub fn decode_dedup_index(buf: &[u8]) -> Result<(Vec<u32>, usize)> {
    let mut r = ByteReader::new(buf);
    let rows = r.varint().context("dedup rows")? as usize;
    let unique = r.varint().context("dedup unique")? as usize;
    if unique > rows {
        bail!("dedup index: {unique} uniques for {rows} rows");
    }
    let mut inverse = Vec::with_capacity(rows);
    for i in 0..rows {
        let u = r.varint().with_context(|| format!("inverse {i}"))?;
        if u >= unique as u64 {
            bail!("dedup index: inverse {u} out of range ({unique} uniques)");
        }
        inverse.push(u as u32);
    }
    Ok((inverse, unique))
}

// ---------------------------------------------------------------------
// Map streams (baseline): every row's full feature map, row-major.
// The reader must decode *everything* to extract any feature — exactly
// the "over read" the paper's feature flattening eliminates.
// ---------------------------------------------------------------------

pub fn encode_map_dense(samples: &[Sample]) -> Vec<u8> {
    let mut out = Vec::new();
    put_varint(&mut out, samples.len() as u64);
    for s in samples {
        put_varint(&mut out, s.dense.len() as u64);
        for &(fid, v) in &s.dense {
            put_varint(&mut out, fid.0 as u64);
            put_f32(&mut out, v);
        }
    }
    out
}

pub fn encode_map_sparse(samples: &[Sample]) -> Vec<u8> {
    let mut out = Vec::new();
    put_varint(&mut out, samples.len() as u64);
    for s in samples {
        put_varint(&mut out, s.sparse.len() as u64);
        for (fid, v) in &s.sparse {
            put_varint(&mut out, fid.0 as u64);
            put_varint(&mut out, v.ids.len() as u64);
            for &id in &v.ids {
                put_varint(&mut out, id);
            }
            match &v.scores {
                Some(sc) => {
                    out.push(1);
                    for &x in sc {
                        put_f32(&mut out, x);
                    }
                }
                None => out.push(0),
            }
        }
    }
    out
}

/// Decode a dense map stream, keeping only features in `projection`
/// (`None` keeps all). Note the cost structure: every entry is decoded
/// regardless of the projection — filtering happens *after* decode.
pub fn decode_map_dense(
    buf: &[u8],
    projection: Option<&dyn Fn(FeatureId) -> bool>,
) -> Result<Vec<Vec<(FeatureId, f32)>>> {
    let mut r = ByteReader::new(buf);
    let rows = r.varint().context("map_dense rows")? as usize;
    let mut out = Vec::with_capacity(rows);
    for _ in 0..rows {
        let n = r.varint().context("n_dense")? as usize;
        let mut row = Vec::with_capacity(n);
        for _ in 0..n {
            let fid = FeatureId(r.varint().context("fid")? as u32);
            let v = r.f32().context("value")?;
            if projection.map_or(true, |p| p(fid)) {
                row.push((fid, v));
            }
        }
        out.push(row);
    }
    Ok(out)
}

pub fn decode_map_sparse(
    buf: &[u8],
    projection: Option<&dyn Fn(FeatureId) -> bool>,
) -> Result<Vec<Vec<(FeatureId, SparseValue)>>> {
    let mut r = ByteReader::new(buf);
    let rows = r.varint().context("map_sparse rows")? as usize;
    let mut out = Vec::with_capacity(rows);
    for _ in 0..rows {
        let n = r.varint().context("n_sparse")? as usize;
        let mut row = Vec::new();
        for _ in 0..n {
            let fid = FeatureId(r.varint().context("fid")? as u32);
            let len = r.varint().context("len")? as usize;
            let mut ids = Vec::with_capacity(len);
            for _ in 0..len {
                ids.push(r.varint().context("id")?);
            }
            let has_scores = r.bytes(1).context("scores flag")?[0] == 1;
            let scores = if has_scores {
                let mut sc = Vec::with_capacity(len);
                for _ in 0..len {
                    sc.push(r.f32().context("score")?);
                }
                Some(sc)
            } else {
                None
            };
            if projection.map_or(true, |p| p(fid)) {
                row.push((fid, SparseValue { ids, scores }));
            }
        }
        out.push(row);
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Flattened feature column streams (the paper's FF optimization).
// ---------------------------------------------------------------------

pub fn encode_flat_dense(col: &DenseColumn) -> Vec<u8> {
    let mut out = Vec::new();
    put_varint(&mut out, col.present.len() as u64);
    for &w in col.present.words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
    put_varint(&mut out, col.values.len() as u64);
    for &v in &col.values {
        put_f32(&mut out, v);
    }
    out
}

pub fn encode_flat_sparse(col: &SparseColumn) -> Vec<u8> {
    let mut out = Vec::new();
    let rows = col.num_rows();
    put_varint(&mut out, rows as u64);
    let mut prev = 0u32;
    for &o in &col.offsets[1..] {
        put_varint(&mut out, (o - prev) as u64);
        prev = o;
    }
    for &id in &col.ids {
        put_varint(&mut out, id);
    }
    match &col.scores {
        Some(sc) => {
            out.push(1);
            for &x in sc {
                put_f32(&mut out, x);
            }
        }
        None => out.push(0),
    }
    out
}

/// Decode a flattened dense column.
///
/// `fast == false`: the baseline path — per-value bounds checks, per-bit
/// presence queries, and unsized growth (models the null-check-laden
/// generic reader the paper's +LO removed).
/// `fast == true`: batch word-wise bitmap copy + exact preallocation +
/// bulk f32 reinterpretation.
pub fn decode_flat_dense(buf: &[u8], id: FeatureId, fast: bool) -> Result<DenseColumn> {
    let mut r = ByteReader::new(buf);
    let rows = r.varint().context("flat_dense rows")? as usize;
    let words = rows.div_ceil(64);
    let mut wv = Vec::with_capacity(words);
    for _ in 0..words {
        wv.push(r.u64().context("bitmap word")?);
    }
    let present = Bitmap::from_words(wv, rows);
    let n = r.varint().context("value count")? as usize;
    let values = if fast {
        let raw = r.bytes(n * 4).context("values")?;
        let mut values = Vec::with_capacity(n);
        // Bulk conversion: chunk-exact, no per-element Option handling.
        values.extend(
            raw.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        values
    } else {
        let mut values = Vec::new(); // unsized: realloc churn like the
                                     // generic row reader
        for i in 0..n {
            let v = r.f32().with_context(|| format!("value {i}"))?;
            // Redundant null/NaN validation per value (the "unnecessary
            // null checks" of §7.5).
            if v.is_nan() {
                bail!("unexpected NaN at {i}");
            }
            if present.count_ones() < values.len() {
                bail!("presence underflow");
            }
            values.push(v);
        }
        values
    };
    if values.len() != present.count_ones() {
        bail!(
            "dense column {id:?}: {} values vs {} present",
            values.len(),
            present.count_ones()
        );
    }
    Ok(DenseColumn {
        id,
        present,
        values,
    })
}

pub fn decode_flat_sparse(buf: &[u8], id: FeatureId, fast: bool) -> Result<SparseColumn> {
    let mut r = ByteReader::new(buf);
    let rows = r.varint().context("flat_sparse rows")? as usize;
    let mut offsets = Vec::with_capacity(rows + 1);
    offsets.push(0u32);
    let mut acc = 0u32;
    for _ in 0..rows {
        acc += r.varint().context("offset delta")? as u32;
        offsets.push(acc);
    }
    let n = acc as usize;
    let ids = if fast {
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(r.varint().context("id")?);
        }
        ids
    } else {
        let mut ids = Vec::new();
        for i in 0..n {
            let v = r.varint().with_context(|| format!("id {i}"))?;
            // Per-value monotone offset re-validation (redundant work the
            // fast path drops).
            let row = match offsets.binary_search(&(i as u32)) {
                Ok(x) => x,
                Err(x) => x - 1,
            };
            if row > rows {
                bail!("row overflow");
            }
            ids.push(v);
        }
        ids
    };
    let has_scores = r.bytes(1).context("scores flag")?[0] == 1;
    let scores = if has_scores {
        let mut sc = Vec::with_capacity(n);
        for _ in 0..n {
            sc.push(r.f32().context("score")?);
        }
        Some(sc)
    } else {
        None
    };
    Ok(SparseColumn {
        id,
        offsets,
        ids,
        scores,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ColumnarBatch;

    fn samples() -> Vec<Sample> {
        (0..9u64)
            .map(|i| {
                let mut s = Sample {
                    dense: vec![(FeatureId(1), i as f32 * 0.5)],
                    sparse: vec![(
                        FeatureId(7),
                        SparseValue::ids(vec![i, i * 3]),
                    )],
                    label: (i % 2) as f32,
                    timestamp: 1000 + i * 7,
                };
                if i % 3 == 0 {
                    s.dense.push((FeatureId(2), -(i as f32)));
                }
                s.sort_features();
                s
            })
            .collect()
    }

    #[test]
    fn row_meta_roundtrip() {
        let labels = vec![0.0, 1.0, 1.0, 0.0];
        let ts = vec![100, 107, 107, 230];
        let buf = encode_row_meta(&labels, &ts);
        let (l2, t2) = decode_row_meta(&buf).unwrap();
        assert_eq!(l2, labels);
        assert_eq!(t2, ts);
    }

    #[test]
    fn map_streams_roundtrip_full() {
        let ss = samples();
        let d = decode_map_dense(&encode_map_dense(&ss), None).unwrap();
        let sp = decode_map_sparse(&encode_map_sparse(&ss), None).unwrap();
        for (i, s) in ss.iter().enumerate() {
            assert_eq!(d[i], s.dense);
            assert_eq!(sp[i], s.sparse);
        }
    }

    #[test]
    fn map_streams_filter_after_decode() {
        let ss = samples();
        let keep = |f: FeatureId| f == FeatureId(2);
        let d = decode_map_dense(&encode_map_dense(&ss), Some(&keep)).unwrap();
        assert!(d[0].iter().all(|(f, _)| *f == FeatureId(2)));
        assert!(d[1].is_empty()); // sample 1 has no feature 2
    }

    #[test]
    fn flat_dense_roundtrip_both_paths() {
        let ss = samples();
        let batch = ColumnarBatch::from_samples(
            &ss,
            &[FeatureId(1), FeatureId(2)],
            &[],
        );
        for col in &batch.dense {
            let buf = encode_flat_dense(col);
            for fast in [false, true] {
                let back = decode_flat_dense(&buf, col.id, fast).unwrap();
                assert_eq!(&back, col, "fast={fast}");
            }
        }
    }

    #[test]
    fn flat_sparse_roundtrip_both_paths() {
        let ss = samples();
        let batch =
            ColumnarBatch::from_samples(&ss, &[], &[FeatureId(7)]);
        let col = &batch.sparse[0];
        let buf = encode_flat_sparse(col);
        for fast in [false, true] {
            let back = decode_flat_sparse(&buf, col.id, fast).unwrap();
            assert_eq!(&back, col, "fast={fast}");
        }
    }

    #[test]
    fn flat_sparse_scored_roundtrip() {
        let col = SparseColumn {
            id: FeatureId(3),
            offsets: vec![0, 2, 2, 3],
            ids: vec![5, 9, 1],
            scores: Some(vec![0.1, 0.9, 0.5]),
        };
        let buf = encode_flat_sparse(&col);
        let back = decode_flat_sparse(&buf, col.id, true).unwrap();
        assert_eq!(back, col);
    }

    #[test]
    fn truncated_buffers_error_not_panic() {
        let ss = samples();
        let buf = encode_map_dense(&ss);
        for cut in [0usize, 1, buf.len() / 2, buf.len() - 1] {
            assert!(decode_map_dense(&buf[..cut], None).is_err());
        }
        let batch = ColumnarBatch::from_samples(&ss, &[FeatureId(1)], &[]);
        let fbuf = encode_flat_dense(&batch.dense[0]);
        for cut in [0usize, 2, fbuf.len() - 1] {
            assert!(decode_flat_dense(&fbuf[..cut], FeatureId(1), true).is_err());
        }
    }

    #[test]
    fn stream_kind_codes_roundtrip() {
        for k in [
            StreamKind::RowMeta,
            StreamKind::MapDense,
            StreamKind::MapSparse,
            StreamKind::FlatDense,
            StreamKind::FlatSparse,
            StreamKind::DedupIndex,
        ] {
            assert_eq!(StreamKind::from_u8(k as u8).unwrap(), k);
        }
        assert!(StreamKind::from_u8(99).is_err());
    }

    #[test]
    fn dedup_index_roundtrip_and_validation() {
        let inverse = vec![0u32, 1, 0, 2, 1, 0];
        let buf = encode_dedup_index(&inverse, 3);
        let (back, unique) = decode_dedup_index(&buf).unwrap();
        assert_eq!(back, inverse);
        assert_eq!(unique, 3);
        // Out-of-range inverse entries are rejected.
        let bad = encode_dedup_index(&[0, 5], 2);
        assert!(decode_dedup_index(&bad).is_err());
        // Truncation errors, never panics.
        for cut in [0usize, 1, buf.len() - 1] {
            assert!(decode_dedup_index(&buf[..cut]).is_err());
        }
    }
}
