//! DWRF writer: buffers rows, flushes stripes, emits the footer.
//!
//! The three storage-side optimizations of the paper's Table 12 map to
//! writer knobs:
//! * **FF** — `Encoding::Flattened` (vs the `Map` baseline),
//! * **FR** — `feature_order: Some(popularity order)` so commonly-read
//!   features are adjacent on disk,
//! * **LS** — `stripe_rows` (large stripes → longer feature streams →
//!   larger I/Os per read).

use super::crypto::StreamCipher;
use super::stream::{
    encode_dedup_index, encode_flat_dense, encode_flat_sparse,
    encode_map_dense, encode_map_sparse, encode_row_meta, StreamKind,
};
use super::{
    FileMeta, RowGroupStats, StreamInfo, StripeInfo, StripeStats, VERSION,
    WHOLE_STRIPE,
};
use crate::data::{ColumnarBatch, Sample};
use crate::dedup::DedupIndex;
use crate::schema::FeatureId;

/// Row encoding (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Baseline: whole-row dense/sparse map streams.
    Map,
    /// Feature flattening: one stream per feature.
    Flattened,
    /// Flattened + RecD-style sample deduplication: duplicate payloads
    /// are clustered into stripes and stored once, with a row→unique
    /// inverse index and per-row labels/timestamps.
    Dedup,
}

#[derive(Clone, Debug)]
pub struct WriterOptions {
    pub encoding: Encoding,
    /// Rows per stripe ("large stripes" increases this).
    pub stripe_rows: usize,
    /// zstd level (1 = fast; the production default here).
    pub zstd_level: i32,
    pub encrypt: bool,
    /// Write order of flattened feature streams within each stripe.
    /// `None` = dataset arrival order (the paper: "effectively random").
    pub feature_order: Option<Vec<FeatureId>>,
    /// Dedup clustering window, in stripes: duplicate payloads arriving
    /// within `stripe_rows * dedup_window_stripes` rows of each other are
    /// guaranteed to land in the same stripe (Dedup encoding only).
    pub dedup_window_stripes: usize,
    /// Rows per zone-map row group (footer v3): every stripe is tiled
    /// into `rows_per_group`-sized runs, each with its own min/max
    /// timestamp / label / presence stats for sub-stripe pruning.
    /// Flattened stripes wider than one group additionally split their
    /// row-meta and per-feature streams at group boundaries, so a pruned
    /// group's bytes are never fetched. Values `>= stripe_rows` degrade
    /// gracefully to one group per stripe (whole-stripe streams).
    pub rows_per_group: usize,
    /// Footer version to emit ([`VERSION`] normally). `2` writes the
    /// legacy pre-row-group layout — used by compatibility tests to
    /// produce byte-real old files that current readers must still
    /// parse.
    pub footer_version: u32,
}

impl Default for WriterOptions {
    fn default() -> Self {
        WriterOptions {
            encoding: Encoding::Flattened,
            stripe_rows: 512,
            zstd_level: 1,
            encrypt: true,
            feature_order: None,
            dedup_window_stripes: 8,
            rows_per_group: 1024,
            footer_version: VERSION,
        }
    }
}

pub struct DwrfWriter {
    opts: WriterOptions,
    cipher: StreamCipher,
    /// Reused compression context (creating a zstd CCtx per stream showed
    /// up at ~15% of write CPU in profiles; see EXPERIMENTS.md §Perf).
    zstd: zstd::bulk::Compressor<'static>,
    /// Full set of logged dense / sparse feature ids (the table schema).
    dense_ids: Vec<FeatureId>,
    sparse_ids: Vec<FeatureId>,
    buf: Vec<u8>,
    pending: Vec<Sample>,
    stripes: Vec<StripeInfo>,
    rows_written: u64,
    nonce: u64,
}

impl DwrfWriter {
    pub fn new(
        table: &str,
        dense_ids: Vec<FeatureId>,
        sparse_ids: Vec<FeatureId>,
        opts: WriterOptions,
    ) -> DwrfWriter {
        DwrfWriter {
            cipher: StreamCipher::for_table(table),
            zstd: zstd::bulk::Compressor::new(opts.zstd_level)
                .expect("zstd context"),
            opts,
            dense_ids,
            sparse_ids,
            buf: Vec::new(),
            pending: Vec::new(),
            stripes: Vec::new(),
            rows_written: 0,
            nonce: 0,
        }
    }

    /// Rows buffered before a flush: one stripe normally, a clustering
    /// window of stripes for the Dedup encoding.
    fn pending_limit(&self) -> usize {
        match self.opts.encoding {
            Encoding::Dedup => {
                self.opts.stripe_rows * self.opts.dedup_window_stripes.max(1)
            }
            _ => self.opts.stripe_rows,
        }
    }

    pub fn write(&mut self, sample: Sample) {
        self.pending.push(sample);
        if self.pending.len() >= self.pending_limit() {
            self.flush_pending();
        }
    }

    pub fn write_all(&mut self, samples: impl IntoIterator<Item = Sample>) {
        for s in samples {
            self.write(s);
        }
    }

    /// Compress + encrypt + append one stream; record its index entry.
    /// `row_group` scopes the stream to one zone-map group
    /// ([`WHOLE_STRIPE`] = covers every row of the stripe).
    fn put_stream(
        &mut self,
        kind: StreamKind,
        feature: u32,
        row_group: u32,
        raw: Vec<u8>,
        out: &mut Vec<StreamInfo>,
    ) {
        let raw_len = raw.len() as u64;
        let mut data = self.zstd.compress(&raw).expect("zstd compress");
        let nonce = self.nonce;
        self.nonce += 1;
        if self.opts.encrypt {
            self.cipher.apply(nonce, &mut data);
        }
        let crc = crc32fast::hash(&data);
        out.push(StreamInfo {
            kind,
            feature,
            row_group,
            offset: self.buf.len() as u64,
            len: data.len() as u64,
            raw_len,
            nonce,
            crc,
        });
        self.buf.extend_from_slice(&data);
    }

    /// Flush buffered rows. Map/Flattened: the buffer is exactly one
    /// stripe. Dedup: cluster the window's rows by payload (duplicates
    /// become adjacent, first-seen order preserved between groups), then
    /// emit `stripe_rows`-sized stripes — duplicate sessions land in the
    /// same stripe where the inverse index can collapse them.
    fn flush_pending(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let samples = std::mem::take(&mut self.pending);
        match self.opts.encoding {
            Encoding::Dedup => self.flush_dedup_window(samples),
            _ => self.emit_stripe(&samples, None),
        }
    }

    /// Cluster one window of rows (payloads fingerprinted once), move
    /// them into clustered order, and emit stripes whose local inverse
    /// indices are *remapped* from the window-level index — no second
    /// fingerprinting pass per stripe.
    fn flush_dedup_window(&mut self, samples: Vec<Sample>) {
        let idx = DedupIndex::analyze(&samples);
        let mut order: Vec<usize> = (0..samples.len()).collect();
        order.sort_by_key(|&r| (idx.inverse[r], r));
        // Window-unique id per clustered position, and the rows moved
        // (not cloned) into clustered order.
        let win_ids: Vec<u32> =
            order.iter().map(|&r| idx.inverse[r]).collect();
        let mut slots: Vec<Option<Sample>> =
            samples.into_iter().map(Some).collect();
        let clustered: Vec<Sample> = order
            .into_iter()
            .map(|r| slots[r].take().expect("permutation"))
            .collect();
        let stripe_rows = self.opts.stripe_rows;
        // Scratch map: window-unique id → stripe-local unique id.
        let mut slot: Vec<u32> = vec![u32::MAX; idx.unique_count()];
        let mut start = 0;
        while start < clustered.len() {
            let end = (start + stripe_rows).min(clustered.len());
            let mut local = DedupIndex::default();
            let mut used = Vec::new();
            for (i, &w) in win_ids[start..end].iter().enumerate() {
                let w = w as usize;
                if slot[w] == u32::MAX {
                    slot[w] = local.unique_rows.len() as u32;
                    local.unique_rows.push(i);
                    used.push(w);
                }
                local.inverse.push(slot[w]);
            }
            for w in used {
                slot[w] = u32::MAX;
            }
            self.emit_stripe(&clustered[start..end], Some(&local));
            start = end;
        }
    }

    /// Emit the per-feature streams of one or more columnar batches in
    /// the configured write order (shared by the Flattened and Dedup
    /// encodings). `batches` is `[(row_group, batch)]`: a single
    /// `(WHOLE_STRIPE, batch)` entry for whole-stripe layout, or one
    /// entry per zone-map group for row-group-split stripes. The layout
    /// is feature-major (a feature's group chunks are adjacent on disk),
    /// so feature reordering keeps its locality win and surviving
    /// groups of one feature coalesce into contiguous reads.
    fn put_feature_streams(
        &mut self,
        batches: &[(u32, ColumnarBatch)],
        streams: &mut Vec<StreamInfo>,
    ) {
        // Order the feature streams. Default: interleaved arrival
        // order (dense then sparse by id) — "effectively random"
        // w.r.t. training-job popularity.
        let order: Vec<FeatureId> = match &self.opts.feature_order {
            Some(o) => o.clone(),
            None => self
                .dense_ids
                .iter()
                .chain(self.sparse_ids.iter())
                .copied()
                .collect(),
        };
        // Index columns by feature id (a linear `find` per ordered
        // feature is O(F^2) — ~10% of write CPU at 1k features).
        let idx: Vec<_> = batches
            .iter()
            .map(|(g, batch)| {
                let dense: std::collections::HashMap<_, _> =
                    batch.dense.iter().map(|c| (c.id, c)).collect();
                let sparse: std::collections::HashMap<_, _> =
                    batch.sparse.iter().map(|c| (c.id, c)).collect();
                (*g, dense, sparse)
            })
            .collect();
        for fid in order {
            for (g, dense_idx, sparse_idx) in &idx {
                if let Some(col) = dense_idx.get(&fid) {
                    self.put_stream(
                        StreamKind::FlatDense,
                        fid.0,
                        *g,
                        encode_flat_dense(col),
                        streams,
                    );
                } else if let Some(col) = sparse_idx.get(&fid) {
                    self.put_stream(
                        StreamKind::FlatSparse,
                        fid.0,
                        *g,
                        encode_flat_sparse(col),
                        streams,
                    );
                }
            }
        }
    }

    /// Emit one stripe. `dedup` carries the stripe-local inverse index
    /// (Dedup encoding only; computed once per window upstream).
    fn emit_stripe(&mut self, samples: &[Sample], dedup: Option<&DedupIndex>) {
        if samples.is_empty() {
            return;
        }
        let rows = samples.len();
        let mut streams = Vec::new();
        // Footer statistics for predicate pushdown: computed over the
        // stripe's *rows* (for Dedup stripes, rows and unique payloads
        // carry the same feature-presence set, so row-level stats stay
        // conservative for both read paths).
        let stats = StripeStats::from_samples(samples);
        // Per-row-group zone maps (footer v3): fixed-size row runs with
        // their own stats, same conservative shape one level down.
        let rpg = self.opts.rows_per_group.max(1);
        let groups: Vec<RowGroupStats> = if self.opts.footer_version >= 3 {
            samples
                .chunks(rpg)
                .map(|c| RowGroupStats {
                    rows: c.len() as u32,
                    stats: StripeStats::from_samples(c),
                })
                .collect()
        } else {
            Vec::new()
        };
        // Row-group stream splitting: only the Flattened encoding has a
        // layout where fixed row runs map to independent streams (Map
        // rows are variable-width blobs; Dedup feature streams cover
        // stripe-level *unique* payloads, not row runs) — those
        // encodings keep whole-stripe streams and prune at decode via
        // the group mask instead.
        let split_groups =
            self.opts.encoding == Encoding::Flattened && groups.len() > 1;

        // Row meta (labels + timestamps) — always read. Under the Dedup
        // encoding this stays per-*row*: duplicate payloads keep their
        // own outcomes and event times (losslessness). Split per row
        // group when the stripe is, so pruned groups skip their row-meta
        // bytes too.
        if split_groups {
            for (g, chunk) in samples.chunks(rpg).enumerate() {
                let labels: Vec<f32> = chunk.iter().map(|s| s.label).collect();
                let ts: Vec<u64> =
                    chunk.iter().map(|s| s.timestamp).collect();
                self.put_stream(
                    StreamKind::RowMeta,
                    u32::MAX,
                    g as u32,
                    encode_row_meta(&labels, &ts),
                    &mut streams,
                );
            }
        } else {
            let labels: Vec<f32> = samples.iter().map(|s| s.label).collect();
            let ts: Vec<u64> = samples.iter().map(|s| s.timestamp).collect();
            self.put_stream(
                StreamKind::RowMeta,
                u32::MAX,
                WHOLE_STRIPE,
                encode_row_meta(&labels, &ts),
                &mut streams,
            );
        }

        match self.opts.encoding {
            Encoding::Map => {
                self.put_stream(
                    StreamKind::MapDense,
                    u32::MAX,
                    WHOLE_STRIPE,
                    encode_map_dense(samples),
                    &mut streams,
                );
                self.put_stream(
                    StreamKind::MapSparse,
                    u32::MAX,
                    WHOLE_STRIPE,
                    encode_map_sparse(samples),
                    &mut streams,
                );
            }
            Encoding::Flattened => {
                let batches: Vec<(u32, ColumnarBatch)> = if split_groups {
                    samples
                        .chunks(rpg)
                        .enumerate()
                        .map(|(g, chunk)| {
                            (
                                g as u32,
                                ColumnarBatch::from_samples(
                                    chunk,
                                    &self.dense_ids,
                                    &self.sparse_ids,
                                ),
                            )
                        })
                        .collect()
                } else {
                    vec![(
                        WHOLE_STRIPE,
                        ColumnarBatch::from_samples(
                            samples,
                            &self.dense_ids,
                            &self.sparse_ids,
                        ),
                    )]
                };
                self.put_feature_streams(&batches, &mut streams);
            }
            Encoding::Dedup => {
                let idx = dedup.expect("dedup stripe requires its index");
                self.put_stream(
                    StreamKind::DedupIndex,
                    u32::MAX,
                    WHOLE_STRIPE,
                    encode_dedup_index(&idx.inverse, idx.unique_count()),
                    &mut streams,
                );
                // Feature streams cover *unique* payloads only.
                let uniques: Vec<Sample> = idx
                    .unique_rows
                    .iter()
                    .map(|&r| samples[r].clone())
                    .collect();
                let batch = ColumnarBatch::from_samples(
                    &uniques,
                    &self.dense_ids,
                    &self.sparse_ids,
                );
                self.put_feature_streams(
                    &[(WHOLE_STRIPE, batch)],
                    &mut streams,
                );
            }
        }

        self.stripes.push(StripeInfo {
            row_start: self.rows_written,
            rows: rows as u32,
            stats,
            groups,
            streams,
        });
        self.rows_written += rows as u64;
    }

    /// Finish the file: flush the tail stripe, append footer + trailer.
    pub fn finish(mut self) -> Vec<u8> {
        self.flush_pending();
        let meta = FileMeta {
            encoding: self.opts.encoding,
            encrypted: self.opts.encrypt,
            total_rows: self.rows_written,
            stripes: std::mem::take(&mut self.stripes),
            file_len: 0, // filled by reader from actual length
        };
        let footer = meta.encode_footer_versioned(self.opts.footer_version);
        let mut out = std::mem::take(&mut self.buf);
        let flen = footer.len() as u64;
        out.extend_from_slice(&footer);
        out.extend_from_slice(&flen.to_le_bytes());
        out.extend_from_slice(&super::MAGIC.to_le_bytes());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SparseValue;

    fn mk_samples(n: usize) -> Vec<Sample> {
        (0..n as u64)
            .map(|i| {
                let mut s = Sample {
                    dense: vec![(FeatureId(0), i as f32)],
                    sparse: vec![(FeatureId(100), SparseValue::ids(vec![i]))],
                    label: 1.0,
                    timestamp: i,
                };
                s.sort_features();
                s
            })
            .collect()
    }

    fn writer(enc: Encoding, stripe_rows: usize) -> DwrfWriter {
        DwrfWriter::new(
            "t",
            vec![FeatureId(0), FeatureId(1)],
            vec![FeatureId(100)],
            WriterOptions {
                encoding: enc,
                stripe_rows,
                ..Default::default()
            },
        )
    }

    #[test]
    fn stripe_count_follows_stripe_rows() {
        let mut w = writer(Encoding::Flattened, 10);
        w.write_all(mk_samples(25));
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        assert_eq!(meta.stripes.len(), 3); // 10 + 10 + 5
        assert_eq!(meta.total_rows, 25);
        assert_eq!(meta.stripes[2].rows, 5);
        assert_eq!(meta.stripes[1].row_start, 10);
    }

    #[test]
    fn map_encoding_has_three_streams_per_stripe() {
        let mut w = writer(Encoding::Map, 100);
        w.write_all(mk_samples(10));
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        assert_eq!(meta.stripes.len(), 1);
        assert_eq!(meta.stripes[0].streams.len(), 3); // meta, dense, sparse
    }

    #[test]
    fn flattened_encoding_has_stream_per_feature() {
        let mut w = writer(Encoding::Flattened, 100);
        w.write_all(mk_samples(10));
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        // 1 row-meta + 2 dense + 1 sparse
        assert_eq!(meta.stripes[0].streams.len(), 4);
    }

    #[test]
    fn feature_order_controls_stream_layout() {
        let order = vec![FeatureId(100), FeatureId(1), FeatureId(0)];
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0), FeatureId(1)],
            vec![FeatureId(100)],
            WriterOptions {
                encoding: Encoding::Flattened,
                stripe_rows: 100,
                feature_order: Some(order),
                ..Default::default()
            },
        );
        w.write_all(mk_samples(10));
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        let feats: Vec<u32> = meta.stripes[0]
            .streams
            .iter()
            .filter(|s| s.feature != u32::MAX)
            .map(|s| s.feature)
            .collect();
        assert_eq!(feats, vec![100, 1, 0]);
        // Offsets must be increasing in written order.
        let offs: Vec<u64> =
            meta.stripes[0].streams.iter().map(|s| s.offset).collect();
        assert!(offs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn stripe_stats_recorded_in_footer() {
        let mut w = writer(Encoding::Flattened, 10);
        w.write_all(mk_samples(25)); // timestamps 0..25, labels all 1.0
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        assert_eq!(meta.stripes.len(), 3);
        let s0 = &meta.stripes[0].stats;
        assert_eq!(s0.min_timestamp, 0);
        assert_eq!(s0.max_timestamp, 9);
        assert_eq!(s0.label_positives, 10);
        assert!(s0.maybe_present(0));
        assert!(s0.maybe_present(100));
        let s2 = &meta.stripes[2].stats;
        assert_eq!(s2.min_timestamp, 20);
        assert_eq!(s2.max_timestamp, 24);
        assert_eq!(s2.label_positives, 5);
    }

    #[test]
    fn presence_filter_is_one_sided() {
        // A feature never written must read "absent" unless a hash
        // collision with a written feature flips its bit — check a batch
        // of ids so at least the written set is always "maybe present".
        let mut st = StripeStats::default();
        for f in [3u32, 900, 77] {
            st.mark_present(f);
        }
        for f in [3u32, 900, 77] {
            assert!(st.maybe_present(f));
        }
        assert!(!StripeStats::default().maybe_present(3));
    }

    #[test]
    fn empty_writer_produces_valid_empty_file() {
        let w = writer(Encoding::Flattened, 10);
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        assert_eq!(meta.total_rows, 0);
        assert!(meta.stripes.is_empty());
    }

    /// n samples, every `dup`-th a payload-duplicate of sample 0.
    fn mk_dup_samples(n: usize, dup: usize) -> Vec<Sample> {
        (0..n as u64)
            .map(|i| {
                let payload = if (i as usize) % dup == 0 { 0 } else { i };
                let mut s = Sample {
                    dense: vec![(FeatureId(0), payload as f32)],
                    sparse: vec![(
                        FeatureId(100),
                        SparseValue::ids(vec![payload, payload + 1]),
                    )],
                    label: (i % 2) as f32,
                    timestamp: 9000 + i,
                };
                s.sort_features();
                s
            })
            .collect()
    }

    #[test]
    fn dedup_stripe_has_index_stream_and_fewer_feature_bytes() {
        let samples = mk_dup_samples(32, 2); // half the rows share payload 0
        let build = |enc: Encoding| -> Vec<u8> {
            let mut w = DwrfWriter::new(
                "t",
                vec![FeatureId(0)],
                vec![FeatureId(100)],
                WriterOptions {
                    encoding: enc,
                    stripe_rows: 32,
                    encrypt: false,
                    ..Default::default()
                },
            );
            w.write_all(samples.clone());
            w.finish()
        };
        let flat = build(Encoding::Flattened);
        let dedup = build(Encoding::Dedup);
        let meta = crate::dwrf::reader::DwrfReader::open(&dedup).unwrap().meta;
        assert_eq!(meta.encoding, Encoding::Dedup);
        assert_eq!(meta.total_rows, 32);
        let kinds: Vec<StreamKind> = meta.stripes[0]
            .streams
            .iter()
            .map(|s| s.kind)
            .collect();
        assert!(kinds.contains(&StreamKind::DedupIndex));
        assert!(kinds.contains(&StreamKind::FlatDense));
        // Raw (pre-compression) feature bytes shrink: unique payloads only.
        let raw_feats = |m: &crate::dwrf::FileMeta| -> u64 {
            m.stripes
                .iter()
                .flat_map(|s| s.streams.iter())
                .filter(|s| {
                    matches!(
                        s.kind,
                        StreamKind::FlatDense | StreamKind::FlatSparse
                    )
                })
                .map(|s| s.raw_len)
                .sum()
        };
        let flat_meta =
            crate::dwrf::reader::DwrfReader::open(&flat).unwrap().meta;
        assert!(
            raw_feats(&meta) < raw_feats(&flat_meta),
            "dedup {} !< flat {}",
            raw_feats(&meta),
            raw_feats(&flat_meta)
        );
    }

    #[test]
    fn dedup_window_spans_multiple_stripes() {
        // Duplicates are 8 rows apart with stripe_rows=4: without the
        // clustering window they'd never share a stripe.
        let samples = mk_dup_samples(32, 8);
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0)],
            vec![FeatureId(100)],
            WriterOptions {
                encoding: Encoding::Dedup,
                stripe_rows: 4,
                dedup_window_stripes: 8,
                ..Default::default()
            },
        );
        w.write_all(samples);
        let bytes = w.finish();
        let meta = crate::dwrf::reader::DwrfReader::open(&bytes).unwrap().meta;
        assert_eq!(meta.total_rows, 32);
        assert_eq!(meta.stripes.len(), 8);
        // Every stripe is intact: rows sum and row_starts chain.
        let rows: u32 = meta.stripes.iter().map(|s| s.rows).sum();
        assert_eq!(rows, 32);
        for w in meta.stripes.windows(2) {
            assert_eq!(w[1].row_start, w[0].row_start + w[0].rows as u64);
        }
    }
}
