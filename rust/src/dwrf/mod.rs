//! DWRF — the warehouse columnar file format (§3.1.2), forked-from-ORC in
//! the paper, rebuilt here from scratch.
//!
//! A file is a sequence of *stripes* (a run of table rows); each stripe is
//! a set of compressed + encrypted *streams*; a footer indexes every
//! stream's file extent and (since footer v2) carries per-stripe
//! [`StripeStats`] — min/max timestamp, label positives, and a hashed
//! feature-presence filter — which predicate pushdown consults to skip
//! whole stripes before issuing any I/O. Two row encodings are supported:
//!
//! * [`Encoding::Map`] — the pre-optimization baseline: per-stripe dense
//!   and sparse *map* streams holding every feature of every row. Readers
//!   must fetch and decode the entire stripe to extract any feature.
//! * [`Encoding::Flattened`] — the paper's **feature flattening** (§7.5):
//!   each feature is materialized as its own stream, so a projection
//!   fetches only the features it needs — at the cost of many small I/Os
//!   (Table 6), which **coalesced reads** and **feature reordering**
//!   then repair.
//! * [`Encoding::Dedup`] — RecD-style flattened encoding: rows buffered
//!   over a clustering window are grouped by feature-payload content, so
//!   duplicate sessions land in the same stripe; each stripe stores each
//!   unique payload **once** plus a row→unique inverse index
//!   ([`StreamKind::DedupIndex`]) and per-row labels/timestamps —
//!   roundtrip-lossless up to the clustering permutation within a window.
//!
//! The writer supports the paper's co-designed knobs directly:
//! `stripe_rows` (large stripes), `feature_order` (feature reordering),
//! and the encoding choice (feature flattening).

pub mod crypto;
pub mod plan;
pub mod reader;
pub mod stream;
pub mod writer;

pub use plan::{IoBuffers, IoRange, ReadPlan, StripePlan};
pub use reader::{DecodeMode, DedupStripe, DwrfReader, Projection};
pub use stream::StreamKind;
pub use writer::{DwrfWriter, Encoding, WriterOptions};

use anyhow::{bail, Result};

pub const MAGIC: u32 = 0x4457_5246; // "DWRF"
pub const VERSION: u32 = 2;

/// Per-stripe row statistics recorded in the footer (v2): the metadata
/// predicate pushdown consults to skip whole stripes — and all their
/// I/Os — before a single data byte is fetched. Every field is
/// conservative: a pruning decision based on it can never drop a
/// matching row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeStats {
    /// Smallest / largest event timestamp among the stripe's rows.
    pub min_timestamp: u64,
    pub max_timestamp: u64,
    /// Rows with label > 0 (positives).
    pub label_positives: u32,
    /// 128-bit hashed feature-presence filter: the bit for feature `f`
    /// is set iff some row carries `f`. No false negatives ⇒ an unset
    /// bit proves the feature absent from the whole stripe.
    pub presence: [u64; 2],
}

impl Default for StripeStats {
    fn default() -> Self {
        StripeStats {
            min_timestamp: u64::MAX,
            max_timestamp: 0,
            label_positives: 0,
            presence: [0; 2],
        }
    }
}

impl StripeStats {
    fn presence_slot(feature: u32) -> (usize, u64) {
        let h = crate::transforms::hash64(feature as u64 ^ 0xD5F7_57A7);
        (((h >> 6) & 1) as usize, 1u64 << (h & 63))
    }

    pub fn mark_present(&mut self, feature: u32) {
        let (w, bit) = Self::presence_slot(feature);
        self.presence[w] |= bit;
    }

    /// `false` proves no row of the stripe has the feature; `true` is
    /// only "maybe" (hash collisions make it one-sided).
    pub fn maybe_present(&self, feature: u32) -> bool {
        let (w, bit) = Self::presence_slot(feature);
        self.presence[w] & bit != 0
    }

    pub fn observe(&mut self, sample: &crate::data::Sample) {
        self.min_timestamp = self.min_timestamp.min(sample.timestamp);
        self.max_timestamp = self.max_timestamp.max(sample.timestamp);
        if sample.label > 0.0 {
            self.label_positives += 1;
        }
        for (fid, _) in &sample.dense {
            self.mark_present(fid.0);
        }
        for (fid, v) in &sample.sparse {
            if !v.is_empty() {
                self.mark_present(fid.0);
            }
        }
    }

    pub fn from_samples(samples: &[crate::data::Sample]) -> StripeStats {
        let mut st = StripeStats::default();
        for s in samples {
            st.observe(s);
        }
        st
    }
}

/// Index entry for one stream within a stripe.
#[derive(Clone, Debug)]
pub struct StreamInfo {
    pub kind: StreamKind,
    /// Feature id for flattened streams; `u32::MAX` otherwise.
    pub feature: u32,
    /// Absolute file offset of the (compressed, encrypted) bytes.
    pub offset: u64,
    pub len: u64,
    /// Decompressed length (for memory accounting).
    pub raw_len: u64,
    /// AES-CTR nonce.
    pub nonce: u64,
    /// CRC32 of the stored bytes.
    pub crc: u32,
}

/// Index entry for one stripe.
#[derive(Clone, Debug)]
pub struct StripeInfo {
    pub row_start: u64,
    pub rows: u32,
    /// Row statistics for predicate pushdown (footer v2).
    pub stats: StripeStats,
    pub streams: Vec<StreamInfo>,
}

/// Parsed file footer.
#[derive(Clone, Debug)]
pub struct FileMeta {
    pub encoding: Encoding,
    pub encrypted: bool,
    pub total_rows: u64,
    pub stripes: Vec<StripeInfo>,
    /// Total file length including footer (for sizing).
    pub file_len: u64,
}

impl FileMeta {
    pub fn data_bytes(&self) -> u64 {
        self.stripes
            .iter()
            .flat_map(|s| s.streams.iter())
            .map(|st| st.len)
            .sum()
    }

    pub(crate) fn encode_footer(&self) -> Vec<u8> {
        use crate::util::bytes::{put_u32, put_u64, put_varint};
        let mut out = Vec::new();
        put_u32(&mut out, VERSION);
        out.push(match self.encoding {
            Encoding::Map => 0,
            Encoding::Flattened => 1,
            Encoding::Dedup => 2,
        });
        out.push(self.encrypted as u8);
        put_u64(&mut out, self.total_rows);
        put_varint(&mut out, self.stripes.len() as u64);
        for s in &self.stripes {
            put_u64(&mut out, s.row_start);
            put_u32(&mut out, s.rows);
            put_u64(&mut out, s.stats.min_timestamp);
            put_u64(&mut out, s.stats.max_timestamp);
            put_u32(&mut out, s.stats.label_positives);
            put_u64(&mut out, s.stats.presence[0]);
            put_u64(&mut out, s.stats.presence[1]);
            put_varint(&mut out, s.streams.len() as u64);
            for st in &s.streams {
                out.push(st.kind as u8);
                put_u32(&mut out, st.feature);
                put_u64(&mut out, st.offset);
                put_u64(&mut out, st.len);
                put_u64(&mut out, st.raw_len);
                put_u64(&mut out, st.nonce);
                put_u32(&mut out, st.crc);
            }
        }
        out
    }

    pub(crate) fn decode_footer(buf: &[u8], file_len: u64) -> Result<FileMeta> {
        use crate::util::bytes::ByteReader;
        let mut r = ByteReader::new(buf);
        let version = r.u32().ok_or_else(|| anyhow::anyhow!("short footer"))?;
        if version != VERSION {
            bail!("unsupported DWRF version {version}");
        }
        let enc = r.bytes(1).ok_or_else(|| anyhow::anyhow!("enc"))?[0];
        let encoding = match enc {
            0 => Encoding::Map,
            1 => Encoding::Flattened,
            2 => Encoding::Dedup,
            _ => bail!("bad encoding {enc}"),
        };
        let encrypted = r.bytes(1).ok_or_else(|| anyhow::anyhow!("encflag"))?[0] == 1;
        let total_rows = r.u64().ok_or_else(|| anyhow::anyhow!("rows"))?;
        let n_stripes = r.varint().ok_or_else(|| anyhow::anyhow!("n_stripes"))? as usize;
        let mut stripes = Vec::with_capacity(n_stripes);
        for _ in 0..n_stripes {
            let row_start = r.u64().ok_or_else(|| anyhow::anyhow!("row_start"))?;
            let rows = r.u32().ok_or_else(|| anyhow::anyhow!("stripe rows"))?;
            let stats = StripeStats {
                min_timestamp: r.u64().ok_or_else(|| anyhow::anyhow!("min_ts"))?,
                max_timestamp: r.u64().ok_or_else(|| anyhow::anyhow!("max_ts"))?,
                label_positives: r
                    .u32()
                    .ok_or_else(|| anyhow::anyhow!("positives"))?,
                presence: [
                    r.u64().ok_or_else(|| anyhow::anyhow!("presence0"))?,
                    r.u64().ok_or_else(|| anyhow::anyhow!("presence1"))?,
                ],
            };
            let n_streams =
                r.varint().ok_or_else(|| anyhow::anyhow!("n_streams"))? as usize;
            let mut streams = Vec::with_capacity(n_streams);
            for _ in 0..n_streams {
                let kind = StreamKind::from_u8(
                    r.bytes(1).ok_or_else(|| anyhow::anyhow!("kind"))?[0],
                )?;
                let feature = r.u32().ok_or_else(|| anyhow::anyhow!("feature"))?;
                let offset = r.u64().ok_or_else(|| anyhow::anyhow!("offset"))?;
                let len = r.u64().ok_or_else(|| anyhow::anyhow!("len"))?;
                let raw_len = r.u64().ok_or_else(|| anyhow::anyhow!("raw_len"))?;
                let nonce = r.u64().ok_or_else(|| anyhow::anyhow!("nonce"))?;
                let crc = r.u32().ok_or_else(|| anyhow::anyhow!("crc"))?;
                streams.push(StreamInfo {
                    kind,
                    feature,
                    offset,
                    len,
                    raw_len,
                    nonce,
                    crc,
                });
            }
            stripes.push(StripeInfo {
                row_start,
                rows,
                stats,
                streams,
            });
        }
        Ok(FileMeta {
            encoding,
            encrypted,
            total_rows,
            stripes,
            file_len,
        })
    }
}
