//! DWRF — the warehouse columnar file format (§3.1.2), forked-from-ORC in
//! the paper, rebuilt here from scratch.
//!
//! A file is a sequence of *stripes* (a run of table rows); each stripe is
//! a set of compressed + encrypted *streams*; a footer indexes every
//! stream's file extent and (since footer v2) carries per-stripe
//! [`StripeStats`] — min/max timestamp, label positives, and a hashed
//! feature-presence filter — which predicate pushdown consults to skip
//! whole stripes before issuing any I/O. Footer v3 refines the same
//! zone-map idea one level down: each stripe is tiled into fixed-size
//! *row groups* (`WriterOptions::rows_per_group`) with their own
//! [`RowGroupStats`], and flattened stripes additionally split their
//! row-meta and feature streams per row group so a pruned group's bytes
//! are never even fetched. Two row encodings are supported:
//!
//! * [`Encoding::Map`] — the pre-optimization baseline: per-stripe dense
//!   and sparse *map* streams holding every feature of every row. Readers
//!   must fetch and decode the entire stripe to extract any feature.
//! * [`Encoding::Flattened`] — the paper's **feature flattening** (§7.5):
//!   each feature is materialized as its own stream, so a projection
//!   fetches only the features it needs — at the cost of many small I/Os
//!   (Table 6), which **coalesced reads** and **feature reordering**
//!   then repair.
//! * [`Encoding::Dedup`] — RecD-style flattened encoding: rows buffered
//!   over a clustering window are grouped by feature-payload content, so
//!   duplicate sessions land in the same stripe; each stripe stores each
//!   unique payload **once** plus a row→unique inverse index
//!   ([`StreamKind::DedupIndex`]) and per-row labels/timestamps —
//!   roundtrip-lossless up to the clustering permutation within a window.
//!
//! The writer supports the paper's co-designed knobs directly:
//! `stripe_rows` (large stripes), `feature_order` (feature reordering),
//! and the encoding choice (feature flattening).

pub mod crypto;
pub mod plan;
pub mod reader;
pub mod stream;
pub mod writer;

pub use plan::{IoBuffers, IoRange, ReadPlan, StripePlan};
pub use reader::{DecodeMode, DedupStripe, DwrfReader, Projection};
pub use stream::StreamKind;
pub use writer::{DwrfWriter, Encoding, WriterOptions};

use anyhow::{bail, Result};

pub const MAGIC: u32 = 0x4457_5246; // "DWRF"
/// Current footer version. v2 added per-stripe [`StripeStats`]; v3 adds
/// per-row-group zone maps ([`RowGroupStats`]) and per-row-group stream
/// scoping. The reader parses both: a v2 footer simply has no group
/// stats, so pruning falls back to stripe granularity.
pub const VERSION: u32 = 3;
/// Oldest footer version the reader still parses.
pub const MIN_VERSION: u32 = 2;
/// `StreamInfo::row_group` value for streams that cover the whole stripe.
pub const WHOLE_STRIPE: u32 = u32::MAX;
/// Upper bound on any stream's decompressed size. Footer-derived
/// `raw_len` values size the decompression buffer, so an unvalidated
/// corrupt footer could demand a near-`u64::MAX` allocation before a
/// single content check runs; real streams are a few MB at most.
pub const MAX_STREAM_RAW_LEN: u64 = 1 << 30;

/// Per-stripe row statistics recorded in the footer (v2): the metadata
/// predicate pushdown consults to skip whole stripes — and all their
/// I/Os — before a single data byte is fetched. Every field is
/// conservative: a pruning decision based on it can never drop a
/// matching row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeStats {
    /// Smallest / largest event timestamp among the stripe's rows.
    pub min_timestamp: u64,
    pub max_timestamp: u64,
    /// Rows with label > 0 (positives).
    pub label_positives: u32,
    /// 128-bit hashed feature-presence filter: the bit for feature `f`
    /// is set iff some row carries `f`. No false negatives ⇒ an unset
    /// bit proves the feature absent from the whole stripe.
    pub presence: [u64; 2],
}

impl Default for StripeStats {
    fn default() -> Self {
        StripeStats {
            min_timestamp: u64::MAX,
            max_timestamp: 0,
            label_positives: 0,
            presence: [0; 2],
        }
    }
}

impl StripeStats {
    /// `min_timestamp > max_timestamp` can only arise from a stats
    /// record that observed **no** rows (the `Default` sentinel — an
    /// empty or fully-deduped stripe serializes exactly this). Pruning
    /// and selectivity estimation treat it as "no rows" explicitly
    /// instead of relying on accidental comparison behavior.
    pub fn is_empty_domain(&self) -> bool {
        self.min_timestamp > self.max_timestamp
    }

    fn presence_slot(feature: u32) -> (usize, u64) {
        let h = crate::transforms::hash64(feature as u64 ^ 0xD5F7_57A7);
        (((h >> 6) & 1) as usize, 1u64 << (h & 63))
    }

    pub fn mark_present(&mut self, feature: u32) {
        let (w, bit) = Self::presence_slot(feature);
        self.presence[w] |= bit;
    }

    /// `false` proves no row of the stripe has the feature; `true` is
    /// only "maybe" (hash collisions make it one-sided).
    pub fn maybe_present(&self, feature: u32) -> bool {
        let (w, bit) = Self::presence_slot(feature);
        self.presence[w] & bit != 0
    }

    pub fn observe(&mut self, sample: &crate::data::Sample) {
        self.min_timestamp = self.min_timestamp.min(sample.timestamp);
        self.max_timestamp = self.max_timestamp.max(sample.timestamp);
        if sample.label > 0.0 {
            self.label_positives += 1;
        }
        for (fid, _) in &sample.dense {
            self.mark_present(fid.0);
        }
        for (fid, v) in &sample.sparse {
            if !v.is_empty() {
                self.mark_present(fid.0);
            }
        }
    }

    pub fn from_samples(samples: &[crate::data::Sample]) -> StripeStats {
        let mut st = StripeStats::default();
        for s in samples {
            st.observe(s);
        }
        st
    }
}

/// Zone map for one row group — a fixed-size run of consecutive rows
/// inside a stripe (footer v3). Same conservative shape as the stripe
/// stats, so the identical pruning logic applies one level down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowGroupStats {
    pub rows: u32,
    pub stats: StripeStats,
}

/// Index entry for one stream within a stripe.
#[derive(Clone, Debug)]
pub struct StreamInfo {
    pub kind: StreamKind,
    /// Feature id for flattened streams; `u32::MAX` otherwise.
    pub feature: u32,
    /// Row group this stream covers (footer v3, row-group-split stripes
    /// only); [`WHOLE_STRIPE`] for streams spanning every row. A stream
    /// scoped to a pruned row group is never fetched — this is what lets
    /// the planner shrink I/O ranges below stripe granularity.
    pub row_group: u32,
    /// Absolute file offset of the (compressed, encrypted) bytes.
    pub offset: u64,
    pub len: u64,
    /// Decompressed length (for memory accounting).
    pub raw_len: u64,
    /// AES-CTR nonce.
    pub nonce: u64,
    /// CRC32 of the stored bytes.
    pub crc: u32,
}

/// Index entry for one stripe.
#[derive(Clone, Debug)]
pub struct StripeInfo {
    pub row_start: u64,
    pub rows: u32,
    /// Row statistics for predicate pushdown (footer v2).
    pub stats: StripeStats,
    /// Per-row-group zone maps (footer v3). Empty on v2 files — pruning
    /// then falls back to stripe granularity. When present, the groups'
    /// row counts sum to `rows` (validated at decode).
    pub groups: Vec<RowGroupStats>,
    pub streams: Vec<StreamInfo>,
}

impl StripeInfo {
    /// Stripe-local `[start, end)` row ranges of the row groups, in
    /// order (empty when the stripe has no group stats).
    pub fn group_row_ranges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.groups.len());
        let mut start = 0usize;
        for g in &self.groups {
            let end = start + g.rows as usize;
            out.push((start, end));
            start = end;
        }
        out
    }

    /// `true` proves no row of this stripe can match `p`: either the
    /// stripe-level stats prune it, or — one level down — every row
    /// group's zone map does.
    pub fn pruned_by(&self, p: &crate::filter::RowPredicate) -> bool {
        self.pruned_at(p, true)
    }

    /// [`StripeInfo::pruned_by`] with the row-group granularity
    /// switchable. This is the **single** prune decision both the
    /// Master's split enumeration / broker interest registration and
    /// the reader's planner call — one implementation, so they cannot
    /// drift apart (a stripe the Master records as skipped must be one
    /// no worker plan would ever fetch).
    pub fn pruned_at(
        &self,
        p: &crate::filter::RowPredicate,
        row_groups: bool,
    ) -> bool {
        if p.prunes_stripe(&self.stats, self.rows) {
            return true;
        }
        row_groups
            && !self.groups.is_empty()
            && self
                .groups
                .iter()
                .all(|g| p.prunes_stripe(&g.stats, g.rows))
    }

    /// Per-row-group survival mask under `p` (`true` = must decode).
    /// `None` when the footer carries no group stats (v2 fallback).
    pub fn surviving_groups(
        &self,
        p: &crate::filter::RowPredicate,
    ) -> Option<Vec<bool>> {
        if self.groups.is_empty() {
            return None;
        }
        Some(
            self.groups
                .iter()
                .map(|g| !p.prunes_stripe(&g.stats, g.rows))
                .collect(),
        )
    }

    /// Stripe-local indices of the rows inside surviving groups — the
    /// pre-seeded selection the decode paths honor so pruned groups are
    /// never materialized.
    pub fn keep_rows(&self, mask: &[bool]) -> Vec<u32> {
        let mut out = Vec::new();
        for (g, (start, end)) in self.group_row_ranges().into_iter().enumerate()
        {
            if mask.get(g).copied().unwrap_or(true) {
                out.extend((start as u32)..(end as u32));
            }
        }
        out
    }
}

/// Parsed file footer.
#[derive(Clone, Debug)]
pub struct FileMeta {
    pub encoding: Encoding,
    pub encrypted: bool,
    pub total_rows: u64,
    pub stripes: Vec<StripeInfo>,
    /// Total file length including footer (for sizing).
    pub file_len: u64,
}

impl FileMeta {
    pub fn data_bytes(&self) -> u64 {
        self.stripes
            .iter()
            .flat_map(|s| s.streams.iter())
            .map(|st| st.len)
            .sum()
    }

    /// Encode the footer at a specific version. `version == 2` emits the
    /// legacy layout (no row-group stats, no per-group stream scoping) —
    /// kept so compatibility tests can produce byte-real old files; the
    /// writer refuses to combine it with row-group-split stripes.
    pub(crate) fn encode_footer_versioned(&self, version: u32) -> Vec<u8> {
        use crate::util::bytes::{put_u32, put_u64, put_varint};
        assert!(
            (MIN_VERSION..=VERSION).contains(&version),
            "unwritable DWRF footer version {version}"
        );
        let mut out = Vec::new();
        put_u32(&mut out, version);
        out.push(match self.encoding {
            Encoding::Map => 0,
            Encoding::Flattened => 1,
            Encoding::Dedup => 2,
        });
        out.push(self.encrypted as u8);
        put_u64(&mut out, self.total_rows);
        put_varint(&mut out, self.stripes.len() as u64);
        let put_stats = |out: &mut Vec<u8>, st: &StripeStats| {
            put_u64(out, st.min_timestamp);
            put_u64(out, st.max_timestamp);
            put_u32(out, st.label_positives);
            put_u64(out, st.presence[0]);
            put_u64(out, st.presence[1]);
        };
        for s in &self.stripes {
            put_u64(&mut out, s.row_start);
            put_u32(&mut out, s.rows);
            put_stats(&mut out, &s.stats);
            if version >= 3 {
                put_varint(&mut out, s.groups.len() as u64);
                for g in &s.groups {
                    put_u32(&mut out, g.rows);
                    put_stats(&mut out, &g.stats);
                }
            } else {
                assert!(
                    s.streams.iter().all(|st| st.row_group == WHOLE_STRIPE),
                    "v2 footers cannot index row-group-scoped streams"
                );
            }
            put_varint(&mut out, s.streams.len() as u64);
            for st in &s.streams {
                out.push(st.kind as u8);
                put_u32(&mut out, st.feature);
                if version >= 3 {
                    put_u32(&mut out, st.row_group);
                }
                put_u64(&mut out, st.offset);
                put_u64(&mut out, st.len);
                put_u64(&mut out, st.raw_len);
                put_u64(&mut out, st.nonce);
                put_u32(&mut out, st.crc);
            }
        }
        out
    }

    pub(crate) fn decode_footer(buf: &[u8], file_len: u64) -> Result<FileMeta> {
        use crate::util::bytes::ByteReader;
        let mut r = ByteReader::new(buf);
        let version = r.u32().ok_or_else(|| anyhow::anyhow!("short footer"))?;
        if !(MIN_VERSION..=VERSION).contains(&version) {
            bail!("unsupported DWRF version {version}");
        }
        let enc = r.bytes(1).ok_or_else(|| anyhow::anyhow!("enc"))?[0];
        let encoding = match enc {
            0 => Encoding::Map,
            1 => Encoding::Flattened,
            2 => Encoding::Dedup,
            _ => bail!("bad encoding {enc}"),
        };
        let encrypted = r.bytes(1).ok_or_else(|| anyhow::anyhow!("encflag"))?[0] == 1;
        let total_rows = r.u64().ok_or_else(|| anyhow::anyhow!("rows"))?;
        let n_stripes = r.varint().ok_or_else(|| anyhow::anyhow!("n_stripes"))? as usize;
        let read_stats = |r: &mut ByteReader<'_>| -> Result<StripeStats> {
            Ok(StripeStats {
                min_timestamp: r.u64().ok_or_else(|| anyhow::anyhow!("min_ts"))?,
                max_timestamp: r.u64().ok_or_else(|| anyhow::anyhow!("max_ts"))?,
                label_positives: r
                    .u32()
                    .ok_or_else(|| anyhow::anyhow!("positives"))?,
                presence: [
                    r.u64().ok_or_else(|| anyhow::anyhow!("presence0"))?,
                    r.u64().ok_or_else(|| anyhow::anyhow!("presence1"))?,
                ],
            })
        };
        // Counts come straight off disk: clamp pre-allocations so a
        // fuzzed footer can't trigger a huge reservation before the
        // per-entry reads run out of bytes and error.
        let cap = |n: usize| n.min(4096);
        let mut stripes = Vec::with_capacity(cap(n_stripes));
        for _ in 0..n_stripes {
            let row_start = r.u64().ok_or_else(|| anyhow::anyhow!("row_start"))?;
            let rows = r.u32().ok_or_else(|| anyhow::anyhow!("stripe rows"))?;
            let stats = read_stats(&mut r)?;
            let mut groups = Vec::new();
            if version >= 3 {
                let n_groups =
                    r.varint().ok_or_else(|| anyhow::anyhow!("n_groups"))? as usize;
                groups.reserve(cap(n_groups));
                for _ in 0..n_groups {
                    let g_rows =
                        r.u32().ok_or_else(|| anyhow::anyhow!("group rows"))?;
                    let g_stats = read_stats(&mut r)?;
                    groups.push(RowGroupStats {
                        rows: g_rows,
                        stats: g_stats,
                    });
                }
                // Zone maps must tile the stripe exactly, or a pruning
                // mask could silently drop live rows.
                if !groups.is_empty() {
                    let sum: u64 = groups.iter().map(|g| g.rows as u64).sum();
                    if sum != rows as u64 {
                        bail!(
                            "row groups cover {sum} rows, stripe has {rows}"
                        );
                    }
                }
            }
            let n_streams =
                r.varint().ok_or_else(|| anyhow::anyhow!("n_streams"))? as usize;
            let mut streams = Vec::with_capacity(cap(n_streams));
            for _ in 0..n_streams {
                let kind = StreamKind::from_u8(
                    r.bytes(1).ok_or_else(|| anyhow::anyhow!("kind"))?[0],
                )?;
                let feature = r.u32().ok_or_else(|| anyhow::anyhow!("feature"))?;
                let row_group = if version >= 3 {
                    r.u32().ok_or_else(|| anyhow::anyhow!("row_group"))?
                } else {
                    WHOLE_STRIPE
                };
                let offset = r.u64().ok_or_else(|| anyhow::anyhow!("offset"))?;
                let len = r.u64().ok_or_else(|| anyhow::anyhow!("len"))?;
                let raw_len = r.u64().ok_or_else(|| anyhow::anyhow!("raw_len"))?;
                let nonce = r.u64().ok_or_else(|| anyhow::anyhow!("nonce"))?;
                let crc = r.u32().ok_or_else(|| anyhow::anyhow!("crc"))?;
                // Every stream extent is footer-derived and therefore
                // untrusted: validate against the real file length here,
                // once, so no read path can slice out of bounds (or
                // overflow `offset + len`) on a corrupt footer.
                let end = offset.checked_add(len).ok_or_else(|| {
                    anyhow::anyhow!(
                        "stream extent overflows: offset {offset} + len {len}"
                    )
                })?;
                if end > file_len {
                    bail!(
                        "stream extent [{offset}, {end}) exceeds file \
                         length {file_len}"
                    );
                }
                if raw_len > MAX_STREAM_RAW_LEN {
                    bail!("stream raw_len {raw_len} exceeds sanity cap");
                }
                if row_group != WHOLE_STRIPE
                    && row_group as usize >= groups.len()
                {
                    bail!(
                        "stream scoped to row group {row_group} of {}",
                        groups.len()
                    );
                }
                streams.push(StreamInfo {
                    kind,
                    feature,
                    row_group,
                    offset,
                    len,
                    raw_len,
                    nonce,
                    crc,
                });
            }
            stripes.push(StripeInfo {
                row_start,
                rows,
                stats,
                groups,
                streams,
            });
        }
        Ok(FileMeta {
            encoding,
            encrypted,
            total_rows,
            stripes,
            file_len,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(min: u64, max: u64) -> StripeStats {
        StripeStats {
            min_timestamp: min,
            max_timestamp: max,
            label_positives: 3,
            presence: [5, 9],
        }
    }

    fn stream(
        kind: StreamKind,
        row_group: u32,
        offset: u64,
        len: u64,
    ) -> StreamInfo {
        StreamInfo {
            kind,
            feature: 7,
            row_group,
            offset,
            len,
            raw_len: len * 2,
            nonce: 11,
            crc: 22,
        }
    }

    fn meta_with(stripes: Vec<StripeInfo>) -> FileMeta {
        FileMeta {
            encoding: Encoding::Flattened,
            encrypted: true,
            total_rows: stripes.iter().map(|s| s.rows as u64).sum(),
            stripes,
            file_len: 0,
        }
    }

    fn grouped_stripe() -> StripeInfo {
        StripeInfo {
            row_start: 0,
            rows: 10,
            stats: stats(100, 199),
            groups: vec![
                RowGroupStats {
                    rows: 6,
                    stats: stats(100, 149),
                },
                RowGroupStats {
                    rows: 4,
                    stats: stats(150, 199),
                },
            ],
            streams: vec![
                stream(StreamKind::RowMeta, 0, 0, 10),
                stream(StreamKind::RowMeta, 1, 10, 10),
                stream(StreamKind::FlatDense, 0, 20, 30),
                stream(StreamKind::FlatDense, 1, 50, 30),
            ],
        }
    }

    #[test]
    fn footer_v3_roundtrips_groups_and_stream_scoping() {
        let meta = meta_with(vec![grouped_stripe()]);
        let buf = meta.encode_footer_versioned(VERSION);
        let back = FileMeta::decode_footer(&buf, 1 << 20).unwrap();
        assert_eq!(back.total_rows, 10);
        let s = &back.stripes[0];
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].rows, 6);
        assert_eq!(s.groups[1].stats, stats(150, 199));
        assert_eq!(s.group_row_ranges(), vec![(0, 6), (6, 10)]);
        let rgs: Vec<u32> = s.streams.iter().map(|st| st.row_group).collect();
        assert_eq!(rgs, vec![0, 1, 0, 1]);
        assert_eq!(s.keep_rows(&[false, true]), vec![6, 7, 8, 9]);
        assert_eq!(s.keep_rows(&[true, false]), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn footer_v2_roundtrips_without_groups_and_v3_reader_accepts() {
        // A v2 footer (the legacy layout real old files carry) must
        // parse under the current reader with empty group stats — the
        // stats-less fallback that keeps pruning at stripe granularity.
        let mut st = grouped_stripe();
        st.groups.clear();
        for s in &mut st.streams {
            s.row_group = WHOLE_STRIPE;
        }
        let meta = meta_with(vec![st]);
        let buf = meta.encode_footer_versioned(2);
        let back = FileMeta::decode_footer(&buf, 1 << 20).unwrap();
        assert!(back.stripes[0].groups.is_empty());
        assert!(back.stripes[0]
            .streams
            .iter()
            .all(|s| s.row_group == WHOLE_STRIPE));
        // And the same logical content encodes differently but decodes
        // identically-shaped under v3.
        let v3 = FileMeta::decode_footer(
            &meta.encode_footer_versioned(VERSION),
            1 << 20,
        )
        .unwrap();
        assert_eq!(v3.stripes[0].streams.len(), back.stripes[0].streams.len());
    }

    #[test]
    fn corrupt_footer_extents_error_instead_of_panicking() {
        // Out-of-range extent: offset + len past the file end.
        let mut st = grouped_stripe();
        st.streams[2] = stream(StreamKind::FlatDense, 0, 100, 100);
        let buf = meta_with(vec![st]).encode_footer_versioned(VERSION);
        let err = FileMeta::decode_footer(&buf, 150).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds file length"));

        // Overflowing extent: offset + len wraps u64.
        let mut st = grouped_stripe();
        st.streams[3] = stream(StreamKind::FlatDense, 1, u64::MAX - 4, 16);
        let buf = meta_with(vec![st]).encode_footer_versioned(VERSION);
        let err = FileMeta::decode_footer(&buf, 1 << 20).unwrap_err();
        assert!(format!("{err:#}").contains("overflows"));

        // Row groups that don't tile the stripe.
        let mut st = grouped_stripe();
        st.groups[1].rows = 5; // 6 + 5 != 10
        let buf = meta_with(vec![st]).encode_footer_versioned(VERSION);
        assert!(FileMeta::decode_footer(&buf, 1 << 20).is_err());

        // A stream scoped to a group that doesn't exist.
        let mut st = grouped_stripe();
        st.streams[3].row_group = 9;
        let buf = meta_with(vec![st]).encode_footer_versioned(VERSION);
        assert!(FileMeta::decode_footer(&buf, 1 << 20).is_err());

        // Truncations error at every cut point.
        let buf = meta_with(vec![grouped_stripe()])
            .encode_footer_versioned(VERSION);
        for cut in [0, 1, 4, buf.len() / 2, buf.len() - 1] {
            assert!(
                FileMeta::decode_footer(&buf[..cut], 1 << 20).is_err(),
                "cut at {cut} must error"
            );
        }
    }

    #[test]
    fn degenerate_group_stats_prune_and_helpers_agree() {
        use crate::filter::RowPredicate;
        let mut st = grouped_stripe();
        // Second group's stats degenerate (min > max): treated as "no
        // rows" — pruned under any predicate.
        st.groups[1].stats = StripeStats::default();
        let keep_all = RowPredicate::SampleRate { rate: 1.0, seed: 0 };
        assert!(!st.pruned_by(&keep_all), "first group still live");
        assert_eq!(
            st.surviving_groups(&keep_all),
            Some(vec![true, false]),
            "degenerate group masked out"
        );
        // Both groups degenerate ⇒ the stripe itself is provably dead
        // even though its stripe-level stats look alive.
        st.groups[0].stats = StripeStats::default();
        assert!(st.pruned_by(&keep_all));
        // v2 fallback: no groups ⇒ no mask, stripe-level only.
        st.groups.clear();
        assert!(st.surviving_groups(&keep_all).is_none());
        assert!(!st.pruned_by(&keep_all));
    }
}
