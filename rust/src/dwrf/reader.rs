//! DWRF reader: footer parsing, projection-driven read planning, and
//! stripe decoding (to row maps or to the columnar flatmap).

use super::crypto::StreamCipher;
use super::plan::{coalesce, IoBuffers, IoRange, ReadPlan, StripePlan};
use super::stream::{
    decode_dedup_index, decode_flat_dense, decode_flat_sparse,
    decode_map_dense, decode_map_sparse, decode_row_meta, StreamKind,
};
use super::{Encoding, FileMeta, WHOLE_STRIPE};
use crate::broker::{ColumnId, SharedColumn};
use crate::data::{ColumnarBatch, DenseColumn, Sample, SparseColumn};
use crate::filter::RowPredicate;
use crate::schema::FeatureId;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::sync::Arc;

/// A decoded Dedup-encoded stripe, *before* expansion: feature columns
/// over unique payloads, the row→unique inverse index, and per-row
/// labels/timestamps. The dedup-aware DPP worker transforms `unique`
/// once and ships the inverse; [`DedupStripe::expand`] reconstructs the
/// full per-row batch for duplication-oblivious consumers.
#[derive(Clone, Debug)]
pub struct DedupStripe {
    /// Feature columns over unique payloads (`num_rows` = unique count;
    /// its labels/timestamps are placeholders — see the row-level fields).
    pub unique: ColumnarBatch,
    pub inverse: Vec<u32>,
    pub labels: Vec<f32>,
    pub timestamps: Vec<u64>,
}

impl DedupStripe {
    /// Full (pre-dedup) row count.
    pub fn rows(&self) -> usize {
        self.inverse.len()
    }

    /// rows / unique payloads in this stripe.
    pub fn factor(&self) -> f64 {
        if self.unique.num_rows == 0 {
            1.0
        } else {
            self.inverse.len() as f64 / self.unique.num_rows as f64
        }
    }

    /// Materialize the full per-row batch (features gathered through the
    /// inverse index; labels/timestamps from the row-level streams).
    pub fn expand(&self) -> ColumnarBatch {
        let mut batch = self.unique.gather(&self.inverse);
        batch.labels = self.labels.clone();
        batch.timestamps = self.timestamps.clone();
        batch
    }

    /// Restrict the unique payload columns to `projection`; the inverse
    /// index and per-row meta are untouched. This is a session's view of
    /// a stripe decoded **once** with a wider shared projection (the
    /// read broker's union across registered sessions) — identical to
    /// having decoded with `projection` directly.
    pub fn project(&self, projection: &Projection) -> DedupStripe {
        DedupStripe {
            unique: self
                .unique
                .retain_features(|f| projection.contains(f)),
            inverse: self.inverse.clone(),
            labels: self.labels.clone(),
            timestamps: self.timestamps.clone(),
        }
    }

    /// Restrict to the surviving rows of a predicate selection (`keep` =
    /// ascending row indices): row meta and inverse are gathered, and the
    /// unique payloads are compacted to the ones still referenced — so
    /// the dedup-aware transform stage never touches a filtered-out
    /// payload.
    pub fn filter_rows(&self, keep: &[u32]) -> DedupStripe {
        let mut slot: Vec<u32> = vec![u32::MAX; self.unique.num_rows];
        let mut used: Vec<u32> = Vec::new();
        let mut inverse = Vec::with_capacity(keep.len());
        for &r in keep {
            let u = self.inverse[r as usize] as usize;
            if slot[u] == u32::MAX {
                slot[u] = used.len() as u32;
                used.push(u as u32);
            }
            inverse.push(slot[u]);
        }
        DedupStripe {
            unique: self.unique.gather(&used),
            inverse,
            labels: keep.iter().map(|&r| self.labels[r as usize]).collect(),
            timestamps: keep
                .iter()
                .map(|&r| self.timestamps[r as usize])
                .collect(),
        }
    }
}

/// Column filter: the set of features a training job reads (§5.1).
#[derive(Clone, Debug, Default)]
pub struct Projection {
    features: HashSet<FeatureId>,
}

impl Projection {
    pub fn new(features: impl IntoIterator<Item = FeatureId>) -> Projection {
        Projection {
            features: features.into_iter().collect(),
        }
    }

    pub fn contains(&self, id: FeatureId) -> bool {
        self.features.contains(&id)
    }

    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &FeatureId> {
        self.features.iter()
    }
}

/// Decode options.
#[derive(Clone, Copy, Debug)]
pub struct DecodeMode {
    /// Use the branch-lean inner loops (the paper's +LO).
    pub fast: bool,
}

impl Default for DecodeMode {
    fn default() -> Self {
        DecodeMode { fast: true }
    }
}

pub struct DwrfReader {
    pub meta: FileMeta,
    cipher: StreamCipher,
}

impl DwrfReader {
    /// Parse a complete in-memory file (tests / local use). The storage
    /// pipeline uses [`DwrfReader::footer_ios`] + [`DwrfReader::from_footer`]
    /// to avoid fetching the whole file.
    pub fn open(bytes: &[u8]) -> Result<DwrfReader> {
        Self::open_table(bytes, "default")
    }

    /// Construct from an already-parsed footer (the DPP worker path:
    /// the Master / worker cache fetches footers once via ranged reads).
    pub fn from_meta(meta: FileMeta, table: &str) -> DwrfReader {
        DwrfReader {
            meta,
            cipher: StreamCipher::for_table(table),
        }
    }

    pub fn open_table(bytes: &[u8], table: &str) -> Result<DwrfReader> {
        let file_len = bytes.len() as u64;
        let (foff, flen) = Self::footer_extent(bytes)?;
        // dsi-lint: allow(unchecked-wire-arith): footer_extent proved
        // foff + flen == bytes.len() - 12, so the sum cannot wrap.
        let footer = &bytes[foff as usize..(foff + flen) as usize];
        let meta = FileMeta::decode_footer(footer, file_len)?;
        Ok(DwrfReader {
            meta,
            cipher: StreamCipher::for_table(table),
        })
    }

    /// Locate the footer from the 12-byte trailer.
    fn footer_extent(bytes: &[u8]) -> Result<(u64, u64)> {
        if bytes.len() < 12 {
            bail!("file too short for DWRF trailer");
        }
        let n = bytes.len();
        let magic = u32::from_le_bytes(bytes[n - 4..].try_into().unwrap());
        if magic != super::MAGIC {
            bail!("bad DWRF magic {magic:#x}");
        }
        let flen = u64::from_le_bytes(bytes[n - 12..n - 4].try_into().unwrap());
        // `flen` comes straight off disk: a corrupt value near u64::MAX
        // would wrap `flen + 12` and underflow the offset — reject it.
        let total = flen.checked_add(12).filter(|&t| t <= n as u64);
        let Some(total) = total else {
            bail!("corrupt footer length {flen}");
        };
        Ok((n as u64 - total, flen))
    }

    /// The bootstrap tail read a remote reader starts from: one I/O
    /// covering the trailer plus a generous footer estimate (the
    /// paper's readers likewise fetch per-feature metadata before
    /// data). **Contract: the footer may be larger than this probe** —
    /// v3 footers grow with stripes × row groups — so every caller must
    /// re-read with a bigger tail when the trailer's `footer_len` says
    /// the probe fell short. [`crate::dpp::Master::fetch_meta`] (which
    /// the broker's footer cache and the worker path go through) is the
    /// canonical loop: it starts from this probe and doubles until the
    /// footer fits.
    pub fn footer_ios(file_len: u64) -> IoRange {
        let len = file_len.min(256 * 1024);
        IoRange {
            offset: file_len - len,
            len,
        }
    }

    /// Build the read plan for a projection.
    ///
    /// * `Map` encoding: every stripe's map streams must be fetched whole —
    ///   the row filter/column filter can only apply after decode.
    /// * `Flattened`: only the projected features' streams are fetched.
    /// * `coalesce_window`: `None` → one I/O per stream (post-FF baseline);
    ///   `Some(w)` → coalesced reads (§7.5).
    pub fn plan(
        &self,
        projection: &Projection,
        coalesce_window: Option<u64>,
    ) -> ReadPlan {
        self.plan_stripes(projection, coalesce_window, 0, self.meta.stripes.len())
    }

    /// [`DwrfReader::plan`] with a row predicate pushed down: stripes the
    /// footer stats prove row-free are skipped outright.
    pub fn plan_filtered(
        &self,
        projection: &Projection,
        coalesce_window: Option<u64>,
        predicate: Option<&RowPredicate>,
    ) -> ReadPlan {
        self.plan_stripes_filtered(
            projection,
            coalesce_window,
            0,
            self.meta.stripes.len(),
            predicate,
        )
    }

    /// Plan only stripes `[start, start+count)` — the unit a DPP split
    /// covers.
    pub fn plan_stripes(
        &self,
        projection: &Projection,
        coalesce_window: Option<u64>,
        start: usize,
        count: usize,
    ) -> ReadPlan {
        self.plan_stripes_filtered(projection, coalesce_window, start, count, None)
    }

    /// [`DwrfReader::plan_stripes`] with predicate pushdown: before any
    /// extent is considered, each stripe's footer [`super::StripeStats`]
    /// are tested against the predicate; provably-empty stripes produce
    /// **no** I/O and are recorded in [`ReadPlan::skipped_stripes`] with
    /// their forgone bytes in [`ReadPlan::skipped_bytes`]. Surviving
    /// stripes are then pruned one level down against their row-group
    /// zone maps (footer v3): the plan carries the per-group survival
    /// mask, and streams scoped to pruned groups are dropped from the
    /// I/O set outright.
    pub fn plan_stripes_filtered(
        &self,
        projection: &Projection,
        coalesce_window: Option<u64>,
        start: usize,
        count: usize,
        predicate: Option<&RowPredicate>,
    ) -> ReadPlan {
        self.plan_stripes_granular(
            projection,
            coalesce_window,
            start,
            count,
            predicate,
            true,
        )
    }

    /// [`DwrfReader::plan_stripes_filtered`] with row-group pruning
    /// switchable (`row_groups = false` limits pushdown to stripe
    /// granularity — the pre-zone-map behavior, kept for ablation).
    pub fn plan_stripes_granular(
        &self,
        projection: &Projection,
        coalesce_window: Option<u64>,
        start: usize,
        count: usize,
        predicate: Option<&RowPredicate>,
        row_groups: bool,
    ) -> ReadPlan {
        let mut plan = ReadPlan::default();
        let end = (start + count).min(self.meta.stripes.len());
        for (si, stripe) in self
            .meta
            .stripes
            .iter()
            .enumerate()
            .take(end)
            .skip(start)
        {
            let pruned =
                predicate.is_some_and(|p| stripe.pruned_at(p, row_groups));
            // Sub-stripe zone maps: survival mask per row group, kept
            // only when it actually prunes something (an all-true mask
            // plans and decodes exactly like no mask).
            let mask: Option<Vec<bool>> = if pruned || !row_groups {
                None
            } else {
                predicate
                    .and_then(|p| stripe.surviving_groups(p))
                    .filter(|m| m.iter().any(|&keep| !keep))
            };
            let mut wanted = Vec::new();
            let mut pruned_group_bytes = 0u64;
            for (i, st) in stripe.streams.iter().enumerate() {
                let take = match st.kind {
                    StreamKind::RowMeta
                    | StreamKind::MapDense
                    | StreamKind::MapSparse
                    | StreamKind::DedupIndex => true,
                    StreamKind::FlatDense | StreamKind::FlatSparse => {
                        projection.contains(FeatureId(st.feature))
                    }
                };
                if !take {
                    continue;
                }
                // A stream scoped to a pruned row group is never
                // fetched — this is where the I/O ranges shrink below
                // stripe granularity.
                if let Some(m) = &mask {
                    if st.row_group != WHOLE_STRIPE
                        && !m
                            .get(st.row_group as usize)
                            .copied()
                            .unwrap_or(true)
                    {
                        pruned_group_bytes += st.len;
                        continue;
                    }
                }
                wanted.push(i);
            }
            let extents: Vec<IoRange> = wanted
                .iter()
                .map(|&i| {
                    let st = &stripe.streams[i];
                    IoRange {
                        offset: st.offset,
                        len: st.len,
                    }
                })
                .collect();
            let wanted_bytes = extents.iter().map(|e| e.len).sum::<u64>();
            if pruned {
                plan.skipped_stripes.push(si);
                plan.skipped_bytes += wanted_bytes;
                continue;
            }
            if let Some(m) = &mask {
                for (g, &keep) in m.iter().enumerate() {
                    if !keep {
                        plan.pruned_groups += 1;
                        plan.pruned_group_rows += stripe
                            .groups
                            .get(g)
                            .map_or(0, |rg| rg.rows as u64);
                    }
                }
                plan.pruned_group_bytes += pruned_group_bytes;
            }
            plan.useful_bytes += wanted_bytes;
            let ios = coalesce(extents, coalesce_window);
            plan.read_bytes += ios.iter().map(|e| e.len).sum::<u64>();
            plan.stripes.push(StripePlan {
                stripe: si,
                wanted_streams: wanted,
                ios,
                group_mask: mask,
            });
        }
        plan
    }

    /// Decrypt + decompress one stream out of fetched buffers.
    fn stream_bytes(
        &self,
        stripe: usize,
        stream: usize,
        bufs: &IoBuffers,
    ) -> Result<Vec<u8>> {
        let st = &self.meta.stripes[stripe].streams[stream];
        let data = bufs
            .slice(st.offset, st.len)
            .with_context(|| format!("stream extent not fetched: {st:?}"))?;
        if crc32fast::hash(data) != st.crc {
            bail!("stream crc mismatch at stripe {stripe} stream {stream}");
        }
        let mut data = data.to_vec();
        if self.meta.encrypted {
            self.cipher.apply(st.nonce, &mut data);
        }
        // Thread-local reused DCtx: a fresh zstd context per stream is
        // measurable on the extract path (EXPERIMENTS.md §Perf).
        thread_local! {
            static DCTX: std::cell::RefCell<zstd::bulk::Decompressor<'static>> =
                std::cell::RefCell::new(
                    zstd::bulk::Decompressor::new().expect("zstd dctx"),
                );
        }
        let raw = DCTX.with(|d| {
            d.borrow_mut().decompress(&data, st.raw_len as usize)
        })
        .context("zstd decompress")?;
        Ok(raw)
    }

    /// Decode a stripe into row-map samples (the baseline in-memory format).
    pub fn decode_stripe_rows(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
    ) -> Result<Vec<Sample>> {
        self.decode_stripe_rows_masked(stripe, bufs, projection, mode, None)
    }

    /// [`DwrfReader::decode_stripe_rows`] honoring a row-group survival
    /// mask (from [`StripePlan::group_mask`]): rows of pruned groups are
    /// never materialized. Sound by construction — the zone maps prove
    /// those rows cannot match the predicate that produced the mask.
    pub fn decode_stripe_rows_masked(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
        mask: Option<&[bool]>,
    ) -> Result<Vec<Sample>> {
        match self.meta.encoding {
            Encoding::Map => {
                self.decode_map_stripe(stripe, bufs, projection, mask)
            }
            Encoding::Flattened | Encoding::Dedup => {
                // Decode columnar then materialize rows (format conversion).
                let batch = self.decode_stripe_columnar_masked(
                    stripe, bufs, projection, mode, mask,
                )?;
                Ok(batch.to_samples())
            }
        }
    }

    fn decode_map_stripe(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mask: Option<&[bool]>,
    ) -> Result<Vec<Sample>> {
        let info = &self.meta.stripes[stripe];
        let mut meta_raw = None;
        let mut dense_raw = None;
        let mut sparse_raw = None;
        for (i, st) in info.streams.iter().enumerate() {
            match st.kind {
                StreamKind::RowMeta => meta_raw = Some(self.stream_bytes(stripe, i, bufs)?),
                StreamKind::MapDense => dense_raw = Some(self.stream_bytes(stripe, i, bufs)?),
                StreamKind::MapSparse => sparse_raw = Some(self.stream_bytes(stripe, i, bufs)?),
                _ => bail!("flat stream in map-encoded stripe"),
            }
        }
        let (labels, ts) =
            decode_row_meta(meta_raw.as_deref().context("missing row meta")?)?;
        let keep = |f: FeatureId| projection.contains(f);
        let dense = decode_map_dense(
            dense_raw.as_deref().context("missing dense map")?,
            Some(&keep),
        )?;
        let sparse = decode_map_sparse(
            sparse_raw.as_deref().context("missing sparse map")?,
            Some(&keep),
        )?;
        let rows = labels.len();
        if dense.len() != rows || sparse.len() != rows {
            bail!("stripe row-count mismatch");
        }
        // Map streams are variable-width row blobs: every row must be
        // *decoded* to find the next, but rows of pruned groups are
        // dropped here — before any Sample is materialized.
        let live = mask.map(|m| {
            let kept = info.keep_rows(m);
            let mut live = vec![false; rows];
            for &r in &kept {
                if let Some(slot) = live.get_mut(r as usize) {
                    *slot = true;
                }
            }
            live
        });
        let mut out = Vec::with_capacity(rows);
        for i in 0..rows {
            if let Some(live) = &live {
                if !live.get(i).copied().unwrap_or(true) {
                    continue;
                }
            }
            let mut s = Sample {
                dense: dense[i].clone(),
                sparse: sparse[i].clone(),
                label: labels[i],
                timestamp: ts[i],
            };
            s.sort_features();
            out.push(s);
        }
        Ok(out)
    }

    /// Decode a stripe straight into the columnar flatmap (the paper's
    /// +FM in-memory format; only efficient with flattened files).
    pub fn decode_stripe_columnar(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
    ) -> Result<ColumnarBatch> {
        self.decode_stripe_columnar_masked(stripe, bufs, projection, mode, None)
    }

    /// [`DwrfReader::decode_stripe_columnar`] honoring a row-group
    /// survival mask: pruned groups are never materialized into batch
    /// rows. On row-group-split flattened stripes their streams aren't
    /// even touched (the plan excluded those byte ranges); on
    /// whole-stripe layouts (Map, Dedup, v2 files) the streams decode
    /// but the pruned rows are dropped before materialization.
    pub fn decode_stripe_columnar_masked(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
        mask: Option<&[bool]>,
    ) -> Result<ColumnarBatch> {
        match self.meta.encoding {
            Encoding::Map => {
                // Map files can only produce rows; converting to columnar is
                // an extra format change (costed honestly).
                let rows =
                    self.decode_map_stripe(stripe, bufs, projection, mask)?;
                let mut dense_ids: Vec<FeatureId> = rows
                    .iter()
                    .flat_map(|s| s.dense.iter().map(|(f, _)| *f))
                    .collect();
                dense_ids.sort();
                dense_ids.dedup();
                let mut sparse_ids: Vec<FeatureId> = rows
                    .iter()
                    .flat_map(|s| s.sparse.iter().map(|(f, _)| *f))
                    .collect();
                sparse_ids.sort();
                sparse_ids.dedup();
                Ok(ColumnarBatch::from_samples(&rows, &dense_ids, &sparse_ids))
            }
            Encoding::Flattened => {
                let info = &self.meta.stripes[stripe];
                if info.streams.iter().any(|s| s.row_group != WHOLE_STRIPE) {
                    return self.decode_flattened_grouped(
                        stripe, bufs, projection, mode, mask,
                    );
                }
                let mut batch = ColumnarBatch {
                    num_rows: info.rows as usize,
                    ..Default::default()
                };
                for (i, st) in info.streams.iter().enumerate() {
                    match st.kind {
                        StreamKind::RowMeta => {
                            let raw = self.stream_bytes(stripe, i, bufs)?;
                            let (labels, ts) = decode_row_meta(&raw)?;
                            batch.labels = labels;
                            batch.timestamps = ts;
                        }
                        StreamKind::FlatDense => {
                            let fid = FeatureId(st.feature);
                            if projection.contains(fid) {
                                let raw = self.stream_bytes(stripe, i, bufs)?;
                                batch.dense.push(decode_flat_dense(
                                    &raw, fid, mode.fast,
                                )?);
                            }
                        }
                        StreamKind::FlatSparse => {
                            let fid = FeatureId(st.feature);
                            if projection.contains(fid) {
                                let raw = self.stream_bytes(stripe, i, bufs)?;
                                batch.sparse.push(decode_flat_sparse(
                                    &raw, fid, mode.fast,
                                )?);
                            }
                        }
                        _ => bail!("map stream in flattened stripe"),
                    }
                }
                // Whole-stripe layout + mask (possible only on files
                // whose stripes weren't group-split): drop pruned rows
                // by gathering the survivors.
                match mask {
                    Some(m) => Ok(batch.gather(&info.keep_rows(m))),
                    None => Ok(batch),
                }
            }
            Encoding::Dedup => {
                // Duplication-oblivious path: decode unique payloads +
                // inverse (pruned-group rows dropped at the expansion
                // index, their unreferenced payloads compacted away),
                // then expand to the per-row batch.
                let ds = self.decode_stripe_dedup_masked(
                    stripe, bufs, projection, mode, mask,
                )?;
                Ok(ds.expand())
            }
        }
    }

    /// Decode a row-group-split flattened stripe: each surviving group's
    /// row-meta and feature streams decode independently and splice back
    /// into one batch in row order. Pruned groups' streams are never
    /// read — their extents weren't fetched in the first place.
    fn decode_flattened_grouped(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
        mask: Option<&[bool]>,
    ) -> Result<ColumnarBatch> {
        let info = &self.meta.stripes[stripe];
        let n_groups = info.groups.len();
        if n_groups == 0 {
            bail!("group-scoped streams but no row-group stats");
        }
        let mut out: Option<ColumnarBatch> = None;
        for g in 0..n_groups {
            if let Some(m) = mask {
                if !m.get(g).copied().unwrap_or(true) {
                    continue;
                }
            }
            let mut batch = ColumnarBatch {
                num_rows: info.groups[g].rows as usize,
                ..Default::default()
            };
            for (i, st) in info.streams.iter().enumerate() {
                if st.row_group != g as u32 {
                    continue;
                }
                match st.kind {
                    StreamKind::RowMeta => {
                        let raw = self.stream_bytes(stripe, i, bufs)?;
                        let (labels, ts) = decode_row_meta(&raw)?;
                        batch.labels = labels;
                        batch.timestamps = ts;
                    }
                    StreamKind::FlatDense => {
                        let fid = FeatureId(st.feature);
                        if projection.contains(fid) {
                            let raw = self.stream_bytes(stripe, i, bufs)?;
                            batch
                                .dense
                                .push(decode_flat_dense(&raw, fid, mode.fast)?);
                        }
                    }
                    StreamKind::FlatSparse => {
                        let fid = FeatureId(st.feature);
                        if projection.contains(fid) {
                            let raw = self.stream_bytes(stripe, i, bufs)?;
                            batch.sparse.push(decode_flat_sparse(
                                &raw, fid, mode.fast,
                            )?);
                        }
                    }
                    _ => bail!("unexpected stream kind in grouped stripe"),
                }
            }
            if batch.labels.len() != batch.num_rows {
                bail!(
                    "row group {g} meta covers {} rows, expected {}",
                    batch.labels.len(),
                    batch.num_rows
                );
            }
            match &mut out {
                None => out = Some(batch),
                Some(acc) => acc.append_rows(&batch)?,
            }
        }
        Ok(out.unwrap_or_default())
    }

    /// Decode a Dedup-encoded stripe *without* expanding duplicates: the
    /// dedup-aware DPP worker path (§RecD) — preprocess `unique` once,
    /// ship the inverse.
    pub fn decode_stripe_dedup(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
    ) -> Result<DedupStripe> {
        self.decode_stripe_dedup_masked(stripe, bufs, projection, mode, None)
    }

    /// [`DwrfReader::decode_stripe_dedup`] honoring a row-group survival
    /// mask. Dedup streams stay stripe-wide (feature streams cover
    /// stripe-level *unique* payloads, which don't tile into row runs),
    /// so pruning applies at the unique-row expansion step:
    /// pruned-group rows are dropped from the inverse index and the
    /// unique payloads they alone referenced are compacted away — the
    /// transform stage never touches them.
    pub fn decode_stripe_dedup_masked(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
        mask: Option<&[bool]>,
    ) -> Result<DedupStripe> {
        let ds = self.decode_stripe_dedup_inner(stripe, bufs, projection, mode)?;
        match mask {
            Some(m) => {
                let keep = self.meta.stripes[stripe].keep_rows(m);
                Ok(ds.filter_rows(&keep))
            }
            None => Ok(ds),
        }
    }

    fn decode_stripe_dedup_inner(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        projection: &Projection,
        mode: DecodeMode,
    ) -> Result<DedupStripe> {
        if self.meta.encoding != Encoding::Dedup {
            bail!("decode_stripe_dedup on {:?} file", self.meta.encoding);
        }
        let info = &self.meta.stripes[stripe];
        let mut labels = Vec::new();
        let mut ts = Vec::new();
        let mut index: Option<(Vec<u32>, usize)> = None;
        let mut dense = Vec::new();
        let mut sparse = Vec::new();
        for (i, st) in info.streams.iter().enumerate() {
            match st.kind {
                StreamKind::RowMeta => {
                    let raw = self.stream_bytes(stripe, i, bufs)?;
                    let (l, t) = decode_row_meta(&raw)?;
                    labels = l;
                    ts = t;
                }
                StreamKind::DedupIndex => {
                    let raw = self.stream_bytes(stripe, i, bufs)?;
                    index = Some(decode_dedup_index(&raw)?);
                }
                StreamKind::FlatDense => {
                    let fid = FeatureId(st.feature);
                    if projection.contains(fid) {
                        let raw = self.stream_bytes(stripe, i, bufs)?;
                        dense.push(decode_flat_dense(&raw, fid, mode.fast)?);
                    }
                }
                StreamKind::FlatSparse => {
                    let fid = FeatureId(st.feature);
                    if projection.contains(fid) {
                        let raw = self.stream_bytes(stripe, i, bufs)?;
                        sparse.push(decode_flat_sparse(&raw, fid, mode.fast)?);
                    }
                }
                _ => bail!("map stream in dedup stripe"),
            }
        }
        let (inverse, unique_rows) =
            index.context("dedup stripe missing index stream")?;
        if inverse.len() != info.rows as usize {
            bail!(
                "dedup index covers {} rows, stripe has {}",
                inverse.len(),
                info.rows
            );
        }
        if labels.len() != inverse.len() {
            bail!("dedup stripe row-meta mismatch");
        }
        for col in &dense {
            if col.present.len() != unique_rows {
                bail!("dense column {:?} rows != uniques", col.id);
            }
        }
        for col in &sparse {
            if col.num_rows() != unique_rows {
                bail!("sparse column {:?} rows != uniques", col.id);
            }
        }
        Ok(DedupStripe {
            unique: ColumnarBatch {
                num_rows: unique_rows,
                dense,
                sparse,
                labels: Vec::new(),
                timestamps: Vec::new(),
                selection: None,
            },
            inverse,
            labels,
            timestamps: ts,
        })
    }

    /// The order a stripe-grain decode with `projection` would emit its
    /// dense / sparse feature columns in (file stream order, first
    /// occurrence). The column-grain path reassembles batches in this
    /// order so its output stays byte-identical to the stripe-grain
    /// decode. Features with no stream in this stripe are absent, just
    /// as a stripe decode would omit them.
    pub fn projected_columns(
        &self,
        stripe: usize,
        projection: &Projection,
    ) -> (Vec<FeatureId>, Vec<FeatureId>) {
        let info = &self.meta.stripes[stripe];
        let mut dense = Vec::new();
        let mut sparse = Vec::new();
        for st in &info.streams {
            let f = FeatureId(st.feature);
            match st.kind {
                StreamKind::FlatDense => {
                    if projection.contains(f) && !dense.contains(&f) {
                        dense.push(f);
                    }
                }
                StreamKind::FlatSparse => {
                    if projection.contains(f) && !sparse.contains(&f) {
                        sparse.push(f);
                    }
                }
                _ => {}
            }
        }
        (dense, sparse)
    }

    /// The I/O extents backing `cols` of one stripe — every group chunk
    /// of each column, **unmasked**: a cached column must be whole so
    /// sessions with different predicates can apply their own pruning
    /// downstream. `Meta` covers the row-meta (and dedup-index) streams.
    /// Errors on `Map` encoding, whose row-wise streams don't split into
    /// columns.
    pub fn column_ios(
        &self,
        stripe: usize,
        cols: &[ColumnId],
    ) -> Result<Vec<IoRange>> {
        if self.meta.encoding == Encoding::Map {
            bail!("column-grain reads unsupported on Map encoding");
        }
        let info = &self.meta.stripes[stripe];
        let mut out = Vec::new();
        for st in &info.streams {
            let wanted = match st.kind {
                StreamKind::RowMeta | StreamKind::DedupIndex => {
                    cols.contains(&ColumnId::Meta)
                }
                StreamKind::FlatDense | StreamKind::FlatSparse => cols
                    .contains(&ColumnId::Feature(FeatureId(st.feature))),
                StreamKind::MapDense | StreamKind::MapSparse => {
                    bail!("map stream in non-Map stripe")
                }
            };
            if wanted {
                out.push(IoRange {
                    offset: st.offset,
                    len: st.len,
                });
            }
        }
        Ok(out)
    }

    /// Decode the requested columns of one stripe independently of each
    /// other: each column's group chunks decode and splice in group
    /// order, exactly as the stripe-grain decode would produce them.
    /// Returns `(column, payload, io_bytes)` per column, where
    /// `io_bytes` is the storage footprint of the streams backing it
    /// (what a later cache hit saves). A projected feature with no
    /// stream in this stripe yields no entry.
    pub fn decode_columns(
        &self,
        stripe: usize,
        bufs: &IoBuffers,
        cols: &[ColumnId],
        mode: DecodeMode,
    ) -> Result<Vec<(ColumnId, SharedColumn, u64)>> {
        if self.meta.encoding == Encoding::Map {
            bail!("column-grain decode unsupported on Map encoding");
        }
        let info = &self.meta.stripes[stripe];
        let grouped =
            info.streams.iter().any(|s| s.row_group != WHOLE_STRIPE);
        // Stream indices backing one column, in the order a stripe-grain
        // decode would consume them (group order when group-split).
        let ordered = |pick: &dyn Fn(&super::StreamInfo) -> bool| -> Vec<usize> {
            if grouped {
                let whole: Vec<usize> = info
                    .streams
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        pick(s) && s.row_group == WHOLE_STRIPE
                    })
                    .map(|(i, _)| i)
                    .collect();
                let mut by_group: Vec<usize> = (0..info.groups.len())
                    .filter_map(|g| {
                        info.streams.iter().position(|s| {
                            pick(s) && s.row_group == g as u32
                        })
                    })
                    .collect();
                let mut v = whole;
                v.append(&mut by_group);
                v
            } else {
                info.streams
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| pick(s))
                    .map(|(i, _)| i)
                    .collect()
            }
        };
        let mut out = Vec::with_capacity(cols.len());
        for &c in cols {
            match c {
                ColumnId::Meta => {
                    let mut labels = Vec::new();
                    let mut ts = Vec::new();
                    let mut inverse: Option<Vec<u32>> = None;
                    let mut unique_rows: Option<usize> = None;
                    let mut io_bytes = 0u64;
                    for i in
                        ordered(&|s| s.kind == StreamKind::RowMeta)
                    {
                        io_bytes += info.streams[i].len;
                        let raw = self.stream_bytes(stripe, i, bufs)?;
                        let (l, t) = decode_row_meta(&raw)?;
                        labels.extend(l);
                        ts.extend(t);
                    }
                    for i in
                        ordered(&|s| s.kind == StreamKind::DedupIndex)
                    {
                        io_bytes += info.streams[i].len;
                        let raw = self.stream_bytes(stripe, i, bufs)?;
                        let (inv, u) = decode_dedup_index(&raw)?;
                        inverse = Some(inv);
                        unique_rows = Some(u);
                    }
                    if labels.len() != info.rows as usize {
                        bail!(
                            "stripe {stripe} row meta covers {} rows, expected {}",
                            labels.len(),
                            info.rows
                        );
                    }
                    if let Some(inv) = &inverse {
                        if inv.len() != info.rows as usize {
                            bail!("dedup index covers {} rows, stripe has {}",
                                inv.len(), info.rows);
                        }
                    }
                    let col_rows =
                        unique_rows.unwrap_or(info.rows as usize);
                    out.push((
                        c,
                        SharedColumn::Meta {
                            labels,
                            timestamps: ts,
                            inverse,
                            col_rows,
                        },
                        io_bytes,
                    ));
                }
                ColumnId::Feature(f) => {
                    let idxs = ordered(&|s| {
                        matches!(
                            s.kind,
                            StreamKind::FlatDense
                                | StreamKind::FlatSparse
                        ) && s.feature == f.0
                    });
                    let Some(&first) = idxs.first() else {
                        // Not materialized in this stripe.
                        continue;
                    };
                    let mut io_bytes = 0u64;
                    match info.streams[first].kind {
                        StreamKind::FlatDense => {
                            let mut acc: Option<DenseColumn> = None;
                            for i in idxs {
                                io_bytes += info.streams[i].len;
                                let raw =
                                    self.stream_bytes(stripe, i, bufs)?;
                                let col = decode_flat_dense(
                                    &raw, f, mode.fast,
                                )?;
                                match &mut acc {
                                    None => acc = Some(col),
                                    Some(a) => {
                                        a.present.append(&col.present);
                                        a.values.extend_from_slice(
                                            &col.values,
                                        );
                                    }
                                }
                            }
                            out.push((
                                c,
                                SharedColumn::Dense(acc.unwrap()),
                                io_bytes,
                            ));
                        }
                        StreamKind::FlatSparse => {
                            let mut acc: Option<SparseColumn> = None;
                            for i in idxs {
                                io_bytes += info.streams[i].len;
                                let raw =
                                    self.stream_bytes(stripe, i, bufs)?;
                                let col = decode_flat_sparse(
                                    &raw, f, mode.fast,
                                )?;
                                match &mut acc {
                                    None => acc = Some(col),
                                    Some(a) => a.append(&col)?,
                                }
                            }
                            out.push((
                                c,
                                SharedColumn::Sparse(acc.unwrap()),
                                io_bytes,
                            ));
                        }
                        _ => unreachable!("picked flat streams only"),
                    }
                }
            }
        }
        Ok(out)
    }

    /// Reassemble the `ColumnarBatch` a stripe-grain Flattened decode
    /// with `projection` would have produced, from individually cached
    /// columns (`cols` as returned by a column-grain serve).
    pub fn assemble_columnar(
        &self,
        stripe: usize,
        projection: &Projection,
        cols: &[(ColumnId, Arc<SharedColumn>)],
    ) -> Result<ColumnarBatch> {
        let info = &self.meta.stripes[stripe];
        let find = |c: ColumnId| {
            cols.iter().find(|(k, _)| *k == c).map(|(_, p)| p)
        };
        let meta =
            find(ColumnId::Meta).context("meta column missing")?;
        let SharedColumn::Meta {
            labels, timestamps, inverse, ..
        } = &**meta
        else {
            bail!("meta column has a feature payload");
        };
        if inverse.is_some() {
            bail!("dedup meta in flattened assembly");
        }
        let mut batch = ColumnarBatch {
            num_rows: info.rows as usize,
            labels: labels.clone(),
            timestamps: timestamps.clone(),
            ..Default::default()
        };
        let (dense_ids, sparse_ids) =
            self.projected_columns(stripe, projection);
        for f in dense_ids {
            match find(ColumnId::Feature(f)).map(|p| &**p) {
                Some(SharedColumn::Dense(col)) => {
                    batch.dense.push(col.clone())
                }
                Some(_) => bail!("column {f:?} has a non-dense payload"),
                None => bail!("dense column {f:?} missing"),
            }
        }
        for f in sparse_ids {
            match find(ColumnId::Feature(f)).map(|p| &**p) {
                Some(SharedColumn::Sparse(col)) => {
                    batch.sparse.push(col.clone())
                }
                Some(_) => {
                    bail!("column {f:?} has a non-sparse payload")
                }
                None => bail!("sparse column {f:?} missing"),
            }
        }
        Ok(batch)
    }

    /// Reassemble the [`DedupStripe`] a stripe-grain Dedup decode with
    /// `projection` would have produced, from individually cached
    /// columns.
    pub fn assemble_dedup(
        &self,
        stripe: usize,
        projection: &Projection,
        cols: &[(ColumnId, Arc<SharedColumn>)],
    ) -> Result<DedupStripe> {
        let info = &self.meta.stripes[stripe];
        let find = |c: ColumnId| {
            cols.iter().find(|(k, _)| *k == c).map(|(_, p)| p)
        };
        let meta =
            find(ColumnId::Meta).context("meta column missing")?;
        let SharedColumn::Meta {
            labels,
            timestamps,
            inverse,
            col_rows,
        } = &**meta
        else {
            bail!("meta column has a feature payload");
        };
        let Some(inverse) = inverse else {
            bail!("flattened meta in dedup assembly");
        };
        if inverse.len() != info.rows as usize {
            bail!(
                "dedup index covers {} rows, stripe has {}",
                inverse.len(),
                info.rows
            );
        }
        let mut unique = ColumnarBatch {
            num_rows: *col_rows,
            ..Default::default()
        };
        let (dense_ids, sparse_ids) =
            self.projected_columns(stripe, projection);
        for f in dense_ids {
            match find(ColumnId::Feature(f)).map(|p| &**p) {
                Some(SharedColumn::Dense(col)) => {
                    if col.present.len() != *col_rows {
                        bail!("dense column {f:?} rows != uniques");
                    }
                    unique.dense.push(col.clone());
                }
                Some(_) => bail!("column {f:?} has a non-dense payload"),
                None => bail!("dense column {f:?} missing"),
            }
        }
        for f in sparse_ids {
            match find(ColumnId::Feature(f)).map(|p| &**p) {
                Some(SharedColumn::Sparse(col)) => {
                    if col.num_rows() != *col_rows {
                        bail!("sparse column {f:?} rows != uniques");
                    }
                    unique.sparse.push(col.clone());
                }
                Some(_) => {
                    bail!("column {f:?} has a non-sparse payload")
                }
                None => bail!("sparse column {f:?} missing"),
            }
        }
        Ok(DedupStripe {
            unique,
            inverse: inverse.clone(),
            labels: labels.clone(),
            timestamps: timestamps.clone(),
        })
    }

    /// Execute a plan against a whole in-memory file (local path used by
    /// tests and benches; the DPP worker path executes I/Os via tectonic).
    pub fn fetch_local(&self, file: &[u8], plan: &ReadPlan) -> IoBuffers {
        let mut bufs = IoBuffers::new();
        for sp in &plan.stripes {
            for io in &sp.ios {
                bufs.insert(
                    *io,
                    file[io.offset as usize..io.end() as usize].to_vec(),
                );
            }
        }
        bufs
    }
}

/// Convenience wrapper for `DenseColumn`/`SparseColumn` lookup by feature.
pub trait BatchExt {
    fn dense_col(&self, id: FeatureId) -> Option<&DenseColumn>;
    fn sparse_col(&self, id: FeatureId) -> Option<&SparseColumn>;
}

impl BatchExt for ColumnarBatch {
    fn dense_col(&self, id: FeatureId) -> Option<&DenseColumn> {
        self.dense.iter().find(|c| c.id == id)
    }

    fn sparse_col(&self, id: FeatureId) -> Option<&SparseColumn> {
        self.sparse.iter().find(|c| c.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SparseValue;
    use crate::dwrf::writer::{DwrfWriter, Encoding, WriterOptions};

    fn mk_samples(n: usize) -> Vec<Sample> {
        (0..n as u64)
            .map(|i| {
                let mut s = Sample {
                    dense: vec![
                        (FeatureId(0), i as f32),
                        (FeatureId(1), -(i as f32)),
                    ],
                    sparse: vec![(
                        FeatureId(100),
                        SparseValue::ids(vec![i, i + 1]),
                    )],
                    label: (i % 2) as f32,
                    timestamp: 5000 + i,
                };
                if i % 2 == 0 {
                    s.sparse
                        .push((FeatureId(101), SparseValue::ids(vec![9])));
                }
                s.sort_features();
                s
            })
            .collect()
    }

    fn build(enc: Encoding) -> (Vec<Sample>, Vec<u8>) {
        let samples = mk_samples(20);
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0), FeatureId(1)],
            vec![FeatureId(100), FeatureId(101)],
            WriterOptions {
                encoding: enc,
                stripe_rows: 8,
                ..Default::default()
            },
        );
        w.write_all(samples.clone());
        (samples, w.finish())
    }

    fn full_projection() -> Projection {
        Projection::new([
            FeatureId(0),
            FeatureId(1),
            FeatureId(100),
            FeatureId(101),
        ])
    }

    fn read_all(bytes: &[u8], proj: &Projection) -> Vec<Sample> {
        let r = DwrfReader::open_table(bytes, "t").unwrap();
        let plan = r.plan(proj, None);
        let bufs = r.fetch_local(bytes, &plan);
        let mut out = Vec::new();
        for si in 0..r.meta.stripes.len() {
            out.extend(
                r.decode_stripe_rows(si, &bufs, proj, DecodeMode::default())
                    .unwrap(),
            );
        }
        out
    }

    #[test]
    fn roundtrip_map_encoding() {
        let (samples, bytes) = build(Encoding::Map);
        assert_eq!(read_all(&bytes, &full_projection()), samples);
    }

    #[test]
    fn roundtrip_flattened_encoding() {
        let (samples, bytes) = build(Encoding::Flattened);
        assert_eq!(read_all(&bytes, &full_projection()), samples);
    }

    #[test]
    fn projection_filters_features_both_encodings() {
        for enc in [Encoding::Map, Encoding::Flattened] {
            let (_, bytes) = build(enc);
            let proj = Projection::new([FeatureId(0), FeatureId(100)]);
            let rows = read_all(&bytes, &proj);
            for s in &rows {
                assert!(s.dense.iter().all(|(f, _)| *f == FeatureId(0)));
                assert!(s.sparse.iter().all(|(f, _)| *f == FeatureId(100)));
            }
        }
    }

    #[test]
    fn flattened_projection_reads_fewer_bytes_than_map() {
        let (_, map_bytes) = build(Encoding::Map);
        let (_, flat_bytes) = build(Encoding::Flattened);
        let proj = Projection::new([FeatureId(0)]);
        let mr = DwrfReader::open_table(&map_bytes, "t").unwrap();
        let fr = DwrfReader::open_table(&flat_bytes, "t").unwrap();
        let mp = mr.plan(&proj, None);
        let fp = fr.plan(&proj, None);
        assert!(
            fp.useful_bytes < mp.useful_bytes,
            "flattened {} !< map {}",
            fp.useful_bytes,
            mp.useful_bytes
        );
    }

    #[test]
    fn flattened_has_more_smaller_ios_without_coalescing() {
        let (_, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let uncoalesced = r.plan(&proj, None);
        let coalesced = r.plan(&proj, Some(crate::dwrf::plan::COALESCE_WINDOW));
        assert!(coalesced.num_ios() < uncoalesced.num_ios());
        assert!(coalesced.read_bytes >= coalesced.useful_bytes);
    }

    #[test]
    fn decode_from_coalesced_buffers_matches() {
        let (samples, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, Some(1 << 20));
        let bufs = r.fetch_local(&bytes, &plan);
        let mut rows = Vec::new();
        for si in 0..r.meta.stripes.len() {
            rows.extend(
                r.decode_stripe_rows(si, &bufs, &proj, DecodeMode::default())
                    .unwrap(),
            );
        }
        assert_eq!(rows, samples);
    }

    #[test]
    fn columnar_decode_matches_rows() {
        let (samples, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let batch = r
            .decode_stripe_columnar(0, &bufs, &proj, DecodeMode::default())
            .unwrap();
        assert_eq!(batch.num_rows, 8);
        assert_eq!(batch.to_samples(), samples[..8].to_vec());
    }

    #[test]
    fn checked_and_fast_paths_agree() {
        let (_, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let slow = r
            .decode_stripe_columnar(1, &bufs, &proj, DecodeMode { fast: false })
            .unwrap();
        let fast = r
            .decode_stripe_columnar(1, &bufs, &proj, DecodeMode { fast: true })
            .unwrap();
        assert_eq!(slow, fast);
    }

    #[test]
    fn wrong_table_key_fails_decode() {
        let (_, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "WRONG").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        // CRC passes (it covers ciphertext) but zstd will reject the
        // mis-decrypted payload.
        assert!(r
            .decode_stripe_rows(0, &bufs, &proj, DecodeMode::default())
            .is_err());
    }

    #[test]
    fn corrupted_stream_detected_by_crc() {
        let (_, mut bytes) = build(Encoding::Flattened);
        // Flip a byte early in the file (inside some stream).
        bytes[5] ^= 0xff;
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let mut failed = false;
        for si in 0..r.meta.stripes.len() {
            if r.decode_stripe_rows(si, &bufs, &proj, DecodeMode::default())
                .is_err()
            {
                failed = true;
            }
        }
        assert!(failed, "corruption must be detected");
    }

    #[test]
    fn bad_magic_rejected() {
        let (_, mut bytes) = build(Encoding::Map);
        let n = bytes.len();
        bytes[n - 1] ^= 0x55;
        assert!(DwrfReader::open(&bytes).is_err());
    }

    fn mk_dup_samples(n: usize) -> Vec<Sample> {
        (0..n as u64)
            .map(|i| {
                let payload = i / 3; // runs of 3 duplicate payloads
                let mut s = Sample {
                    dense: vec![(FeatureId(0), payload as f32)],
                    sparse: vec![(
                        FeatureId(100),
                        SparseValue::ids(vec![payload, payload + 7]),
                    )],
                    label: (i % 2) as f32,
                    timestamp: 400 + i,
                };
                if payload % 2 == 0 {
                    s.dense.push((FeatureId(1), -(payload as f32)));
                }
                s.sort_features();
                s
            })
            .collect()
    }

    fn build_dedup(samples: &[Sample], stripe_rows: usize) -> Vec<u8> {
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0), FeatureId(1)],
            vec![FeatureId(100), FeatureId(101)],
            WriterOptions {
                encoding: Encoding::Dedup,
                stripe_rows,
                ..Default::default()
            },
        );
        w.write_all(samples.to_vec());
        w.finish()
    }

    fn canonical(mut rows: Vec<Sample>) -> Vec<Sample> {
        rows.sort_by(|a, b| {
            a.timestamp
                .cmp(&b.timestamp)
                .then(a.label.total_cmp(&b.label))
        });
        rows
    }

    #[test]
    fn dedup_roundtrip_recovers_every_sample() {
        let samples = mk_dup_samples(21);
        let bytes = build_dedup(&samples, 8);
        // The clustering window may permute rows; the sample *multiset*
        // (and every label/timestamp pairing) must survive exactly.
        let got = read_all(&bytes, &full_projection());
        assert_eq!(got.len(), samples.len());
        assert_eq!(canonical(got), canonical(samples));
    }

    #[test]
    fn dedup_stripe_decodes_unique_payloads_once() {
        let samples = mk_dup_samples(12); // 4 unique payloads, 3 rows each
        let bytes = build_dedup(&samples, 12);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let ds = r
            .decode_stripe_dedup(0, &bufs, &proj, DecodeMode::default())
            .unwrap();
        assert_eq!(ds.rows(), 12);
        assert_eq!(ds.unique.num_rows, 4);
        assert!((ds.factor() - 3.0).abs() < 1e-12);
        // Expansion matches the row-level decode.
        let expanded = ds.expand();
        assert_eq!(expanded.num_rows, 12);
        let rows = r
            .decode_stripe_rows(0, &bufs, &proj, DecodeMode::default())
            .unwrap();
        assert_eq!(expanded.to_samples(), rows);
    }

    #[test]
    fn dedup_projection_filters_features() {
        let samples = mk_dup_samples(12);
        let bytes = build_dedup(&samples, 6);
        let proj = Projection::new([FeatureId(0), FeatureId(100)]);
        for s in read_all(&bytes, &proj) {
            assert!(s.dense.iter().all(|(f, _)| *f == FeatureId(0)));
            assert!(s.sparse.iter().all(|(f, _)| *f == FeatureId(100)));
        }
    }

    #[test]
    fn dedup_stores_fewer_raw_feature_bytes_than_flattened() {
        let samples = mk_dup_samples(60);
        let dedup = build_dedup(&samples, 60);
        let mut w = DwrfWriter::new(
            "t",
            vec![FeatureId(0), FeatureId(1)],
            vec![FeatureId(100), FeatureId(101)],
            WriterOptions {
                encoding: Encoding::Flattened,
                stripe_rows: 60,
                ..Default::default()
            },
        );
        w.write_all(samples);
        let flat = w.finish();
        // Compare logical (pre-compression) bytes of the projected sparse
        // feature: deterministic, unlike zstd's opportunistic matching.
        let raw_sparse = |bytes: &[u8]| -> u64 {
            DwrfReader::open_table(bytes, "t")
                .unwrap()
                .meta
                .stripes
                .iter()
                .flat_map(|s| s.streams.iter())
                .filter(|s| s.kind == StreamKind::FlatSparse && s.feature == 100)
                .map(|s| s.raw_len)
                .sum()
        };
        let (d, f) = (raw_sparse(&dedup), raw_sparse(&flat));
        assert!(d * 2 < f, "dedup {d} raw bytes !< half of flat {f}");
    }

    #[test]
    fn filtered_plan_skips_disjoint_stripes_with_zero_ios() {
        // mk_samples stamps timestamps 5000..5020 over stripes of 8.
        let (_, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        // Window covering only the first stripe's rows.
        let pred = RowPredicate::TimestampRange {
            min: 5000,
            max: 5007,
        };
        let plan = r.plan_filtered(&proj, None, Some(&pred));
        assert_eq!(plan.stripes.len(), 1);
        assert_eq!(plan.stripes[0].stripe, 0);
        assert_eq!(plan.skipped_stripes, vec![1, 2]);
        assert!(plan.skipped_bytes > 0);
        // A window beyond every row issues no I/O at all.
        let none = RowPredicate::TimestampRange { min: 0, max: 10 };
        let empty = r.plan_filtered(&proj, None, Some(&none));
        assert_eq!(empty.num_ios(), 0);
        assert_eq!(empty.read_bytes, 0);
        assert_eq!(empty.skipped_stripes.len(), r.meta.stripes.len());
        // No predicate ⇒ identical to the unfiltered plan.
        let a = r.plan(&proj, None);
        let b = r.plan_filtered(&proj, None, None);
        assert_eq!(a.num_ios(), b.num_ios());
        assert_eq!(a.read_bytes, b.read_bytes);
        assert!(b.skipped_stripes.is_empty());
    }

    #[test]
    fn dedup_filter_rows_compacts_uniques() {
        let samples = mk_dup_samples(12); // payload runs of 3
        let bytes = build_dedup(&samples, 12);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let ds = r
            .decode_stripe_dedup(0, &bufs, &proj, DecodeMode::default())
            .unwrap();
        // Keep only the rows of one payload run plus one stray row.
        let all = ds.expand().to_samples();
        let keep: Vec<u32> = (0..ds.rows() as u32)
            .filter(|&i| all[i as usize].timestamp % 2 == 0)
            .collect();
        let filtered = ds.filter_rows(&keep);
        assert_eq!(filtered.rows(), keep.len());
        assert!(filtered.unique.num_rows <= ds.unique.num_rows);
        let got = filtered.expand().to_samples();
        let want: Vec<Sample> = keep
            .iter()
            .map(|&i| all[i as usize].clone())
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn corrupt_footer_len_rejected_without_panicking() {
        // A trailer advertising footer_len near u64::MAX used to wrap
        // the `flen + 12` bound check and underflow the offset.
        let (_, mut bytes) = build(Encoding::Flattened);
        let n = bytes.len();
        bytes[n - 12..n - 4].copy_from_slice(&(u64::MAX - 5).to_le_bytes());
        assert!(DwrfReader::open(&bytes).is_err());
        // Oversized-but-not-overflowing is rejected too, not a panic.
        let (_, mut bytes2) = build(Encoding::Flattened);
        let n2 = bytes2.len();
        bytes2[n2 - 12..n2 - 4]
            .copy_from_slice(&(n2 as u64).to_le_bytes());
        assert!(DwrfReader::open(&bytes2).is_err());
    }

    #[test]
    fn dedup_project_matches_narrow_decode() {
        let samples = mk_dup_samples(12);
        let bytes = build_dedup(&samples, 12);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let full = full_projection();
        let narrow = Projection::new([FeatureId(0), FeatureId(100)]);
        let plan = r.plan(&full, None);
        let bufs = r.fetch_local(&bytes, &plan);
        let wide = r
            .decode_stripe_dedup(0, &bufs, &full, DecodeMode::default())
            .unwrap();
        let direct = r
            .decode_stripe_dedup(0, &bufs, &narrow, DecodeMode::default())
            .unwrap();
        let projected = wide.project(&narrow);
        assert_eq!(projected.unique, direct.unique);
        assert_eq!(projected.inverse, direct.inverse);
        assert_eq!(projected.labels, direct.labels);
        assert_eq!(
            projected.expand().to_samples(),
            direct.expand().to_samples()
        );
    }

    #[test]
    fn dedup_on_non_dedup_file_errors() {
        let (_, bytes) = build(Encoding::Flattened);
        let r = DwrfReader::open_table(&bytes, "t").unwrap();
        let proj = full_projection();
        let plan = r.plan(&proj, None);
        let bufs = r.fetch_local(&bytes, &plan);
        assert!(r
            .decode_stripe_dedup(0, &bufs, &proj, DecodeMode::default())
            .is_err());
    }
}
