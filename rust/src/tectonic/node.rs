//! A storage node: a set of chunks on one device, with a seek/transfer
//! service-time model and IOPS/bytes accounting.

use crate::config::DeviceSpec;
use crate::sync::{lock_or_recover, Mutex};
use std::collections::HashMap;

/// Per-node I/O accounting. Times are *simulated device seconds*, which is
/// what the storage-throughput experiments report; data movement itself is
/// real (bytes are actually copied).
#[derive(Clone, Debug, Default)]
pub struct IoStats {
    pub reads: u64,
    pub seeks: u64,
    /// Forward read-through skips (gap cheaper than a seek).
    pub skips: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub device_secs: f64,
}

impl IoStats {
    pub fn merge(&mut self, o: &IoStats) {
        self.reads += o.reads;
        self.seeks += o.seeks;
        self.skips += o.skips;
        self.bytes_read += o.bytes_read;
        self.bytes_written += o.bytes_written;
        self.device_secs += o.device_secs;
    }

    /// Effective read throughput (MB/s of fetched bytes per device-second).
    pub fn read_mbps(&self) -> f64 {
        if self.device_secs == 0.0 {
            0.0
        } else {
            self.bytes_read as f64 / 1e6 / self.device_secs
        }
    }

    /// Achieved IOPS.
    pub fn iops(&self) -> f64 {
        if self.device_secs == 0.0 {
            0.0
        } else {
            self.reads as f64 / self.device_secs
        }
    }
}

struct NodeState {
    chunks: HashMap<u64, Vec<u8>>,
    stats: IoStats,
    /// Device position: chunk id + offset of the last read's end, used to
    /// decide whether the next read is sequential (no seek).
    head: Option<(u64, u64)>,
}

/// One storage node holding replicated chunks on a single device.
pub struct StorageNode {
    pub id: usize,
    pub device: DeviceSpec,
    state: Mutex<NodeState>,
}

impl StorageNode {
    pub fn new(id: usize, device: DeviceSpec) -> StorageNode {
        StorageNode {
            id,
            device,
            state: Mutex::new(NodeState {
                chunks: HashMap::new(),
                stats: IoStats::default(),
                head: None,
            }),
        }
    }

    pub fn put_chunk(&self, chunk_id: u64, data: Vec<u8>) {
        let mut st = lock_or_recover(&self.state, "storage node");
        st.stats.bytes_written += data.len() as u64;
        st.chunks.insert(chunk_id, data);
    }

    pub fn has_chunk(&self, chunk_id: u64) -> bool {
        lock_or_recover(&self.state, "storage node")
            .chunks
            .contains_key(&chunk_id)
    }

    pub fn chunk_count(&self) -> usize {
        lock_or_recover(&self.state, "storage node").chunks.len()
    }

    pub fn stored_bytes(&self) -> u64 {
        lock_or_recover(&self.state, "storage node")
            .chunks
            .values()
            .map(|c| c.len() as u64)
            .sum()
    }

    /// Read `[offset, offset+len)` of a chunk. Every request pays one
    /// positioning cost plus transfer: production storage nodes serve many
    /// tenants concurrently, so successive requests from one reader find
    /// the head elsewhere — there is no cross-request locality. Locality
    /// is only exploitable *within* a request, which is precisely what
    /// coalesced reads buy (the +CR mechanism of §7.5).
    pub fn read(&self, chunk_id: u64, offset: u64, len: u64) -> Option<Vec<u8>> {
        let mut st = lock_or_recover(&self.state, "storage node");
        let data = st.chunks.get(&chunk_id)?;
        if offset + len > data.len() as u64 {
            return None;
        }
        let out = data[offset as usize..(offset + len) as usize].to_vec();
        let t = self.device.service_time(len, false);
        st.stats.seeks += 1;
        st.stats.reads += 1;
        st.stats.bytes_read += len;
        st.stats.device_secs += t;
        st.head = Some((chunk_id, offset + len));
        Some(out)
    }

    /// Append to a chunk in place (writer path; device write time is not
    /// modelled — offline data generation is off the critical path, §3.1.1).
    pub fn append_chunk(&self, chunk_id: u64, data: &[u8]) {
        let mut st = lock_or_recover(&self.state, "storage node");
        st.stats.bytes_written += data.len() as u64;
        st.chunks
            .entry(chunk_id)
            .or_default()
            .extend_from_slice(data);
    }

    pub fn stats(&self) -> IoStats {
        lock_or_recover(&self.state, "storage node").stats.clone()
    }

    pub fn reset_stats(&self) {
        let mut st = lock_or_recover(&self.state, "storage node");
        st.stats = IoStats::default();
        st.head = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hdd_node() -> StorageNode {
        StorageNode::new(0, DeviceSpec::hdd())
    }

    #[test]
    fn put_read_roundtrip() {
        let n = hdd_node();
        n.put_chunk(1, (0..100u8).collect());
        assert_eq!(n.read(1, 10, 5), Some(vec![10, 11, 12, 13, 14]));
        assert!(n.read(1, 98, 5).is_none(), "out of bounds");
        assert!(n.read(2, 0, 1).is_none(), "missing chunk");
    }

    #[test]
    fn random_reads_charge_seeks() {
        let n = hdd_node();
        // Strides larger than the read-through window force true seeks.
        n.put_chunk(1, vec![0u8; 64 << 20]);
        for i in 0..10u64 {
            n.read(1, (i * 37_000_000) % (60 << 20), 100);
        }
        let s = n.stats();
        assert_eq!(s.reads, 10);
        assert_eq!(s.seeks, 10);
        // 10 seeks at 8ms dominate.
        assert!(s.device_secs > 0.079, "{}", s.device_secs);
    }

    #[test]
    fn every_request_pays_positioning() {
        // Multi-tenant model: no cross-request head locality — a big
        // coalesced read is the only way to amortize positioning.
        let n = hdd_node();
        n.put_chunk(1, vec![0u8; 1 << 20]);
        let mut off = 0;
        for _ in 0..10 {
            n.read(1, off, 4096);
            off += 4096;
        }
        let s = n.stats();
        assert_eq!(s.reads, 10);
        assert_eq!(s.seeks, 10);
        n.reset_stats();
        // Same bytes in one coalesced request: one positioning op.
        n.read(1, 0, 10 * 4096);
        let s = n.stats();
        assert_eq!(s.seeks, 1);
    }

    #[test]
    fn hdd_small_random_io_is_seek_bound() {
        // The Table 12 mechanism: post-FF 20 KB random reads crater HDD
        // throughput vs 8 MB sequential reads.
        let n = hdd_node();
        n.put_chunk(1, vec![0u8; 64 << 20]);
        // 100 random 20 KB reads, scattered beyond read-through reach.
        for i in 0..100u64 {
            n.read(1, (i * 17_000_000) % (60 << 20), 20_000);
        }
        let small = n.stats().read_mbps();
        n.reset_stats();
        // Sequential 8 MB in 1 MB pieces.
        for i in 0..8u64 {
            n.read(1, i << 20, 1 << 20);
        }
        let big = n.stats().read_mbps();
        assert!(
            big / small > 10.0,
            "sequential {big:.1} MB/s vs random {small:.1} MB/s"
        );
    }

    #[test]
    fn ssd_barely_penalizes_small_io() {
        let n = StorageNode::new(0, DeviceSpec::ssd());
        n.put_chunk(1, vec![0u8; 64 << 20]);
        for i in 0..100u64 {
            n.read(1, (i * 17_000_000) % (60 << 20), 20_000);
        }
        let small = n.stats().read_mbps();
        // SSD random 20 KB should still be near half its sequential rate.
        assert!(small > 500.0, "{small}");
    }

    #[test]
    fn stats_merge() {
        let mut a = IoStats {
            reads: 1,
            seeks: 1,
            skips: 0,
            bytes_read: 10,
            bytes_written: 0,
            device_secs: 0.5,
        };
        let b = IoStats {
            reads: 3,
            seeks: 0,
            skips: 1,
            bytes_read: 30,
            bytes_written: 7,
            device_secs: 0.5,
        };
        a.merge(&b);
        assert_eq!(a.reads, 4);
        assert_eq!(a.bytes_read, 40);
        assert!((a.iops() - 4.0).abs() < 1e-9);
        assert!((a.read_mbps() - 40.0 / 1e6).abs() < 1e-9);
    }
}
