//! Tectonic — an exabyte-scale distributed append-only filesystem in the
//! paper (§3.1.2); rebuilt here as a chunked object store over modelled
//! storage nodes.
//!
//! Real byte storage + simulated device time: file contents are held in
//! memory (our "exabyte" is MiB-scale), but every read is charged against
//! a [`crate::config::DeviceSpec`]-based seek/transfer model so IOPS,
//! service time, and the paper's throughput-to-storage gap (§7.1: >8×
//! even after 3× replication) fall out of the same mechanism as in
//! production — HDD seeks dominating small feature reads
//! (Table 6 → Table 12).

pub mod cluster;
pub mod node;
pub mod tiering;

pub use cluster::{Cluster, ClusterConfig, FileId};
pub use node::{IoStats, StorageNode};
pub use tiering::TieredStore;
