//! The Tectonic cluster: name-node metadata + chunk placement +
//! replicated reads/appends across storage nodes.

use super::node::{IoStats, StorageNode};
use crate::config::DeviceSpec;
use crate::dwrf::{IoBuffers, IoRange};
use anyhow::{bail, Context, Result};
use crate::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::sync::{
    lock_or_recover, read_or_recover, write_or_recover, Mutex, RwLock,
};
use std::collections::HashMap;

/// Opaque file handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u64);

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub device: DeviceSpec,
    /// Replication factor (paper: triplicate for durability, §7.1).
    pub replication: usize,
    /// Chunk size (paper: Tectonic's ~8 MB; tests shrink this).
    pub chunk_bytes: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            nodes: 6,
            device: DeviceSpec::hdd(),
            replication: 3,
            chunk_bytes: 8 << 20,
        }
    }
}

struct ChunkLoc {
    chunk_id: u64,
    /// Node indices holding replicas.
    replicas: Vec<usize>,
    len: u64,
}

struct FileMetaEntry {
    chunks: Vec<ChunkLoc>,
    len: u64,
    sealed: bool,
}

/// The cluster: metadata service + storage nodes. Thread-safe; DPP workers
/// read concurrently.
pub struct Cluster {
    pub cfg: ClusterConfig,
    nodes: Vec<StorageNode>,
    files: RwLock<HashMap<FileId, FileMetaEntry>>,
    next_file: AtomicU64,
    next_chunk: AtomicU64,
    rr: AtomicUsize,
    /// Lock ordering: `files` before `names`.
    names: Mutex<HashMap<String, FileId>>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Cluster {
        assert!(cfg.replication >= 1 && cfg.replication <= cfg.nodes);
        let nodes = (0..cfg.nodes)
            .map(|i| StorageNode::new(i, cfg.device.clone()))
            .collect();
        Cluster {
            cfg,
            nodes,
            files: RwLock::new(HashMap::new()),
            next_file: AtomicU64::new(1),
            next_chunk: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            names: Mutex::new(HashMap::new()),
        }
    }

    pub fn create(&self, name: &str) -> FileId {
        // Relaxed: a pure unique-ID ticket. Each fetch_add returns a
        // distinct value at any ordering; nothing else is published
        // through it (the metadata insert below is guarded by `files`).
        let id = FileId(self.next_file.fetch_add(1, Ordering::Relaxed));
        write_or_recover(&self.files, "cluster files").insert(
            id,
            FileMetaEntry {
                chunks: Vec::new(),
                len: 0,
                sealed: false,
            },
        );
        lock_or_recover(&self.names, "cluster names")
            .insert(name.to_string(), id);
        id
    }

    pub fn lookup(&self, name: &str) -> Option<FileId> {
        lock_or_recover(&self.names, "cluster names").get(name).copied()
    }

    /// Append bytes (append-only, like Tectonic). Splits into chunks and
    /// places `replication` copies round-robin across nodes.
    pub fn append(&self, file: FileId, data: &[u8]) -> Result<()> {
        let mut files = write_or_recover(&self.files, "cluster files");
        let entry = files.get_mut(&file).context("no such file")?;
        if entry.sealed {
            bail!("file {file:?} is sealed (append-only store)");
        }
        let mut pos = 0usize;
        // Fill the tail chunk first if it has room.
        while pos < data.len() {
            let need_new = match entry.chunks.last() {
                Some(c) => c.len >= self.cfg.chunk_bytes,
                None => true,
            };
            if need_new {
                // Relaxed on both: `next_chunk` is another unique-ID
                // ticket; `rr` is a best-effort round-robin cursor where
                // placement only needs spread, not a total order.
                let chunk_id = self.next_chunk.fetch_add(1, Ordering::Relaxed);
                let start = self.rr.fetch_add(1, Ordering::Relaxed);
                let replicas: Vec<usize> = (0..self.cfg.replication)
                    .map(|r| (start + r) % self.nodes.len())
                    .collect();
                for &n in &replicas {
                    self.nodes[n].put_chunk(chunk_id, Vec::new());
                }
                entry.chunks.push(ChunkLoc {
                    chunk_id,
                    replicas,
                    len: 0,
                });
            }
            let chunk = entry.chunks.last_mut().unwrap();
            let room = (self.cfg.chunk_bytes - chunk.len) as usize;
            let take = room.min(data.len() - pos);
            let piece = &data[pos..pos + take];
            for &n in &chunk.replicas {
                self.nodes[n].append_chunk(chunk.chunk_id, piece);
            }
            chunk.len += take as u64;
            entry.len += take as u64;
            pos += take;
        }
        Ok(())
    }

    /// Seal a file (no further appends; readers may cache layout).
    pub fn seal(&self, file: FileId) {
        if let Some(e) =
            write_or_recover(&self.files, "cluster files").get_mut(&file)
        {
            e.sealed = true;
        }
    }

    pub fn file_len(&self, file: FileId) -> Option<u64> {
        read_or_recover(&self.files, "cluster files")
            .get(&file)
            .map(|e| e.len)
    }

    /// Total bytes stored across all nodes (includes replication).
    pub fn stored_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.stored_bytes()).sum()
    }

    /// Logical bytes (pre-replication).
    pub fn logical_bytes(&self) -> u64 {
        read_or_recover(&self.files, "cluster files")
            .values()
            .map(|e| e.len)
            .sum()
    }

    /// Execute one logical read `[offset, offset+len)` of a file. The read
    /// is split at chunk boundaries; each piece goes to one replica
    /// (rotating for load spread).
    pub fn read_range(&self, file: FileId, io: IoRange) -> Result<Vec<u8>> {
        let files = read_or_recover(&self.files, "cluster files");
        let entry = files.get(&file).context("no such file")?;
        if io.offset + io.len > entry.len {
            bail!(
                "read past EOF: {}+{} > {}",
                io.offset,
                io.len,
                entry.len
            );
        }
        let mut out = Vec::with_capacity(io.len as usize);
        let mut remaining = io.len;
        let mut pos = io.offset;
        while remaining > 0 {
            let ci = (pos / self.cfg.chunk_bytes) as usize;
            let within = pos % self.cfg.chunk_bytes;
            let chunk = &entry.chunks[ci];
            let take = remaining.min(chunk.len - within);
            // Chunk-affine replica selection: a scan over one chunk keeps
            // hitting the same node so the head-position model sees the
            // sequentiality a real reader preserves (readers don't bounce
            // replicas mid-scan).
            let replica_idx = (chunk.chunk_id as usize) % chunk.replicas.len();
            let node = &self.nodes[chunk.replicas[replica_idx]];
            let data = node
                .read(chunk.chunk_id, within, take)
                .context("replica read failed")?;
            out.extend_from_slice(&data);
            pos += take;
            remaining -= take;
        }
        Ok(out)
    }

    /// Execute a set of planned I/Os, returning decode-ready buffers.
    pub fn execute_ios(&self, file: FileId, ios: &[IoRange]) -> Result<IoBuffers> {
        let mut bufs = IoBuffers::new();
        for &io in ios {
            let data = self.read_range(file, io)?;
            bufs.insert(io, data);
        }
        Ok(bufs)
    }

    /// Execute planned stream extents with per-file read coalescing:
    /// sorted extents within `window` merge into one physical I/O (gap
    /// bytes are over-read) — the read broker's batched-fetch path,
    /// where one shared fetch covers a whole stripe's wanted streams.
    /// Returns the decode-ready buffers plus the number of physical
    /// I/Os actually issued (callers account `extents - ios` as saved).
    pub fn execute_ios_merged(
        &self,
        file: FileId,
        extents: &[IoRange],
        window: Option<u64>,
    ) -> Result<(IoBuffers, usize)> {
        let ios = crate::dwrf::plan::coalesce(extents.to_vec(), window);
        let mut bufs = IoBuffers::new();
        for &io in &ios {
            bufs.insert(io, self.read_range(file, io)?);
        }
        Ok((bufs, ios.len()))
    }

    /// Aggregate I/O stats across nodes.
    pub fn stats(&self) -> IoStats {
        let mut s = IoStats::default();
        for n in &self.nodes {
            s.merge(&n.stats());
        }
        s
    }

    pub fn reset_stats(&self) {
        for n in &self.nodes {
            n.reset_stats();
        }
    }

    pub fn node_stats(&self) -> Vec<IoStats> {
        self.nodes.iter().map(|n| n.stats()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cluster() -> Cluster {
        Cluster::new(ClusterConfig {
            nodes: 4,
            device: DeviceSpec::hdd(),
            replication: 3,
            chunk_bytes: 1024,
        })
    }

    #[test]
    fn append_and_read_roundtrip() {
        let c = small_cluster();
        let f = c.create("part-0");
        let data: Vec<u8> = (0..5000u32).map(|i| i as u8).collect();
        c.append(f, &data).unwrap();
        assert_eq!(c.file_len(f), Some(5000));
        let got = c
            .read_range(
                f,
                IoRange {
                    offset: 0,
                    len: 5000,
                },
            )
            .unwrap();
        assert_eq!(got, data);
    }

    #[test]
    fn reads_cross_chunk_boundaries() {
        let c = small_cluster();
        let f = c.create("x");
        let data: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
        c.append(f, &data).unwrap();
        // Read spanning chunks 0→2 (chunk=1024).
        let got = c
            .read_range(
                f,
                IoRange {
                    offset: 1000,
                    len: 1100,
                },
            )
            .unwrap();
        assert_eq!(got, data[1000..2100].to_vec());
    }

    #[test]
    fn replication_stores_copies() {
        let c = small_cluster();
        let f = c.create("r");
        c.append(f, &vec![7u8; 2048]).unwrap();
        // 2 chunks × 3 replicas.
        assert_eq!(c.stored_bytes(), 3 * 2048);
        assert_eq!(c.logical_bytes(), 2048);
    }

    #[test]
    fn sealed_file_rejects_append() {
        let c = small_cluster();
        let f = c.create("s");
        c.append(f, b"abc").unwrap();
        c.seal(f);
        assert!(c.append(f, b"more").is_err());
    }

    #[test]
    fn read_past_eof_errors() {
        let c = small_cluster();
        let f = c.create("e");
        c.append(f, b"hello").unwrap();
        assert!(c
            .read_range(f, IoRange { offset: 3, len: 10 })
            .is_err());
    }

    #[test]
    fn incremental_appends_accumulate() {
        let c = small_cluster();
        let f = c.create("inc");
        for i in 0..10u8 {
            c.append(f, &[i; 300]).unwrap();
        }
        assert_eq!(c.file_len(f), Some(3000));
        let got = c
            .read_range(
                f,
                IoRange {
                    offset: 299,
                    len: 2,
                },
            )
            .unwrap();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn stats_account_device_time() {
        let c = small_cluster();
        let f = c.create("st");
        c.append(f, &vec![0u8; 4096]).unwrap();
        c.reset_stats();
        for _ in 0..5 {
            c.read_range(f, IoRange { offset: 0, len: 512 }).unwrap();
        }
        let s = c.stats();
        assert_eq!(s.reads, 5);
        assert!(s.device_secs > 0.0);
    }

    #[test]
    fn execute_ios_returns_sliceable_buffers() {
        let c = small_cluster();
        let f = c.create("io");
        let data: Vec<u8> = (0..4000u32).map(|i| i as u8).collect();
        c.append(f, &data).unwrap();
        let ios = vec![
            IoRange { offset: 0, len: 100 },
            IoRange {
                offset: 2000,
                len: 500,
            },
        ];
        let bufs = c.execute_ios(f, &ios).unwrap();
        assert_eq!(bufs.bytes(), 600);
        assert_eq!(bufs.slice(2010, 4).unwrap(), &data[2010..2014]);
        assert!(bufs.slice(1000, 4).is_none());
    }

    #[test]
    fn merged_ios_coalesce_and_slice() {
        let c = small_cluster();
        let f = c.create("m");
        let data: Vec<u8> = (0..4000u32).map(|i| i as u8).collect();
        c.append(f, &data).unwrap();
        let extents = vec![
            IoRange { offset: 0, len: 100 },
            IoRange {
                offset: 150,
                len: 100,
            },
            IoRange {
                offset: 3000,
                len: 100,
            },
        ];
        let (bufs, ios) =
            c.execute_ios_merged(f, &extents, Some(1024)).unwrap();
        assert_eq!(ios, 2, "nearby extents merge; the distant one stays");
        assert!(bufs.bytes() >= 350, "gap bytes are over-read");
        assert_eq!(bufs.slice(150, 4).unwrap(), &data[150..154]);
        assert_eq!(bufs.slice(3000, 100).unwrap(), &data[3000..3100]);
        let (_, n) = c.execute_ios_merged(f, &extents, None).unwrap();
        assert_eq!(n, 3, "no window = one I/O per extent");
    }

    #[test]
    fn lookup_by_name() {
        let c = small_cluster();
        let f = c.create("warehouse/rm1/2026-07-01/part-0.dwrf");
        assert_eq!(c.lookup("warehouse/rm1/2026-07-01/part-0.dwrf"), Some(f));
        assert_eq!(c.lookup("nope"), None);
    }
}
