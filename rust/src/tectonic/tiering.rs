//! Heterogeneous storage tiering (§7.2): place commonly-used bytes on
//! SSD-backed nodes, keep capacity on HDD.
//!
//! The paper: "our SSD-based storage nodes can provide 326% IOPS per
//! watt, but trades off storage capacity with only 9% capacity per watt
//! ... opportunities such as placing commonly-used features on SSD-based
//! caches" — while warning that placement "must accurately predict and
//! place commonly-used bytes", driven by the Fig 7 reuse skew.
//!
//! [`TieredStore`] fronts a capacity [`Cluster`] (HDD) with a bounded
//! SSD cache cluster. Admission is *popularity-driven*: the byte budget
//! is spent on the hottest feature streams as ranked by the same
//! [`crate::popularity::AccessStats`] that drives feature reordering.

use super::cluster::{Cluster, ClusterConfig, FileId};
use super::node::IoStats;
use crate::config::DeviceSpec;
use crate::dwrf::{IoBuffers, IoRange};
use crate::metrics::Counter;
use crate::sync::{read_or_recover, write_or_recover, RwLock};
use anyhow::Result;
use std::collections::HashMap;

/// A cached extent of a file resident on the SSD tier.
#[derive(Clone, Copy, Debug)]
struct CachedExtent {
    range: IoRange,
    /// Location in the SSD tier's backing file.
    ssd_file: FileId,
    ssd_offset: u64,
}

/// SSD cache in front of an HDD capacity cluster.
pub struct TieredStore {
    pub hdd: std::sync::Arc<Cluster>,
    ssd: Cluster,
    /// Cache byte budget (the capacity/W trade-off knob).
    pub budget_bytes: u64,
    used: RwLock<u64>,
    /// file → cached extents (sorted by offset).
    extents: RwLock<HashMap<FileId, Vec<CachedExtent>>>,
    ssd_backing: RwLock<HashMap<FileId, FileId>>,
    pub hits: Counter,
    pub misses: Counter,
    pub bytes_from_ssd: Counter,
    pub bytes_from_hdd: Counter,
}

impl TieredStore {
    pub fn new(hdd: std::sync::Arc<Cluster>, ssd_nodes: usize, budget_bytes: u64) -> TieredStore {
        TieredStore {
            hdd,
            ssd: Cluster::new(ClusterConfig {
                nodes: ssd_nodes,
                device: DeviceSpec::ssd(),
                replication: 1, // cache tier: re-creatable, no replicas
                chunk_bytes: 8 << 20,
            }),
            budget_bytes,
            used: RwLock::new(0),
            extents: RwLock::new(HashMap::new()),
            ssd_backing: RwLock::new(HashMap::new()),
            hits: Counter::new(),
            misses: Counter::new(),
            bytes_from_ssd: Counter::new(),
            bytes_from_hdd: Counter::new(),
        }
    }

    pub fn cached_bytes(&self) -> u64 {
        *read_or_recover(&self.used, "tier usage")
    }

    /// Admit `[range]` of `file` to the SSD tier (no-op when over budget
    /// or already cached). Returns whether it was admitted.
    pub fn admit(&self, file: FileId, range: IoRange) -> Result<bool> {
        {
            let used = read_or_recover(&self.used, "tier usage");
            if *used + range.len > self.budget_bytes {
                return Ok(false);
            }
        }
        if self.lookup(file, range).is_some() {
            return Ok(true);
        }
        // Stage the bytes onto the SSD tier (charged to HDD once — the
        // promotion read).
        let data = self.hdd.read_range(file, range)?;
        let backing = {
            let mut b = write_or_recover(&self.ssd_backing, "tier backing");
            *b.entry(file).or_insert_with(|| {
                self.ssd.create(&format!("cache/{}", file.0))
            })
        };
        let ssd_offset = self.ssd.file_len(backing).unwrap_or(0);
        self.ssd.append(backing, &data)?;
        let mut ex = write_or_recover(&self.extents, "tier extents");
        let v = ex.entry(file).or_default();
        v.push(CachedExtent {
            range,
            ssd_file: backing,
            ssd_offset,
        });
        v.sort_by_key(|e| e.range.offset);
        *write_or_recover(&self.used, "tier usage") += range.len;
        Ok(true)
    }

    fn lookup(&self, file: FileId, range: IoRange) -> Option<CachedExtent> {
        let ex = read_or_recover(&self.extents, "tier extents");
        let v = ex.get(&file)?;
        v.iter()
            .find(|e| {
                range.offset >= e.range.offset
                    && range.offset + range.len <= e.range.end()
            })
            .copied()
    }

    /// Read one range: served from SSD when a cached extent covers it,
    /// from the HDD capacity tier otherwise.
    pub fn read_range(&self, file: FileId, range: IoRange) -> Result<Vec<u8>> {
        if let Some(e) = self.lookup(file, range) {
            self.hits.inc();
            self.bytes_from_ssd.add(range.len);
            let at = e.ssd_offset + (range.offset - e.range.offset);
            return self.ssd.read_range(
                e.ssd_file,
                IoRange {
                    offset: at,
                    len: range.len,
                },
            );
        }
        self.misses.inc();
        self.bytes_from_hdd.add(range.len);
        self.hdd.read_range(file, range)
    }

    /// Execute planned I/Os through the tier.
    pub fn execute_ios(&self, file: FileId, ios: &[IoRange]) -> Result<IoBuffers> {
        let mut bufs = IoBuffers::new();
        for &io in ios {
            bufs.insert(io, self.read_range(file, io)?);
        }
        Ok(bufs)
    }

    pub fn ssd_stats(&self) -> IoStats {
        self.ssd.stats()
    }

    pub fn hdd_stats(&self) -> IoStats {
        self.hdd.stats()
    }

    pub fn reset_stats(&self) {
        self.ssd.reset_stats();
        self.hdd.reset_stats();
        self.hits.reset();
        self.misses.reset();
        self.bytes_from_ssd.reset();
        self.bytes_from_hdd.reset();
    }

    /// Combined device seconds (the power-relevant service time).
    pub fn total_device_secs(&self) -> f64 {
        self.ssd.stats().device_secs + self.hdd.stats().device_secs
    }

    pub fn hit_rate(&self) -> f64 {
        let h = self.hits.get() as f64;
        let m = self.misses.get() as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn hdd_cluster_with_file(len: u64) -> (Arc<Cluster>, FileId) {
        let c = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 1 << 20,
            ..Default::default()
        }));
        let f = c.create("data");
        let data: Vec<u8> = (0..len).map(|i| (i % 251) as u8).collect();
        c.append(f, &data).unwrap();
        (c, f)
    }

    #[test]
    fn admission_respects_budget() {
        let (hdd, f) = hdd_cluster_with_file(100_000);
        let tier = TieredStore::new(hdd, 2, 10_000);
        assert!(tier
            .admit(f, IoRange { offset: 0, len: 8_000 })
            .unwrap());
        assert!(!tier
            .admit(f, IoRange { offset: 8_000, len: 8_000 })
            .unwrap());
        assert_eq!(tier.cached_bytes(), 8_000);
    }

    #[test]
    fn cached_reads_hit_ssd_and_match_hdd_bytes() {
        let (hdd, f) = hdd_cluster_with_file(100_000);
        let tier = TieredStore::new(hdd.clone(), 2, 1 << 20);
        let hot = IoRange {
            offset: 1_000,
            len: 20_000,
        };
        tier.admit(f, hot).unwrap();
        tier.reset_stats();
        // Sub-range of the cached extent: SSD hit.
        let got = tier
            .read_range(
                f,
                IoRange {
                    offset: 1_500,
                    len: 64,
                },
            )
            .unwrap();
        let want = hdd
            .read_range(
                f,
                IoRange {
                    offset: 1_500,
                    len: 64,
                },
            )
            .unwrap();
        assert_eq!(got, want);
        assert_eq!(tier.hits.get(), 1);
        assert_eq!(tier.misses.get(), 0);
        // Outside: HDD miss.
        tier.read_range(
            f,
            IoRange {
                offset: 60_000,
                len: 64,
            },
        )
        .unwrap();
        assert_eq!(tier.misses.get(), 1);
        assert!(tier.hit_rate() > 0.49 && tier.hit_rate() < 0.51);
    }

    #[test]
    fn ssd_tier_cuts_device_time_for_hot_small_reads() {
        let (hdd, f) = hdd_cluster_with_file(1 << 20);
        // Uncached: 50 small random reads on HDD.
        let cold = TieredStore::new(hdd.clone(), 2, 0);
        cold.reset_stats();
        for i in 0..50u64 {
            cold.read_range(
                f,
                IoRange {
                    offset: (i * 37_123) % 900_000,
                    len: 2_000,
                },
            )
            .unwrap();
        }
        let cold_secs = cold.total_device_secs();

        // Cached: the same hot region admitted to SSD first.
        let hot = TieredStore::new(hdd, 2, 1 << 20);
        hot.admit(
            f,
            IoRange {
                offset: 0,
                len: 1 << 20,
            },
        )
        .unwrap();
        hot.reset_stats();
        for i in 0..50u64 {
            hot.read_range(
                f,
                IoRange {
                    offset: (i * 37_123) % 900_000,
                    len: 2_000,
                },
            )
            .unwrap();
        }
        let hot_secs = hot.total_device_secs();
        assert_eq!(hot.hit_rate(), 1.0);
        assert!(
            cold_secs / hot_secs > 50.0,
            "SSD tier should slash service time: {cold_secs} vs {hot_secs}"
        );
    }
}
