//! Global training scheduler + the collaborative release process (§4):
//! exploratory → combo → release-candidate jobs across hundreds of
//! models, scheduled over geo-distributed regions with dataset
//! co-location — the generators behind Figs 4, 5, and 6 and the
//! bin-packing analysis of §7.3.

use crate::util::rng::{Pcg32, Zipf};

/// Job phase in the release process (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobType {
    /// Hundreds–thousands of small jobs, <5% of the table.
    Exploratory,
    /// Tens–hundreds of large jobs in a short window, most of the table.
    Combo,
    /// A few large final jobs on fresh data.
    ReleaseCandidate,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Completed,
    Failed,
    Killed,
}

/// One training job instance.
#[derive(Clone, Debug)]
pub struct Job {
    pub model: usize,
    pub kind: JobType,
    /// Start day (fractional) within the simulation horizon.
    pub start: f64,
    /// Duration in days.
    pub duration: f64,
    pub status: JobStatus,
    /// Relative compute demand (trainer nodes).
    pub demand: f64,
    /// Fraction of the model's table this job reads.
    pub table_fraction: f64,
}

impl Job {
    pub fn end(&self) -> f64 {
        self.start + self.duration
    }

    pub fn active_at(&self, day: f64) -> bool {
        day >= self.start && day < self.end()
    }
}

/// Generate one model-release iteration's combo jobs (Fig 4): skewed
/// lognormal durations (long tail past 10 days), temporally skewed
/// starts (engineers launch asynchronously to maximize explored ideas),
/// and a realistic status mix — many jobs fail or are killed for
/// lackluster performance.
pub fn combo_iteration(rng: &mut Pcg32, model: usize, n_jobs: usize, window_days: f64) -> Vec<Job> {
    let mut jobs = Vec::with_capacity(n_jobs);
    for _ in 0..n_jobs {
        // Asynchronous staggering across the window (earlier-heavy).
        let start = window_days * rng.f64().powf(1.5);
        let duration = rng.lognormal_mean(4.0, 0.9).clamp(0.1, 30.0);
        let u = rng.f64();
        let status = if u < 0.55 {
            JobStatus::Completed
        } else if u < 0.8 {
            JobStatus::Killed
        } else {
            JobStatus::Failed
        };
        // Killed jobs die partway through.
        let duration = match status {
            JobStatus::Killed => duration * rng.f64().max(0.05),
            JobStatus::Failed => duration * rng.f64().max(0.02),
            JobStatus::Completed => duration,
        };
        jobs.push(Job {
            model,
            kind: JobType::Combo,
            start,
            duration,
            status,
            demand: rng.lognormal_mean(8.0, 0.5),
            table_fraction: 0.6 + 0.3 * rng.f64(),
        });
    }
    jobs
}

/// The full release cycle for one model over `horizon_days`: continuous
/// exploratory background + periodic combo bursts + RC tails.
pub fn model_release_jobs(
    rng: &mut Pcg32,
    model: usize,
    horizon_days: f64,
    cycle_days: f64,
    demand_scale: f64,
) -> Vec<Job> {
    let mut jobs = Vec::new();
    // Exploratory: a steady trickle, small demand, tiny table fractions.
    let n_explore = (horizon_days * 3.0) as usize;
    for _ in 0..n_explore {
        jobs.push(Job {
            model,
            kind: JobType::Exploratory,
            start: rng.f64() * horizon_days,
            duration: rng.lognormal_mean(1.0, 0.8).clamp(0.05, 10.0),
            status: if rng.chance(0.7) {
                JobStatus::Completed
            } else {
                JobStatus::Killed
            },
            demand: demand_scale * rng.lognormal_mean(0.5, 0.4),
            table_fraction: 0.05 * rng.f64(),
        });
    }
    // Combo bursts every cycle + RC follow-ups.
    let mut t = rng.f64() * cycle_days;
    while t < horizon_days {
        let n = 40 + rng.below(80) as usize;
        for mut j in combo_iteration(rng, model, n, 10.0) {
            j.start += t;
            j.demand *= demand_scale;
            jobs.push(j);
        }
        for _ in 0..2 + rng.below(3) {
            jobs.push(Job {
                model,
                kind: JobType::ReleaseCandidate,
                start: t + 10.0 + rng.f64() * 4.0,
                duration: rng.lognormal_mean(6.0, 0.5).clamp(1.0, 20.0),
                status: JobStatus::Completed,
                demand: demand_scale * rng.lognormal_mean(10.0, 0.3),
                table_fraction: 0.9,
            });
        }
        t += cycle_days;
    }
    jobs
}

/// Daily total compute demand over a horizon (Fig 5's series).
pub fn daily_utilization(jobs: &[Job], horizon_days: usize) -> Vec<f64> {
    let mut days = vec![0.0; horizon_days];
    for j in jobs {
        let lo = j.start.floor().max(0.0) as usize;
        let hi = (j.end().ceil() as usize).min(horizon_days);
        for (d, slot) in days.iter_mut().enumerate().take(hi).skip(lo) {
            // Overlap of [d, d+1) with the job.
            let overlap = (j.end().min(d as f64 + 1.0)
                - j.start.max(d as f64))
            .clamp(0.0, 1.0);
            *slot += overlap * j.demand;
        }
    }
    days
}

/// Regions of the global fleet (Fig 6's R1–R5).
pub const REGIONS: usize = 5;

/// Placement of models' jobs onto regions. The current-production policy
/// balances each model across all regions (requiring every region to
/// hold a copy of its dataset); the bin-packed alternative pins each
/// model to few regions (§7.3).
#[derive(Clone, Debug)]
pub struct Placement {
    /// demand[model][region]
    pub demand: Vec<[f64; REGIONS]>,
    /// Region capacity used (max over time proxy: total demand).
    pub dataset_copies: usize,
}

/// Balance-everywhere policy: each model's demand spread across regions
/// proportional to regional capacity (uniform here).
pub fn place_balanced(rng: &mut Pcg32, model_demand: &[f64]) -> Placement {
    let mut demand = Vec::with_capacity(model_demand.len());
    for &d in model_demand {
        let mut row = [0.0; REGIONS];
        // Roughly even with jitter (the paper's Fig 6 shows every model
        // in every region, unevenly).
        let mut weights = [0.0; REGIONS];
        for w in weights.iter_mut() {
            *w = 0.5 + rng.f64();
        }
        let sum: f64 = weights.iter().sum();
        for r in 0..REGIONS {
            row[r] = d * weights[r] / sum;
        }
        demand.push(row);
    }
    Placement {
        dataset_copies: model_demand.len() * REGIONS,
        demand,
    }
}

/// Bin-packing policy: place each model in the fewest regions that fit
/// its peak demand given per-region capacity.
pub fn place_packed(model_demand: &[f64], region_capacity: f64) -> Placement {
    let mut free = [region_capacity; REGIONS];
    let mut demand = vec![[0.0; REGIONS]; model_demand.len()];
    let mut copies = 0;
    // Largest models first.
    let mut order: Vec<usize> = (0..model_demand.len()).collect();
    order.sort_by(|&a, &b| {
        model_demand[b].partial_cmp(&model_demand[a]).unwrap()
    });
    for m in order {
        let mut remaining = model_demand[m];
        // Fill best-fit regions until demand is placed.
        while remaining > 1e-12 {
            // Region with most free capacity.
            let (r, &cap) = free
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if cap <= 1e-12 {
                // Out of capacity; overflow into the emptiest region.
                demand[m][r] += remaining;
                copies += 1;
                break;
            }
            let take = remaining.min(cap);
            demand[m][r] += take;
            free[r] -= take;
            remaining -= take;
            copies += 1;
        }
    }
    Placement {
        demand,
        dataset_copies: copies,
    }
}

/// Fig 6 inputs: relative compute demand of the top-10 models (A–J),
/// normalized so model J = 1. Zipf-flavored decay matching the figure's
/// heavy skew.
pub fn top10_model_demand() -> Vec<f64> {
    let z = Zipf::new(10, 0.9);
    let base: Vec<f64> = (0..10).map(|k| z.pmf(k)).collect();
    let min = base[9];
    base.iter().map(|b| b / min).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combo_iteration_is_skewed_and_mixed() {
        let mut rng = Pcg32::new(82);
        let jobs = combo_iteration(&mut rng, 0, 82, 10.0);
        assert_eq!(jobs.len(), 82);
        let completed =
            jobs.iter().filter(|j| j.status == JobStatus::Completed).count();
        let failed =
            jobs.iter().filter(|j| j.status == JobStatus::Failed).count();
        let killed =
            jobs.iter().filter(|j| j.status == JobStatus::Killed).count();
        assert!(completed > 25 && completed < 70, "{completed}");
        assert!(failed + killed > 15, "many jobs fail/are killed (§4.1)");
        // Duration skew: max ≫ median; some > 10 days.
        let mut durs: Vec<f64> = jobs
            .iter()
            .filter(|j| j.status == JobStatus::Completed)
            .map(|j| j.duration)
            .collect();
        durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = durs[durs.len() / 2];
        let max = *durs.last().unwrap();
        assert!(max / median > 2.0, "skew {max}/{median}");
        // Paper: individual jobs "can take over 10 days"; with one 82-job
        // sample the tail lands near that.
        assert!(max > 8.0, "long-running jobs exist: {max}");
    }

    #[test]
    fn yearly_utilization_has_combo_peaks() {
        let mut rng = Pcg32::new(5);
        let mut jobs = Vec::new();
        for m in 0..20 {
            let scale = 1.0 / (m as f64 + 1.0).sqrt();
            jobs.extend(model_release_jobs(&mut rng, m, 365.0, 45.0, scale));
        }
        let days = daily_utilization(&jobs, 365);
        let mean = days.iter().sum::<f64>() / 365.0;
        let peak = days.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(
            peak / mean > 1.4,
            "distinct peaks expected: peak/mean = {}",
            peak / mean
        );
        // Utilization is never zero mid-year (continuous training).
        assert!(days[100..300].iter().all(|&d| d > 0.0));
    }

    #[test]
    fn top10_demand_is_skewed_normalized() {
        let d = top10_model_demand();
        assert_eq!(d.len(), 10);
        assert!((d[9] - 1.0).abs() < 1e-9);
        assert!(d[0] > 5.0, "model A ≫ model J: {}", d[0]);
        assert!(d.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn balanced_placement_uses_all_regions() {
        let mut rng = Pcg32::new(7);
        let p = place_balanced(&mut rng, &top10_model_demand());
        assert_eq!(p.dataset_copies, 50);
        for row in &p.demand {
            assert!(row.iter().all(|&d| d > 0.0));
        }
    }

    #[test]
    fn packed_placement_reduces_dataset_copies() {
        let demand = top10_model_demand();
        let total: f64 = demand.iter().sum();
        let p = place_packed(&demand, total / REGIONS as f64 * 1.2);
        assert!(
            p.dataset_copies < 50,
            "packing must beat replicate-everywhere: {}",
            p.dataset_copies
        );
        // All demand placed.
        let placed: f64 = p.demand.iter().flatten().sum();
        assert!((placed - total).abs() / total < 1e-9);
    }

    #[test]
    fn daily_utilization_conserves_job_mass() {
        let jobs = vec![Job {
            model: 0,
            kind: JobType::Combo,
            start: 1.25,
            duration: 2.5,
            status: JobStatus::Completed,
            demand: 4.0,
            table_fraction: 0.5,
        }];
        let days = daily_utilization(&jobs, 10);
        let mass: f64 = days.iter().sum();
        assert!((mass - 10.0).abs() < 1e-9, "4.0 demand × 2.5 days");
        assert_eq!(days[0], 0.0);
        assert!(days[1] > 0.0);
    }
}
