//! Span tracing: a bounded ring buffer of `(session, split, stage, t0,
//! dur)` events, exportable as Chrome trace-event JSON that loads in
//! `chrome://tracing` or Perfetto.

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_or_recover, Mutex};
use std::collections::VecDeque;

use crate::util::json::Json;

/// The DSI pipeline stages a span can belong to, in pipeline order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Master split enumeration + footer planning.
    Plan,
    /// Storage I/O: Tectonic reads (private or through the broker).
    Fetch,
    /// Decrypt + decode fetched streams into columnar rows, and apply
    /// the session's predicate/selection.
    Decode,
    /// The per-feature transform DAG.
    Transform,
    /// Tensorization: surviving rows into wire-ready tensor batches.
    Load,
    /// Worker-side channel send (includes backpressure waits).
    WireSend,
    /// Client-side receive, including any stall waiting for a batch.
    WireRecv,
    /// Client-side drain: decrypt + deserialize (+ dedup expansion).
    Drain,
}

impl Stage {
    pub const ALL: [Stage; 8] = [
        Stage::Plan,
        Stage::Fetch,
        Stage::Decode,
        Stage::Transform,
        Stage::Load,
        Stage::WireSend,
        Stage::WireRecv,
        Stage::Drain,
    ];
    pub const COUNT: usize = Self::ALL.len();

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Plan => "plan",
            Stage::Fetch => "fetch",
            Stage::Decode => "decode",
            Stage::Transform => "transform",
            Stage::Load => "load",
            Stage::WireSend => "wire_send",
            Stage::WireRecv => "wire_recv",
            Stage::Drain => "drain",
        }
    }

    #[inline]
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// One completed span. `t0_ns` is relative to the recorder's epoch.
#[derive(Clone, Copy, Debug)]
pub struct SpanEvent {
    /// Session index from [`super::Obs::register_session`] — the Chrome
    /// trace `pid`, so each session renders as its own process track.
    pub session: u32,
    /// Lane within the session (worker id, or client id offset past the
    /// workers) — the Chrome trace `tid`.
    pub tid: u32,
    pub split: u64,
    pub stage: Stage,
    pub t0_ns: u64,
    pub dur_ns: u64,
}

/// Bounded ring buffer of spans. When full, the oldest span is dropped
/// (and counted) so a long session keeps its most recent window.
#[derive(Debug)]
pub struct TraceRecorder {
    events: Mutex<VecDeque<SpanEvent>>,
    capacity: usize,
    dropped: AtomicU64,
}

impl TraceRecorder {
    pub fn new(capacity: usize) -> Self {
        Self {
            events: Mutex::new(VecDeque::with_capacity(capacity.min(4096))),
            capacity: capacity.max(1),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn record(&self, ev: SpanEvent) {
        let mut q = lock_or_recover(&self.events, "trace ring");
        if q.len() == self.capacity {
            q.pop_front();
            // Relaxed: `dropped` is a monotone statistic bumped under
            // the ring's mutex (so it can't race itself); readers only
            // want an eventual total, not an ordering edge.
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(ev);
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.events, "trace ring").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted to keep the buffer bounded.
    //
    // Relaxed load: pairs with the Relaxed bump in `record`; a sampler
    // may read a slightly stale drop count, never a torn one.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn events(&self) -> Vec<SpanEvent> {
        lock_or_recover(&self.events, "trace ring").iter().copied().collect()
    }

    /// Export as Chrome trace-event JSON: one `"M"` process-name record
    /// per session in `sessions` (index == pid), then one `"ph": "X"`
    /// complete event per span (ts/dur in microseconds).
    pub fn chrome_trace(&self, sessions: &[String]) -> Json {
        let mut events = Vec::new();
        for (pid, name) in sessions.iter().enumerate() {
            let mut args = Json::obj();
            args.set("name", format!("session {name}"));
            let mut m = Json::obj();
            m.set("ph", "M")
                .set("name", "process_name")
                .set("pid", pid)
                .set("tid", 0u64)
                .set("args", args);
            events.push(m);
        }
        for ev in self.events() {
            let mut args = Json::obj();
            args.set("split", ev.split);
            let mut x = Json::obj();
            x.set("ph", "X")
                .set("name", ev.stage.name())
                .set("cat", "dsi")
                .set("ts", ev.t0_ns as f64 / 1e3)
                .set("dur", ev.dur_ns.max(1) as f64 / 1e3)
                .set("pid", ev.session)
                .set("tid", ev.tid)
                .set("args", args);
            events.push(x);
        }
        let mut j = Json::obj();
        j.set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms");
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(session: u32, split: u64, stage: Stage, t0: u64, dur: u64) -> SpanEvent {
        SpanEvent {
            session,
            tid: 0,
            split,
            stage,
            t0_ns: t0,
            dur_ns: dur,
        }
    }

    #[test]
    fn stage_all_covers_every_variant() {
        assert_eq!(Stage::ALL.len(), Stage::COUNT);
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
    }

    #[test]
    fn ring_buffer_drops_oldest() {
        let t = TraceRecorder::new(3);
        for i in 0..5u64 {
            t.record(ev(0, i, Stage::Fetch, i * 100, 10));
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        let splits: Vec<u64> = t.events().iter().map(|e| e.split).collect();
        assert_eq!(splits, vec![2, 3, 4]);
    }

    #[test]
    fn chrome_trace_shape() {
        let t = TraceRecorder::new(16);
        t.record(ev(0, 7, Stage::Decode, 2_000, 1_500));
        let j = t.chrome_trace(&["rm1".to_string()]);
        let evs = match j.get("traceEvents").unwrap() {
            Json::Arr(xs) => xs,
            _ => panic!("traceEvents not an array"),
        };
        assert_eq!(evs.len(), 2); // metadata + span
        let span = &evs[1];
        assert_eq!(span.get("name"), Some(&Json::Str("decode".into())));
        assert_eq!(span.get("ts").unwrap().as_f64(), Some(2.0));
        assert_eq!(span.get("dur").unwrap().as_f64(), Some(1.5));
        let args = span.get("args").unwrap();
        assert_eq!(args.get("split").unwrap().as_f64(), Some(7.0));
    }

    #[test]
    fn zero_duration_spans_render_visible() {
        let t = TraceRecorder::new(4);
        t.record(ev(0, 1, Stage::Load, 0, 0));
        let j = t.chrome_trace(&[]);
        let evs = match j.get("traceEvents").unwrap() {
            Json::Arr(xs) => xs,
            _ => unreachable!(),
        };
        // 0 ns floors to 1 ns = 0.001 us so viewers draw the slice.
        assert_eq!(evs[0].get("dur").unwrap().as_f64(), Some(0.001));
    }
}
