//! Observability for the DSI pipeline: per-stage latency histograms,
//! span tracing (Chrome trace-event export), periodic session
//! telemetry, and client data-stall attribution.
//!
//! One [`Obs`] instance can span multiple concurrent sessions (each
//! [`register_session`](Obs::register_session) gets its own Chrome
//! trace `pid` track); Master, workers, broker, and clients emit spans
//! through cheap [`ObsHandle`]s — a histogram record plus one bounded
//! ring-buffer push per span, nothing on the hot path when tracing is
//! off (the handle is simply absent).

pub mod hist;
pub mod stall;
pub mod telemetry;
pub mod trace;

pub use hist::Histogram;
pub use stall::{StallAttribution, StallAttributor, StallSnapshot};
pub use telemetry::{SessionTelemetry, TelemetrySample};
pub use trace::{SpanEvent, Stage, TraceRecorder};

use crate::sync::{lock_or_recover, Mutex};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;

/// Default span ring-buffer capacity (~4 MB of spans).
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// Shared observability sink: one per run, shared across sessions.
#[derive(Debug)]
pub struct Obs {
    epoch: Instant,
    pub trace: TraceRecorder,
    hists: [Histogram; Stage::COUNT],
    sessions: Mutex<Vec<String>>,
}

impl Obs {
    pub fn new() -> Arc<Obs> {
        Self::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Arc<Obs> {
        Arc::new(Obs {
            epoch: Instant::now(),
            trace: TraceRecorder::new(capacity),
            hists: std::array::from_fn(|_| Histogram::new()),
            sessions: Mutex::new(Vec::new()),
        })
    }

    /// Register a session by name; the returned index is its Chrome
    /// trace `pid` and the `session` field of its spans.
    pub fn register_session(&self, name: &str) -> u32 {
        let mut s = lock_or_recover(&self.sessions, "obs sessions");
        s.push(name.to_string());
        (s.len() - 1) as u32
    }

    /// The latency histogram for one pipeline stage (all sessions).
    pub fn hist(&self, stage: Stage) -> &Histogram {
        &self.hists[stage.index()]
    }

    /// Record a span that started at `t0` and ends now: bumps the
    /// stage histogram and appends a trace event.
    pub fn span(&self, session: u32, tid: u32, split: u64, stage: Stage, t0: Instant) {
        let dur = t0.elapsed();
        self.hists[stage.index()].record(dur);
        let t0_ns = t0.saturating_duration_since(self.epoch).as_nanos() as u64;
        self.trace.record(SpanEvent {
            session,
            tid,
            split,
            stage,
            t0_ns,
            dur_ns: dur.as_nanos() as u64,
        });
    }

    /// Chrome trace-event JSON for every registered session's spans.
    pub fn chrome_trace(&self) -> Json {
        let sessions = lock_or_recover(&self.sessions, "obs sessions").clone();
        self.trace.chrome_trace(&sessions)
    }

    /// `{stage name: histogram summary}` across all stages.
    pub fn histograms_json(&self) -> Json {
        let mut j = Json::obj();
        for stage in Stage::ALL {
            j.set(stage.name(), self.hist(stage).summary_json());
        }
        j
    }
}

/// Cheap per-session handle: the [`Obs`] sink plus this session's id.
#[derive(Clone, Debug)]
pub struct ObsHandle {
    pub obs: Arc<Obs>,
    pub session: u32,
}

impl ObsHandle {
    /// Register `name` as a new session on `obs` and return its handle.
    pub fn for_session(obs: Arc<Obs>, name: &str) -> ObsHandle {
        let session = obs.register_session(name);
        ObsHandle { obs, session }
    }

    #[inline]
    pub fn span(&self, tid: u32, split: u64, stage: Stage, t0: Instant) {
        self.obs.span(self.session, tid, split, stage, t0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_land_in_histogram_and_trace() {
        let obs = Obs::with_capacity(8);
        let h = ObsHandle::for_session(obs.clone(), "rm1");
        assert_eq!(h.session, 0);
        let t0 = Instant::now();
        h.span(3, 42, Stage::Transform, t0);
        assert_eq!(obs.hist(Stage::Transform).count(), 1);
        assert_eq!(obs.trace.len(), 1);
        let ev = obs.trace.events()[0];
        assert_eq!(ev.session, 0);
        assert_eq!(ev.tid, 3);
        assert_eq!(ev.split, 42);
        assert_eq!(ev.stage, Stage::Transform);
    }

    #[test]
    fn sessions_get_distinct_pids() {
        let obs = Obs::new();
        let a = ObsHandle::for_session(obs.clone(), "a");
        let b = ObsHandle::for_session(obs.clone(), "b");
        assert_ne!(a.session, b.session);
        let j = obs.chrome_trace();
        match j.get("traceEvents").unwrap() {
            Json::Arr(xs) => assert_eq!(xs.len(), 2), // two metadata records
            _ => panic!("traceEvents not an array"),
        }
    }

    #[test]
    fn histograms_json_covers_every_stage() {
        let obs = Obs::new();
        let j = obs.histograms_json();
        for stage in Stage::ALL {
            assert!(j.get(stage.name()).is_some(), "{}", stage.name());
        }
    }
}
