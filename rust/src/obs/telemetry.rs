//! Periodic session telemetry: the control loop samples pool, buffer,
//! broker, and drain state into [`Series`] time-series — the per-session
//! inputs the ROADMAP's fleet-level scheduler arbitrates on.

use crate::metrics::Series;
use crate::util::json::Json;

/// One sampled snapshot. `drained_rows` / `stall_secs` are cumulative;
/// the telemetry turns them into rates between samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct TelemetrySample {
    pub t_secs: f64,
    pub live_workers: usize,
    /// Mean buffered tensor batches per live worker.
    pub avg_buffered: f64,
    pub broker_hit_rate: f64,
    pub broker_mem_bytes: u64,
    pub cache_bytes: u64,
    pub drained_rows: u64,
    pub stall_secs: f64,
}

/// Time-series telemetry for one session run.
#[derive(Clone, Debug)]
pub struct SessionTelemetry {
    pub live_workers: Series,
    pub avg_buffered: Series,
    pub broker_hit_rate: Series,
    pub broker_mem_mb: Series,
    pub cache_mb: Series,
    pub drain_rows_per_sec: Series,
    /// Stall seconds accrued per wall second; can exceed 1.0 when
    /// several clients stall concurrently.
    pub stall_frac: Series,
    last: Option<TelemetrySample>,
}

impl Default for SessionTelemetry {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionTelemetry {
    pub fn new() -> Self {
        Self {
            live_workers: Series::new("live_workers"),
            avg_buffered: Series::new("avg_buffered_tensors"),
            broker_hit_rate: Series::new("broker_hit_rate"),
            broker_mem_mb: Series::new("broker_mem_mb"),
            cache_mb: Series::new("cache_mb"),
            drain_rows_per_sec: Series::new("drain_rows_per_sec"),
            stall_frac: Series::new("stall_secs_per_sec"),
            last: None,
        }
    }

    pub fn observe(&mut self, s: TelemetrySample) {
        let t = s.t_secs;
        self.live_workers.push(t, s.live_workers as f64);
        self.avg_buffered.push(t, s.avg_buffered);
        self.broker_hit_rate.push(t, s.broker_hit_rate);
        self.broker_mem_mb.push(t, s.broker_mem_bytes as f64 / 1e6);
        self.cache_mb.push(t, s.cache_bytes as f64 / 1e6);
        if let Some(prev) = self.last {
            let dt = (t - prev.t_secs).max(1e-9);
            let drained = s.drained_rows.saturating_sub(prev.drained_rows);
            self.drain_rows_per_sec.push(t, drained as f64 / dt);
            let dstall = (s.stall_secs - prev.stall_secs).max(0.0);
            self.stall_frac.push(t, dstall / dt);
        }
        self.last = Some(s);
    }

    pub fn samples(&self) -> usize {
        self.live_workers.points.len()
    }

    fn all_series(&self) -> [&Series; 7] {
        [
            &self.live_workers,
            &self.avg_buffered,
            &self.broker_hit_rate,
            &self.broker_mem_mb,
            &self.cache_mb,
            &self.drain_rows_per_sec,
            &self.stall_frac,
        ]
    }

    /// `{"series": [{"name", "points": [[t, y], ...]}, ...]}`.
    pub fn to_json(&self) -> Json {
        let series: Vec<Json> = self
            .all_series()
            .iter()
            .map(|s| {
                let pts: Vec<Json> = s
                    .points
                    .iter()
                    .map(|&(x, y)| Json::Arr(vec![x.into(), y.into()]))
                    .collect();
                let mut j = Json::obj();
                j.set("name", s.name.as_str()).set("points", Json::Arr(pts));
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("series", Json::Arr(series));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_come_from_cumulative_deltas() {
        let mut t = SessionTelemetry::new();
        t.observe(TelemetrySample {
            t_secs: 0.0,
            live_workers: 2,
            drained_rows: 0,
            stall_secs: 0.0,
            ..Default::default()
        });
        t.observe(TelemetrySample {
            t_secs: 2.0,
            live_workers: 3,
            drained_rows: 500,
            stall_secs: 0.4,
            ..Default::default()
        });
        assert_eq!(t.samples(), 2);
        // Rate series only start at the second sample.
        assert_eq!(t.drain_rows_per_sec.points.len(), 1);
        let (_, rps) = t.drain_rows_per_sec.points[0];
        assert!((rps - 250.0).abs() < 1e-9);
        let (_, sf) = t.stall_frac.points[0];
        assert!((sf - 0.2).abs() < 1e-9);
        assert_eq!(t.live_workers.points[1].1, 3.0);
    }

    #[test]
    fn json_has_all_series() {
        let mut t = SessionTelemetry::new();
        t.observe(TelemetrySample::default());
        let j = t.to_json();
        let series = match j.get("series").unwrap() {
            Json::Arr(xs) => xs,
            _ => panic!("series not an array"),
        };
        assert_eq!(series.len(), 7);
        assert!(series
            .iter()
            .any(|s| s.get("name") == Some(&Json::Str("stall_secs_per_sec".into()))));
    }
}
