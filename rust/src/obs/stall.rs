//! Data-stall attribution: split the client's stall seconds into
//! storage-bound / decode-bound / transform-bound / worker-starved
//! buckets by looking at what the worker pool was doing *while* the
//! client waited (the paper's Fig 9 / Table 7 diagnostic, per session).
//!
//! The attributor consumes cumulative snapshots from the session
//! control loop. For each interval where stall time grew, the stall
//! delta is apportioned over the concurrent per-stage busy-time deltas;
//! worker idle time (live-worker wall capacity minus busy time) maps to
//! "worker-starved" — the pool had nothing leased or was too small.

use crate::util::json::Json;

/// Stall seconds attributed per cause. Buckets sum to the session's
/// `client_stall_secs` after [`StallAttributor::finish`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StallAttribution {
    /// Waiting on Tectonic reads (the fetch stage dominated).
    pub storage_secs: f64,
    /// Waiting on decrypt + decode.
    pub decode_secs: f64,
    /// Waiting on transforms + tensor load.
    pub transform_secs: f64,
    /// Workers were idle or absent while the client starved — a pool
    /// sizing / scheduling problem, not a stage bottleneck.
    pub starved_secs: f64,
}

impl StallAttribution {
    pub fn total(&self) -> f64 {
        self.storage_secs + self.decode_secs + self.transform_secs
            + self.starved_secs
    }

    /// Rescale the buckets so they sum exactly to `total` (the
    /// authoritative `client_stall_secs`). Zero/negative totals clear
    /// the attribution; an empty accumulator books everything as
    /// starved (stall with no observed concurrent work).
    pub fn scaled_to(&self, total: f64) -> StallAttribution {
        if total <= 0.0 {
            return StallAttribution::default();
        }
        let t = self.total();
        if t <= 1e-12 {
            return StallAttribution {
                starved_secs: total,
                ..StallAttribution::default()
            };
        }
        let k = total / t;
        StallAttribution {
            storage_secs: self.storage_secs * k,
            decode_secs: self.decode_secs * k,
            transform_secs: self.transform_secs * k,
            starved_secs: self.starved_secs * k,
        }
    }

    /// The heaviest bucket's label, for one-line reports.
    pub fn dominant(&self) -> &'static str {
        let buckets = [
            (self.storage_secs, "storage-bound"),
            (self.decode_secs, "decode-bound"),
            (self.transform_secs, "transform-bound"),
            (self.starved_secs, "worker-starved"),
        ];
        let mut best = (0.0f64, "none");
        for (v, name) in buckets {
            if v > best.0 {
                best = (v, name);
            }
        }
        best.1
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("storage_secs", self.storage_secs)
            .set("decode_secs", self.decode_secs)
            .set("transform_secs", self.transform_secs)
            .set("starved_secs", self.starved_secs)
            .set("dominant", self.dominant());
        j
    }
}

/// One cumulative observation from the session control loop.
#[derive(Clone, Copy, Debug, Default)]
pub struct StallSnapshot {
    /// Session wall clock, seconds since start.
    pub t_secs: f64,
    /// Cumulative client stall seconds (all clients summed).
    pub stall_secs: f64,
    /// Cumulative worker storage-read busy seconds (`t_read`).
    pub read_secs: f64,
    /// Cumulative decrypt+decode busy seconds (`t_extract`).
    pub decode_secs: f64,
    /// Cumulative transform + tensor-load busy seconds.
    pub transform_secs: f64,
    /// Live workers at snapshot time.
    pub live_workers: usize,
}

/// Incremental attributor: feed it cumulative [`StallSnapshot`]s,
/// read partial attribution via [`so_far`](Self::so_far), and close
/// with [`finish`](Self::finish) once the final stall total is known.
#[derive(Debug, Default)]
pub struct StallAttributor {
    prev: Option<StallSnapshot>,
    acc: StallAttribution,
}

impl StallAttributor {
    pub fn observe(&mut self, snap: StallSnapshot) {
        let Some(prev) = self.prev.replace(snap) else {
            return;
        };
        let dstall = snap.stall_secs - prev.stall_secs;
        if dstall <= 0.0 {
            return;
        }
        let dt = (snap.t_secs - prev.t_secs).max(0.0);
        let dread = (snap.read_secs - prev.read_secs).max(0.0);
        let ddecode = (snap.decode_secs - prev.decode_secs).max(0.0);
        let dxform = (snap.transform_secs - prev.transform_secs).max(0.0);
        let busy = dread + ddecode + dxform;
        let pool = snap.live_workers.max(prev.live_workers) as f64;
        let idle = (pool * dt - busy).max(0.0);
        let weight = busy + idle;
        if weight <= 1e-12 {
            // No workers and no work observed: the client starved.
            self.acc.starved_secs += dstall;
            return;
        }
        self.acc.storage_secs += dstall * dread / weight;
        self.acc.decode_secs += dstall * ddecode / weight;
        self.acc.transform_secs += dstall * dxform / weight;
        self.acc.starved_secs += dstall * idle / weight;
    }

    /// Attribution accumulated so far (unscaled).
    pub fn so_far(&self) -> StallAttribution {
        self.acc
    }

    /// Final attribution, rescaled so buckets sum exactly to `total`
    /// (the joined clients' stall seconds).
    pub fn finish(&self, total: f64) -> StallAttribution {
        self.acc.scaled_to(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(
        t: f64,
        stall: f64,
        read: f64,
        decode: f64,
        xform: f64,
        live: usize,
    ) -> StallSnapshot {
        StallSnapshot {
            t_secs: t,
            stall_secs: stall,
            read_secs: read,
            decode_secs: decode,
            transform_secs: xform,
            live_workers: live,
        }
    }

    #[test]
    fn attributes_to_the_busy_stage() {
        let mut a = StallAttributor::default();
        a.observe(snap(0.0, 0.0, 0.0, 0.0, 0.0, 1));
        // One worker fully busy reading while the client stalled 0.5s.
        a.observe(snap(1.0, 0.5, 1.0, 0.0, 0.0, 1));
        let got = a.finish(0.5);
        assert!((got.storage_secs - 0.5).abs() < 1e-9, "{got:?}");
        assert!((got.total() - 0.5).abs() < 1e-9);
        assert_eq!(got.dominant(), "storage-bound");
    }

    #[test]
    fn idle_pool_reads_as_starved() {
        let mut a = StallAttributor::default();
        a.observe(snap(0.0, 0.0, 0.0, 0.0, 0.0, 2));
        // Two live workers, zero busy time: all stall is starvation.
        a.observe(snap(1.0, 1.0, 0.0, 0.0, 0.0, 2));
        let got = a.finish(1.0);
        assert!((got.starved_secs - 1.0).abs() < 1e-9, "{got:?}");
        assert_eq!(got.dominant(), "worker-starved");
    }

    #[test]
    fn splits_proportionally_and_rescales() {
        let mut a = StallAttributor::default();
        a.observe(snap(0.0, 0.0, 0.0, 0.0, 0.0, 1));
        // 1 worker over 1s: 0.25 read, 0.25 decode, 0.5 transform.
        a.observe(snap(1.0, 0.8, 0.25, 0.5, 1.0, 1));
        // finish() rescales to the authoritative total.
        let got = a.finish(1.6);
        assert!((got.total() - 1.6).abs() < 1e-9);
        assert!((got.storage_secs - 0.4).abs() < 1e-9, "{got:?}");
        assert!((got.decode_secs - 0.4).abs() < 1e-9);
        assert!((got.transform_secs - 0.8).abs() < 1e-9);
        assert_eq!(got.dominant(), "transform-bound");
    }

    #[test]
    fn no_observations_books_everything_as_starved() {
        let a = StallAttributor::default();
        let got = a.finish(2.0);
        assert!((got.starved_secs - 2.0).abs() < 1e-12);
        assert_eq!(a.finish(0.0), StallAttribution::default());
        assert_eq!(StallAttribution::default().dominant(), "none");
    }

    #[test]
    fn stall_free_intervals_accumulate_nothing() {
        let mut a = StallAttributor::default();
        a.observe(snap(0.0, 0.0, 0.0, 0.0, 0.0, 1));
        a.observe(snap(1.0, 0.0, 0.9, 0.0, 0.0, 1));
        assert_eq!(a.so_far(), StallAttribution::default());
    }
}
