//! Log-bucketed latency histogram (HDR-lite): lock-free recording via
//! relaxed atomics, mergeable across workers/sessions, quantiles with a
//! bounded ~12% relative error.
//!
//! Buckets are 8 linear sub-buckets per power-of-two octave
//! (`SUB_BITS = 3`), covering 1 ns up to ~2.4 h; anything longer clamps
//! into the last bucket. Bucketing is deterministic per value, so
//! merging two histograms is exactly equivalent to recording both
//! streams into one (`merge == concat`, proven in `tests/proptests.rs`).

use crate::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

const SUB_BITS: u32 = 3;
const SUBS: usize = 1 << SUB_BITS;
/// Octaves above the linear 0..SUBS range; the top bucket absorbs
/// everything past ~2^43 ns (~2.4 h).
const OCTAVES: usize = 40;
const BUCKETS: usize = (OCTAVES + 1) * SUBS;

/// Map a nanosecond value to its bucket index.
#[inline]
fn bucket_index(ns: u64) -> usize {
    if ns < SUBS as u64 {
        return ns as usize;
    }
    let octave = 63 - ns.leading_zeros();
    let shift = octave - SUB_BITS;
    let sub = ((ns >> shift) & (SUBS as u64 - 1)) as usize;
    (((octave - SUB_BITS + 1) as usize) * SUBS + sub).min(BUCKETS - 1)
}

/// Inclusive upper bound (in ns) of the values a bucket holds — what
/// quantiles report, so they never understate the true value (except in
/// the clamped top bucket).
#[inline]
fn bucket_upper_ns(idx: usize) -> u64 {
    if idx < SUBS {
        return idx as u64;
    }
    let octave = (idx / SUBS - 1) as u32 + SUB_BITS;
    let sub = (idx % SUBS) as u64;
    let shift = octave - SUB_BITS;
    (1u64 << octave) + (sub << shift) + (1u64 << shift) - 1
}

/// Thread-safe log-bucketed histogram of durations.
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    // Relaxed throughout: the three cells are independent monotone
    // counters, never read back to make control decisions. A concurrent
    // reader may observe the bucket bump without the total (or vice
    // versa) — quantile() tolerates that skew explicitly — but no update
    // is ever lost (fetch_add is an atomic RMW at every ordering), so
    // quiescent reads are exact.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.counts[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Total recorded time in seconds (exact, not bucket-quantized).
    //
    // Relaxed load: reporting read of a monotone sum (see record_ns).
    pub fn total_secs(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.total_secs() / n as f64
        }
    }

    /// Quantile in seconds: the upper bound of the bucket holding the
    /// rank-`ceil(q * count)` observation. 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .clamp(1, total);
        let mut seen = 0u64;
        // Relaxed bucket loads: pairs with record_ns's Relaxed bumps —
        // a concurrent scan may see a bucket without its total (skew
        // handled below); quiescent scans are exact.
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_upper_ns(i) as f64 / 1e9;
            }
        }
        // Racy concurrent records can leave `seen` short (Relaxed loads
        // may see `count` bumped before its bucket); report the max.
        bucket_upper_ns(BUCKETS - 1) as f64 / 1e9
    }

    /// Fold `other` into `self`. Bucket-exact: the result is identical
    /// to having recorded both streams into one histogram.
    //
    // Relaxed is enough: each of `other`'s cells is read exactly once,
    // so a quiescent `other` merges losslessly; a concurrently-recorded
    // `other` may contribute a torn-but-valid prefix (some records
    // missing, none duplicated), matching record_ns's own guarantee.
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.counts.iter().zip(other.counts.iter()) {
            a.fetch_add(b.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum_ns
            .fetch_add(other.sum_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// `{count, p50, p95, p99, mean_secs, total_secs}` for report JSON.
    pub fn summary_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", self.count())
            .set("p50_secs", self.quantile(0.50))
            .set("p95_secs", self.quantile(0.95))
            .set("p99_secs", self.quantile(0.99))
            .set("mean_secs", self.mean_secs())
            .set("total_secs", self.total_secs());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_continuous_at_octaves() {
        // 0..SUBS map to themselves; 8..15 stay continuous.
        for ns in 0..64u64 {
            assert!(bucket_index(ns + 1) >= bucket_index(ns));
            assert!(bucket_upper_ns(bucket_index(ns)) >= ns);
        }
        assert_eq!(bucket_index(7), 7);
        assert_eq!(bucket_index(8), 8);
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        // Huge values clamp instead of indexing out of range.
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn bucket_upper_bounds_are_strictly_increasing() {
        for i in 1..BUCKETS {
            assert!(bucket_upper_ns(i) > bucket_upper_ns(i - 1), "idx {i}");
        }
    }

    #[test]
    fn quantiles_bracket_recorded_values() {
        let h = Histogram::new();
        for ms in 1..=100u64 {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 100);
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        // p50 of 1..=100 ms is ~50ms, within one bucket (~12%).
        assert!((0.045..=0.060).contains(&p50), "p50 {p50}");
        assert!((0.095..=0.120).contains(&p99), "p99 {p99}");
        assert!(h.quantile(0.0) <= p50 && p50 <= p99);
        assert!((h.total_secs() - 5.050).abs() < 1e-6);
    }

    #[test]
    fn merge_equals_concat() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for ns in [3u64, 900, 1_000_000, 17, 42_000_000_000] {
            a.record_ns(ns);
            all.record_ns(ns);
        }
        for ns in [5u64, 5, 123_456, 7_000_000_000] {
            b.record_ns(ns);
            all.record_ns(ns);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.total_secs(), all.total_secs());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
        let j = h.summary_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(0.0));
    }
}
