//! In-memory sample representations.
//!
//! Two formats, mirroring the paper's §7.5 "in-memory flatmaps" discussion:
//!
//! * [`Sample`] — row-oriented map format (feature id → value), the
//!   *baseline* DPP Worker representation. Reconstructing these from
//!   columnar storage costs format conversions and copies.
//! * [`ColumnarBatch`] — the flatmap format that matches both the DWRF
//!   on-disk layout and the tensor layout, eliminating most conversions
//!   (the paper's +FM optimization, +15% worker throughput).

use crate::schema::FeatureId;
use anyhow::{bail, Result};

/// Variable-length sparse value: categorical ids, optionally scored.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseValue {
    pub ids: Vec<u64>,
    /// Parallel per-id float scores (ScoredSparse features only).
    pub scores: Option<Vec<f32>>,
}

impl SparseValue {
    pub fn ids(ids: Vec<u64>) -> SparseValue {
        SparseValue { ids, scores: None }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

/// Row-oriented training sample (map format). Features are sorted by id
/// so lookups can binary-search.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Sample {
    pub dense: Vec<(FeatureId, f32)>,
    pub sparse: Vec<(FeatureId, SparseValue)>,
    pub label: f32,
    /// Event timestamp (seconds) — used by GetLocalHour and partitioning.
    pub timestamp: u64,
}

impl Sample {
    pub fn get_dense(&self, id: FeatureId) -> Option<f32> {
        self.dense
            .binary_search_by_key(&id, |(f, _)| *f)
            .ok()
            .map(|i| self.dense[i].1)
    }

    pub fn get_sparse(&self, id: FeatureId) -> Option<&SparseValue> {
        self.sparse
            .binary_search_by_key(&id, |(f, _)| *f)
            .ok()
            .map(|i| &self.sparse[i].1)
    }

    pub fn sort_features(&mut self) {
        self.dense.sort_by_key(|(f, _)| *f);
        self.sparse.sort_by_key(|(f, _)| *f);
    }

    /// Approximate in-memory bytes (for memory accounting).
    pub fn approx_bytes(&self) -> usize {
        let dense = self.dense.len() * 8;
        let sparse: usize = self
            .sparse
            .iter()
            .map(|(_, v)| {
                16 + v.ids.len() * 8
                    + v.scores.as_ref().map_or(0, |s| s.len() * 4)
            })
            .sum();
        16 + dense + sparse
    }
}

/// Presence bitmap over rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Bitmap {
    bits: Vec<u64>,
    len: usize,
}

impl Bitmap {
    pub fn new(len: usize) -> Bitmap {
        Bitmap {
            bits: vec![0; len.div_ceil(64)],
            len,
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        self.bits[i / 64] |= 1 << (i % 64);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        (self.bits[i / 64] >> (i % 64)) & 1 == 1
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn count_ones(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Indices of set bits, ascending — the selection-vector form.
    pub fn ones(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count_ones());
        for i in 0..self.len {
            if self.get(i) {
                out.push(i as u32);
            }
        }
        out
    }

    pub fn words(&self) -> &[u64] {
        &self.bits
    }

    pub fn from_words(words: Vec<u64>, len: usize) -> Bitmap {
        assert!(words.len() == len.div_ceil(64));
        Bitmap { bits: words, len }
    }

    /// Append `other`'s bits after this bitmap's (bit-shifted splice) —
    /// the concatenation step when a stripe is decoded as independent
    /// row-group chunks. Tail bits beyond either length are masked off,
    /// so bitmaps deserialized from untrusted words stay well-formed.
    pub fn append(&mut self, other: &Bitmap) {
        let old_len = self.len;
        // Clear any garbage above our own length before splicing.
        let tail = old_len % 64;
        if tail != 0 {
            if let Some(w) = self.bits.get_mut(old_len / 64) {
                *w &= (1u64 << tail) - 1;
            }
        }
        self.len = old_len + other.len;
        self.bits.resize(self.len.div_ceil(64), 0);
        if other.len == 0 {
            return;
        }
        let shift = old_len % 64;
        let base = old_len / 64;
        let last = other.bits.len() - 1;
        let other_tail = other.len % 64;
        for (i, &raw) in other.bits.iter().enumerate() {
            let w = if i == last && other_tail != 0 {
                raw & ((1u64 << other_tail) - 1)
            } else {
                raw
            };
            self.bits[base + i] |= w << shift;
            if shift != 0 && base + i + 1 < self.bits.len() {
                self.bits[base + i + 1] |= w >> (64 - shift);
            }
        }
    }
}

/// One dense feature column: compact values for present rows + presence.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseColumn {
    pub id: FeatureId,
    pub present: Bitmap,
    /// Values only for rows where `present` is set, in row order.
    pub values: Vec<f32>,
}

impl DenseColumn {
    /// Expand into a per-row vector with `default` for missing rows.
    pub fn expand(&self, default: f32) -> Vec<f32> {
        let mut out = vec![default; self.present.len()];
        let mut vi = 0;
        for (row, slot) in out.iter_mut().enumerate() {
            if self.present.get(row) {
                *slot = self.values[vi];
                vi += 1;
            }
        }
        out
    }
}

/// One sparse feature column in CSR-like layout: `offsets.len() == rows+1`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseColumn {
    pub id: FeatureId,
    pub offsets: Vec<u32>,
    pub ids: Vec<u64>,
    pub scores: Option<Vec<f32>>,
}

impl SparseColumn {
    pub fn num_rows(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn row(&self, r: usize) -> &[u64] {
        &self.ids[self.offsets[r] as usize..self.offsets[r + 1] as usize]
    }

    pub fn row_scores(&self, r: usize) -> Option<&[f32]> {
        self.scores.as_ref().map(|s| {
            &s[self.offsets[r] as usize..self.offsets[r + 1] as usize]
        })
    }

    pub fn empty(id: FeatureId, rows: usize) -> SparseColumn {
        SparseColumn {
            id,
            offsets: vec![0; rows + 1],
            ids: Vec::new(),
            scores: None,
        }
    }

    /// Append `other`'s rows after this column's (CSR splice). Scores
    /// must cover all ids or none on both sides; a scored/unscored
    /// mismatch with actual ids present is a format inconsistency.
    pub fn append(&mut self, other: &SparseColumn) -> Result<()> {
        match (&self.scores, &other.scores) {
            (Some(_), None) if !other.ids.is_empty() => {
                bail!("appending unscored ids to scored column {:?}", self.id)
            }
            (None, Some(_)) if !self.ids.is_empty() => {
                bail!("appending scored ids to unscored column {:?}", self.id)
            }
            _ => {}
        }
        let base = self.offsets.last().copied().unwrap_or(0);
        self.offsets
            .extend(other.offsets[1..].iter().map(|&o| o + base));
        self.ids.extend_from_slice(&other.ids);
        if let Some(b) = &other.scores {
            self.scores
                .get_or_insert_with(Vec::new)
                .extend_from_slice(b);
        }
        Ok(())
    }
}

/// Column-oriented batch — the in-memory *flatmap* (paper §7.5 +FM):
/// matches both DWRF streams and the final tensor layout.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ColumnarBatch {
    pub num_rows: usize,
    pub dense: Vec<DenseColumn>,
    pub sparse: Vec<SparseColumn>,
    pub labels: Vec<f32>,
    pub timestamps: Vec<u64>,
    /// Predicate-driven selection vector: ascending indices of the rows
    /// that survive the session's row filter. `None` ⇒ every row. A
    /// partially-matching stripe decodes **once** and carries its
    /// survivors here; the holder must [`ColumnarBatch::compact`]
    /// before handing the batch to consumers that read rows positionally
    /// (`to_samples`, DAG execution, tensorization) — those treat every
    /// physical row as live and ignore this field.
    pub selection: Option<Vec<u32>>,
}

impl ColumnarBatch {
    /// Convert to row-oriented samples (the conversion +FM avoids).
    pub fn to_samples(&self) -> Vec<Sample> {
        let mut out: Vec<Sample> = (0..self.num_rows)
            .map(|r| Sample {
                label: self.labels[r],
                timestamp: *self.timestamps.get(r).unwrap_or(&0),
                ..Default::default()
            })
            .collect();
        for col in &self.dense {
            let mut vi = 0;
            for (r, s) in out.iter_mut().enumerate() {
                if col.present.get(r) {
                    s.dense.push((col.id, col.values[vi]));
                    vi += 1;
                }
            }
        }
        for col in &self.sparse {
            for (r, s) in out.iter_mut().enumerate() {
                let ids = col.row(r);
                if !ids.is_empty() {
                    s.sparse.push((
                        col.id,
                        SparseValue {
                            ids: ids.to_vec(),
                            scores: col.row_scores(r).map(|x| x.to_vec()),
                        },
                    ));
                }
            }
        }
        for s in &mut out {
            s.sort_features();
        }
        out
    }

    /// Build from row-oriented samples over a fixed feature layout.
    ///
    /// Scatter-based: each sample's (sorted, sparse-in-F) feature map is
    /// walked once and values land directly in their column builders — a
    /// per-(row, selected-feature) binary search was ~16% of pipeline CPU
    /// at warehouse feature counts (EXPERIMENTS.md §Perf).
    pub fn from_samples(
        samples: &[Sample],
        dense_ids: &[FeatureId],
        sparse_ids: &[FeatureId],
    ) -> ColumnarBatch {
        use std::collections::HashMap;
        let rows = samples.len();
        let dense_pos: HashMap<FeatureId, usize> = dense_ids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        let sparse_pos: HashMap<FeatureId, usize> = sparse_ids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i))
            .collect();
        let mut dense: Vec<DenseColumn> = dense_ids
            .iter()
            .map(|&id| DenseColumn {
                id,
                present: Bitmap::new(rows),
                values: Vec::new(),
            })
            .collect();
        let mut sparse: Vec<SparseColumn> = sparse_ids
            .iter()
            .map(|&id| SparseColumn {
                id,
                offsets: {
                    let mut v = Vec::with_capacity(rows + 1);
                    v.push(0u32);
                    v
                },
                ids: Vec::new(),
                scores: None,
            })
            .collect();
        for (r, s) in samples.iter().enumerate() {
            for (fid, v) in &s.dense {
                if let Some(&i) = dense_pos.get(fid) {
                    dense[i].present.set(r);
                    dense[i].values.push(*v);
                }
            }
            for (fid, v) in &s.sparse {
                if let Some(&i) = sparse_pos.get(fid) {
                    let col = &mut sparse[i];
                    col.ids.extend_from_slice(&v.ids);
                    if let Some(sc) = &v.scores {
                        col.scores
                            .get_or_insert_with(Vec::new)
                            .extend_from_slice(sc);
                    }
                }
            }
            // Close the row for every sparse column (CSR offsets).
            for col in &mut sparse {
                col.offsets.push(col.ids.len() as u32);
            }
        }
        ColumnarBatch {
            num_rows: rows,
            dense,
            sparse,
            labels: samples.iter().map(|s| s.label).collect(),
            timestamps: samples.iter().map(|s| s.timestamp).collect(),
            selection: None,
        }
    }

    /// Gather rows by index (repetition allowed) into a new batch — the
    /// expansion step of the dedup pipeline: `idx` is an inverse index
    /// over this batch's (unique) rows. Labels/timestamps are gathered
    /// when present; callers with per-output-row metadata (the DedupDWRF
    /// reader) overwrite them afterwards.
    pub fn gather(&self, idx: &[u32]) -> ColumnarBatch {
        let rows = idx.len();
        let mut dense = Vec::with_capacity(self.dense.len());
        for col in &self.dense {
            // Rank of each source row among present rows (value cursor).
            let n = col.present.len();
            let mut rank = Vec::with_capacity(n);
            let mut acc = 0usize;
            for r in 0..n {
                rank.push(acc);
                if col.present.get(r) {
                    acc += 1;
                }
            }
            let mut present = Bitmap::new(rows);
            let mut values = Vec::new();
            for (i, &u) in idx.iter().enumerate() {
                let u = u as usize;
                if col.present.get(u) {
                    present.set(i);
                    values.push(col.values[rank[u]]);
                }
            }
            dense.push(DenseColumn {
                id: col.id,
                present,
                values,
            });
        }
        let mut sparse = Vec::with_capacity(self.sparse.len());
        for col in &self.sparse {
            let mut offsets = Vec::with_capacity(rows + 1);
            offsets.push(0u32);
            let mut ids = Vec::new();
            let mut scores = col.scores.as_ref().map(|_| Vec::new());
            for &u in idx {
                let u = u as usize;
                ids.extend_from_slice(col.row(u));
                if let (Some(out), Some(sc)) = (&mut scores, col.row_scores(u))
                {
                    out.extend_from_slice(sc);
                }
                offsets.push(ids.len() as u32);
            }
            sparse.push(SparseColumn {
                id: col.id,
                offsets,
                ids,
                scores,
            });
        }
        let pick = |i: usize| -> usize { idx[i] as usize };
        ColumnarBatch {
            num_rows: rows,
            dense,
            sparse,
            labels: (0..rows)
                .map(|i| self.labels.get(pick(i)).copied().unwrap_or(0.0))
                .collect(),
            timestamps: (0..rows)
                .map(|i| self.timestamps.get(pick(i)).copied().unwrap_or(0))
                .collect(),
            selection: None,
        }
    }

    /// Rows surviving the selection (`num_rows` when unfiltered).
    pub fn live_rows(&self) -> usize {
        self.selection.as_ref().map_or(self.num_rows, |s| s.len())
    }

    /// Attach a predicate-driven selection vector (ascending row indices).
    pub fn with_selection(mut self, selection: Vec<u32>) -> ColumnarBatch {
        debug_assert!(selection.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(match selection.last() {
            Some(&r) => (r as usize) < self.num_rows,
            None => true,
        });
        self.selection = Some(selection);
        self
    }

    /// Materialize only the surviving rows as a dense batch (selection
    /// applied and cleared) — the compact-on-ship step at the tensor
    /// boundary. A no-op clone when no selection is attached.
    pub fn compact(&self) -> ColumnarBatch {
        match &self.selection {
            None => self.clone(),
            Some(sel) => self.gather(sel),
        }
    }

    /// Append `other`'s rows after this batch's — the concatenation step
    /// when a stripe is decoded as independent row-group chunks (only
    /// surviving groups are ever decoded; their batches splice back into
    /// one stripe batch in row order). Column sets must match exactly
    /// and neither side may carry a selection; both hold by construction
    /// for group chunks of one stripe, and violations (a corrupt footer
    /// indexing inconsistent group streams) error instead of silently
    /// misaligning columns.
    pub fn append_rows(&mut self, other: &ColumnarBatch) -> Result<()> {
        if self.selection.is_some() || other.selection.is_some() {
            bail!("append_rows on a batch with a pending selection");
        }
        if self.dense.len() != other.dense.len()
            || self.sparse.len() != other.sparse.len()
        {
            bail!(
                "append_rows column-set mismatch: {}+{} vs {}+{}",
                self.dense.len(),
                self.sparse.len(),
                other.dense.len(),
                other.sparse.len()
            );
        }
        for (a, b) in self.dense.iter_mut().zip(other.dense.iter()) {
            if a.id != b.id {
                bail!("append_rows dense column {:?} vs {:?}", a.id, b.id);
            }
            a.present.append(&b.present);
            a.values.extend_from_slice(&b.values);
        }
        for (a, b) in self.sparse.iter_mut().zip(other.sparse.iter()) {
            if a.id != b.id {
                bail!("append_rows sparse column {:?} vs {:?}", a.id, b.id);
            }
            a.append(b)?;
        }
        self.labels.extend_from_slice(&other.labels);
        self.timestamps.extend_from_slice(&other.timestamps);
        self.num_rows += other.num_rows;
        Ok(())
    }

    /// Restrict to the feature columns `keep` accepts; row meta,
    /// selection, and row count are preserved. This is how a session
    /// narrows a batch decoded once with a wider *shared* projection
    /// (the read broker's union across registered sessions) down to its
    /// own view — column order is preserved, so the result is identical
    /// to having decoded with the narrow projection directly.
    pub fn retain_features(
        &self,
        keep: impl Fn(FeatureId) -> bool,
    ) -> ColumnarBatch {
        ColumnarBatch {
            num_rows: self.num_rows,
            dense: self
                .dense
                .iter()
                .filter(|c| keep(c.id))
                .cloned()
                .collect(),
            sparse: self
                .sparse
                .iter()
                .filter(|c| keep(c.id))
                .cloned()
                .collect(),
            labels: self.labels.clone(),
            timestamps: self.timestamps.clone(),
            selection: self.selection.clone(),
        }
    }

    pub fn approx_bytes(&self) -> usize {
        let d: usize = self
            .dense
            .iter()
            .map(|c| c.values.len() * 4 + c.present.words().len() * 8)
            .sum();
        let s: usize = self
            .sparse
            .iter()
            .map(|c| {
                c.offsets.len() * 4
                    + c.ids.len() * 8
                    + c.scores.as_ref().map_or(0, |x| x.len() * 4)
            })
            .sum();
        d + s + self.labels.len() * 4 + self.timestamps.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(i: u64) -> Sample {
        let mut s = Sample {
            dense: vec![(FeatureId(0), i as f32), (FeatureId(2), -1.0)],
            sparse: vec![(
                FeatureId(10),
                SparseValue::ids(vec![i, i + 1, i + 2]),
            )],
            label: (i % 2) as f32,
            timestamp: 1_650_000_000 + i,
        };
        if i % 2 == 0 {
            s.sparse.push((
                FeatureId(11),
                SparseValue {
                    ids: vec![7],
                    scores: Some(vec![0.5]),
                },
            ));
        }
        s.sort_features();
        s
    }

    #[test]
    fn bitmap_roundtrip() {
        let mut b = Bitmap::new(130);
        b.set(0);
        b.set(64);
        b.set(129);
        assert!(b.get(0) && b.get(64) && b.get(129));
        assert!(!b.get(1) && !b.get(128));
        assert_eq!(b.count_ones(), 3);
        let b2 = Bitmap::from_words(b.words().to_vec(), 130);
        assert_eq!(b, b2);
    }

    #[test]
    fn columnar_roundtrip_preserves_samples() {
        let samples: Vec<Sample> = (0..17).map(sample).collect();
        let batch = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0), FeatureId(2)],
            &[FeatureId(10), FeatureId(11)],
        );
        assert_eq!(batch.num_rows, 17);
        let back = batch.to_samples();
        assert_eq!(back, samples);
    }

    #[test]
    fn retain_features_matches_narrow_build() {
        let samples: Vec<Sample> = (0..17).map(sample).collect();
        let wide = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0), FeatureId(2)],
            &[FeatureId(10), FeatureId(11)],
        );
        let keep = [FeatureId(0), FeatureId(10)];
        let narrow = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0)],
            &[FeatureId(10)],
        );
        assert_eq!(wide.retain_features(|f| keep.contains(&f)), narrow);
        // Row meta survives a projection that keeps nothing.
        let none = wide.retain_features(|_| false);
        assert_eq!(none.num_rows, 17);
        assert_eq!(none.labels, wide.labels);
        assert!(none.dense.is_empty() && none.sparse.is_empty());
    }

    #[test]
    fn dense_expand_fills_missing() {
        let samples = vec![sample(0), Sample::default(), sample(2)];
        let batch =
            ColumnarBatch::from_samples(&samples, &[FeatureId(0)], &[]);
        let col = &batch.dense[0];
        assert_eq!(col.values.len(), 2); // row 1 missing
        assert_eq!(col.expand(0.0), vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn sparse_rows_access() {
        let samples: Vec<Sample> = (0..4).map(sample).collect();
        let batch =
            ColumnarBatch::from_samples(&samples, &[], &[FeatureId(10)]);
        let col = &batch.sparse[0];
        assert_eq!(col.num_rows(), 4);
        assert_eq!(col.row(2), &[2, 3, 4]);
    }

    #[test]
    fn gather_expands_rows_with_repetition() {
        let samples: Vec<Sample> = (0..4).map(sample).collect();
        let batch = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0), FeatureId(2)],
            &[FeatureId(10), FeatureId(11)],
        );
        let idx = vec![2u32, 0, 2, 3, 0];
        let got = batch.gather(&idx);
        assert_eq!(got.num_rows, 5);
        let want: Vec<Sample> =
            idx.iter().map(|&u| samples[u as usize].clone()).collect();
        assert_eq!(got.to_samples(), want);
    }

    #[test]
    fn gather_identity_is_noop() {
        let samples: Vec<Sample> = (0..6).map(sample).collect();
        let batch = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0), FeatureId(2)],
            &[FeatureId(10), FeatureId(11)],
        );
        let idx: Vec<u32> = (0..6).collect();
        assert_eq!(batch.gather(&idx), batch);
    }

    #[test]
    fn selection_compacts_to_surviving_rows() {
        let samples: Vec<Sample> = (0..6).map(sample).collect();
        let batch = ColumnarBatch::from_samples(
            &samples,
            &[FeatureId(0), FeatureId(2)],
            &[FeatureId(10), FeatureId(11)],
        );
        assert_eq!(batch.live_rows(), 6);
        let sel = batch.clone().with_selection(vec![1, 4, 5]);
        assert_eq!(sel.live_rows(), 3);
        let compacted = sel.compact();
        assert_eq!(compacted.num_rows, 3);
        assert!(compacted.selection.is_none());
        let want: Vec<Sample> = [1usize, 4, 5]
            .iter()
            .map(|&i| samples[i].clone())
            .collect();
        assert_eq!(compacted.to_samples(), want);
        // Compacting an unselected batch is the identity.
        assert_eq!(batch.compact(), batch);
    }

    #[test]
    fn bitmap_ones_lists_set_bits() {
        let mut b = Bitmap::new(70);
        b.set(0);
        b.set(63);
        b.set(69);
        assert_eq!(b.ones(), vec![0, 63, 69]);
        assert_eq!(Bitmap::new(0).ones(), Vec::<u32>::new());
    }

    #[test]
    fn bitmap_append_splices_across_word_boundaries() {
        for (a_len, b_len) in [(0usize, 5usize), (5, 0), (60, 10), (64, 64), (70, 3), (1, 130)] {
            let mut a = Bitmap::new(a_len);
            let mut b = Bitmap::new(b_len);
            for i in (0..a_len).step_by(3) {
                a.set(i);
            }
            for i in (0..b_len).step_by(2) {
                b.set(i);
            }
            let mut joined = a.clone();
            joined.append(&b);
            assert_eq!(joined.len(), a_len + b_len);
            for i in 0..a_len {
                assert_eq!(joined.get(i), a.get(i), "{a_len}+{b_len} @ {i}");
            }
            for i in 0..b_len {
                assert_eq!(
                    joined.get(a_len + i),
                    b.get(i),
                    "{a_len}+{b_len} @ tail {i}"
                );
            }
        }
    }

    #[test]
    fn append_rows_equals_single_build() {
        let samples: Vec<Sample> = (0..13).map(sample).collect();
        let dense_ids = [FeatureId(0), FeatureId(2)];
        let sparse_ids = [FeatureId(10), FeatureId(11)];
        let whole =
            ColumnarBatch::from_samples(&samples, &dense_ids, &sparse_ids);
        let mut acc =
            ColumnarBatch::from_samples(&samples[..5], &dense_ids, &sparse_ids);
        let mid =
            ColumnarBatch::from_samples(&samples[5..9], &dense_ids, &sparse_ids);
        let tail =
            ColumnarBatch::from_samples(&samples[9..], &dense_ids, &sparse_ids);
        acc.append_rows(&mid).unwrap();
        acc.append_rows(&tail).unwrap();
        assert_eq!(acc, whole);
        // Mismatched column sets error instead of misaligning.
        let narrow =
            ColumnarBatch::from_samples(&samples[..2], &dense_ids, &[]);
        assert!(acc.append_rows(&narrow).is_err());
        let sel = whole.clone().with_selection(vec![0]);
        assert!(acc.append_rows(&sel).is_err());
    }

    #[test]
    fn sample_lookup_binary_search() {
        let s = sample(6);
        assert_eq!(s.get_dense(FeatureId(0)), Some(6.0));
        assert_eq!(s.get_dense(FeatureId(1)), None);
        assert_eq!(s.get_sparse(FeatureId(10)).unwrap().ids, vec![6, 7, 8]);
    }
}
