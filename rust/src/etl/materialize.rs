//! Offline/online transform balance (§7.5: "balancing transformations
//! between offline and online ETL").
//!
//! [`materialize_transforms`] runs a session's transform DAG *offline*
//! over a table and writes the outputs as a new, already-preprocessed
//! table. Online, the job then uses a pass-through DAG: extraction still
//! happens, but transformation cost moves off the training-time critical
//! path — paid once at write time instead of per training job, at the
//! price of extra stored bytes (exactly the trade-off the paper weighs;
//! it only pays off for outputs shared across many jobs, cf. Fig 7).

use crate::data::{Bitmap, ColumnarBatch, DenseColumn, SparseColumn};
use crate::dpp::Master;
use crate::dwrf::{DecodeMode, DwrfReader, DwrfWriter, Projection, WriterOptions};
use crate::schema::FeatureId;
use crate::tectonic::Cluster;
use crate::transforms::{TransformDag, Value};
use crate::warehouse::{Catalog, Partition};
use anyhow::{Context, Result};

/// Convert DAG output columns into a columnar batch (labels/timestamps
/// carried through from the source batch).
fn outputs_to_batch(
    outputs: Vec<(FeatureId, Value)>,
    labels: Vec<f32>,
    timestamps: Vec<u64>,
    rows: usize,
) -> ColumnarBatch {
    let mut dense = Vec::new();
    let mut sparse = Vec::new();
    for (id, v) in outputs {
        match v {
            Value::Dense(vals) => {
                let mut present = Bitmap::new(rows);
                for r in 0..rows {
                    present.set(r);
                }
                dense.push(DenseColumn {
                    id,
                    present,
                    values: vals,
                });
            }
            Value::Sparse {
                offsets,
                ids,
                scores,
            } => sparse.push(SparseColumn {
                id,
                offsets,
                ids,
                scores,
            }),
        }
    }
    ColumnarBatch {
        num_rows: rows,
        dense,
        sparse,
        labels,
        timestamps,
        selection: None,
    }
}

/// The pass-through DAG a job uses over a materialized table: every
/// output feature is read as-is.
pub fn passthrough_dag(outputs: &[(FeatureId, bool)]) -> TransformDag {
    let mut dag = TransformDag::default();
    for &(id, is_dense) in outputs {
        let n = if is_dense {
            dag.input_dense(id)
        } else {
            dag.input_sparse(id)
        };
        dag.output(id, n);
    }
    dag
}

/// Run `dag` offline over `table` and write the preprocessed outputs as
/// `<table>__materialized`. Returns the new table name and the output
/// feature layout (id, is_dense) for building the pass-through DAG.
pub fn materialize_transforms(
    cluster: &Cluster,
    catalog: &Catalog,
    table: &str,
    projection: &Projection,
    dag: &TransformDag,
    writer_opts: WriterOptions,
) -> Result<(String, Vec<(FeatureId, bool)>)> {
    let src = catalog.get(table).context("unknown table")?;
    let out_name = format!("{table}__materialized");
    let mut layout: Option<Vec<(FeatureId, bool)>> = None;
    let mut partitions = Vec::new();
    for p in &src.partitions {
        let meta = Master::fetch_meta(cluster, p.file)?;
        let reader = DwrfReader::from_meta(meta, table);
        let mut writer: Option<DwrfWriter> = None;
        let mut rows_written = 0u64;
        for si in 0..reader.meta.stripes.len() {
            let plan = reader.plan_stripes(projection, None, si, 1);
            let bufs = cluster.execute_ios(p.file, &plan.stripes[0].ios)?;
            let batch = reader.decode_stripe_columnar(
                si,
                &bufs,
                projection,
                DecodeMode::default(),
            )?;
            let (outputs, _) = dag.execute(&batch)?;
            // Fix the output layout from the first stripe seen.
            if layout.is_none() {
                layout = Some(
                    outputs
                        .iter()
                        .map(|(id, v)| (*id, matches!(v, Value::Dense(_))))
                        .collect(),
                );
            }
            if writer.is_none() {
                // One writer per output partition.
                let l = layout.as_ref().unwrap();
                let dense_ids: Vec<FeatureId> =
                    l.iter().filter(|(_, d)| *d).map(|(i, _)| *i).collect();
                let sparse_ids: Vec<FeatureId> =
                    l.iter().filter(|(_, d)| !*d).map(|(i, _)| *i).collect();
                writer = Some(DwrfWriter::new(
                    &out_name,
                    dense_ids,
                    sparse_ids,
                    writer_opts.clone(),
                ));
            }
            let rows = batch.num_rows;
            let out_batch = outputs_to_batch(
                outputs,
                batch.labels.clone(),
                batch.timestamps.clone(),
                rows,
            );
            writer
                .as_mut()
                .unwrap()
                .write_all(out_batch.to_samples());
            rows_written += rows as u64;
        }
        let bytes = writer.context("empty partition")?.finish();
        let fname = format!("warehouse/{out_name}/day={}/part-0.dwrf", p.day);
        let file = cluster.create(&fname);
        cluster.append(file, &bytes)?;
        cluster.seal(file);
        partitions.push(Partition {
            day: p.day,
            file,
            rows: rows_written,
            bytes: bytes.len() as u64,
        });
    }
    catalog.register(crate::warehouse::Table {
        name: out_name.clone(),
        schema: src.schema.clone(),
        partitions,
    });
    Ok((out_name, layout.unwrap_or_default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{RmConfig, RmId, SimScale};
    use crate::datagen::build_dataset;
    use crate::dpp::{PipelineOptions, SessionSpec, TensorBatch, WorkerCore};
    use crate::dwrf::crypto::StreamCipher;
    use crate::metrics::EtlMetrics;
    use crate::tectonic::ClusterConfig;
    use crate::transforms::dag::session_dag;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    fn run_session_tensors(
        cluster: &Arc<Cluster>,
        catalog: &Catalog,
        spec: SessionSpec,
    ) -> (Vec<TensorBatch>, Arc<EtlMetrics>) {
        let cipher = StreamCipher::for_table(&spec.table);
        let spec = Arc::new(spec);
        let master = Master::new(catalog, cluster, (*spec).clone()).unwrap();
        let w = master.register_worker();
        let metrics = Arc::new(EtlMetrics::default());
        let mut core =
            WorkerCore::new(spec.clone(), cluster.clone(), metrics.clone());
        let mut out = Vec::new();
        while let Some(split) = master.fetch_split(w) {
            for b in core.process_split(&split).unwrap() {
                out.push(crate::dpp::codec::decode_wire(&cipher, &b).unwrap());
            }
            master.complete_split(w, split.id);
        }
        (out, metrics)
    }

    #[test]
    fn materialized_table_yields_identical_tensors_with_no_online_transforms() {
        let cluster = Arc::new(Cluster::new(ClusterConfig {
            chunk_bytes: 128 << 10,
            ..Default::default()
        }));
        let catalog = Catalog::new();
        let rm = RmConfig::get(RmId::Rm1);
        let h = build_dataset(
            &cluster,
            &catalog,
            &rm,
            &SimScale::tiny(),
            WriterOptions {
                stripe_rows: 32,
                ..Default::default()
            },
            55,
        )
        .unwrap();
        let mut rng = Pcg32::new(55);
        let projection: Vec<FeatureId> =
            h.schema.sample_projection(&mut rng, 12, 1.0);
        let dag = session_dag(&mut rng, &rm, &h.schema, &projection);

        // Online path: full DAG at training time.
        let mut online_spec =
            SessionSpec::from_dag(&h.table_name, 0, u32::MAX, dag.clone(), 16);
        online_spec.projection = Projection::new(projection.iter().copied());
        online_spec.pipeline = PipelineOptions::default();
        let (online, online_metrics) =
            run_session_tensors(&cluster, &catalog, online_spec);

        // Offline path: materialize once, train with a pass-through DAG.
        let (mat_table, layout) = materialize_transforms(
            &cluster,
            &catalog,
            &h.table_name,
            &Projection::new(projection.iter().copied()),
            &dag,
            WriterOptions {
                stripe_rows: 32,
                ..Default::default()
            },
        )
        .unwrap();
        let pt = passthrough_dag(&layout);
        let mut offline_spec =
            SessionSpec::from_dag(&mat_table, 0, u32::MAX, pt, 16);
        offline_spec.projection =
            Projection::new(layout.iter().map(|(i, _)| *i));
        offline_spec.pipeline = PipelineOptions::default();
        let (offline, offline_metrics) =
            run_session_tensors(&cluster, &catalog, offline_spec);

        // Same number of samples; tensors carry the same features; the
        // dense/sparse content matches (both sides produce the DAG's
        // outputs — one at write time, one at read time).
        assert_eq!(online.len(), offline.len());
        let total_on: usize = online.iter().map(|t| t.rows).sum();
        let total_off: usize = offline.iter().map(|t| t.rows).sum();
        assert_eq!(total_on, total_off);
        for (a, b) in online.iter().zip(offline.iter()) {
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.dense_names, b.dense_names);
            assert_eq!(a.dense, b.dense);
            assert_eq!(a.sparse.len(), b.sparse.len());
            for ((fa, oa, ia), (fb, ob, ib)) in
                a.sparse.iter().zip(b.sparse.iter())
            {
                assert_eq!(fa, fb);
                assert_eq!(oa, ob);
                assert_eq!(ia, ib);
            }
        }
        // The whole point: online transform time collapses.
        assert!(
            offline_metrics.t_transform.secs()
                < online_metrics.t_transform.secs() * 0.5,
            "materialized transform time {:.6}s !<< online {:.6}s",
            offline_metrics.t_transform.secs(),
            online_metrics.t_transform.secs()
        );
        // The cost: the materialized table stores the derived features.
        let src_bytes = catalog.get(&h.table_name).unwrap().total_bytes();
        let mat_bytes = catalog.get(&mat_table).unwrap().total_bytes();
        assert!(mat_bytes > 0 && src_bytes > 0);
    }
}
