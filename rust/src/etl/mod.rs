//! Offline ETL (§3.1.1): joins raw feature and event logs from Scribe
//! into labeled, schematized samples — the batch jobs that produce the
//! partitioned offline datasets used to train new model versions.
//!
//! Both engines of the paper are modelled:
//! * [`batch_join`] — the Spark-like batch job building a day partition,
//! * [`StreamingJoiner`] — the streaming engine that incrementally joins
//!   as logs arrive (used for the continuous-update path).

pub mod materialize;

use crate::data::{Sample, SparseValue};
use crate::schema::FeatureId;
use crate::scribe::{EventLog, FeatureLog, Record, Scribe};
use std::collections::HashMap;

fn to_sample(f: &FeatureLog, engaged: bool) -> Sample {
    let mut s = Sample {
        dense: f
            .dense
            .iter()
            .map(|&(id, v)| (FeatureId(id), v))
            .collect(),
        sparse: f
            .sparse
            .iter()
            .map(|(id, ids)| (FeatureId(*id), SparseValue::ids(ids.clone())))
            .chain(f.scored.iter().map(|(id, pairs)| {
                (
                    FeatureId(*id),
                    SparseValue {
                        ids: pairs.iter().map(|p| p.0).collect(),
                        scores: Some(pairs.iter().map(|p| p.1).collect()),
                    },
                )
            }))
            .collect(),
        label: if engaged { 1.0 } else { 0.0 },
        timestamp: f.timestamp,
    };
    s.sort_features();
    s
}

/// Duplication observed in a joined batch (RecD-style ETL-time
/// detection): lets the materialization step decide whether a partition
/// is worth writing with the Dedup encoding before any bytes land in
/// the warehouse.
pub fn duplication_stats(samples: &[Sample]) -> crate::dedup::DedupStats {
    let mut st = crate::dedup::DedupStats::default();
    st.record(&crate::dedup::DedupIndex::analyze(samples));
    st
}

/// Batch join over complete streams: every feature log with a matching
/// event log becomes a labeled sample (in feature-log order).
pub fn batch_join(scribe: &Scribe, feature_stream: &str, event_stream: &str) -> Vec<Sample> {
    let (feats, _) = scribe.tail(feature_stream, 0);
    let (events, _) = scribe.tail(event_stream, 0);
    let mut outcomes: HashMap<u64, bool> = HashMap::new();
    for r in &events {
        if let Record::Event(e) = r {
            outcomes.insert(e.request_id, e.engaged);
        }
    }
    feats
        .iter()
        .filter_map(|r| match r {
            Record::Feature(f) => {
                outcomes.get(&f.request_id).map(|&e| to_sample(f, e))
            }
            _ => None,
        })
        .collect()
}

/// Incremental joiner: buffers unmatched logs; emits samples as pairs
/// complete. Mirrors the streaming engines that update in-production
/// models (§3.1.1).
#[derive(Default)]
pub struct StreamingJoiner {
    pending_features: HashMap<u64, FeatureLog>,
    pending_events: HashMap<u64, EventLog>,
    feature_cursor: usize,
    event_cursor: usize,
}

impl StreamingJoiner {
    pub fn new() -> StreamingJoiner {
        StreamingJoiner::default()
    }

    /// Pull new records from both streams; return newly-joined samples.
    pub fn poll(
        &mut self,
        scribe: &Scribe,
        feature_stream: &str,
        event_stream: &str,
    ) -> Vec<Sample> {
        let mut out = Vec::new();
        let (feats, fc) = scribe.tail(feature_stream, self.feature_cursor);
        self.feature_cursor = fc;
        let (events, ec) = scribe.tail(event_stream, self.event_cursor);
        self.event_cursor = ec;
        for r in events {
            if let Record::Event(e) = r {
                self.pending_events.insert(e.request_id, e);
            }
        }
        for r in feats {
            if let Record::Feature(f) = r {
                if let Some(e) = self.pending_events.remove(&f.request_id) {
                    out.push(to_sample(&f, e.engaged));
                } else {
                    self.pending_features.insert(f.request_id, f);
                }
            }
        }
        // Match any previously-buffered features against new events.
        let matched: Vec<u64> = self
            .pending_features
            .keys()
            .filter(|id| self.pending_events.contains_key(id))
            .copied()
            .collect();
        for id in matched {
            let f = self.pending_features.remove(&id).unwrap();
            let e = self.pending_events.remove(&id).unwrap();
            out.push(to_sample(&f, e.engaged));
        }
        out
    }

    pub fn pending(&self) -> (usize, usize) {
        (self.pending_features.len(), self.pending_events.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feature(id: u64) -> Record {
        Record::Feature(FeatureLog {
            request_id: id,
            timestamp: id,
            dense: vec![(0, id as f32)],
            sparse: vec![(10, vec![id, id + 1])],
            scored: vec![(11, vec![(5, 0.5)])],
        })
    }

    fn event(id: u64, engaged: bool) -> Record {
        Record::Event(EventLog {
            request_id: id,
            timestamp: id + 100,
            engaged,
        })
    }

    #[test]
    fn batch_join_labels_matched_pairs() {
        let s = Scribe::new();
        s.publish_all("f", (0..5).map(feature));
        s.publish_all("e", vec![event(0, true), event(2, false), event(4, true)]);
        let samples = batch_join(&s, "f", "e");
        assert_eq!(samples.len(), 3);
        assert_eq!(samples[0].label, 1.0);
        assert_eq!(samples[1].label, 0.0);
        // Scored sparse features carry scores through the join.
        let sv = samples[0].get_sparse(FeatureId(11)).unwrap();
        assert_eq!(sv.scores.as_deref(), Some(&[0.5f32][..]));
    }

    #[test]
    fn duplication_stats_sees_repeated_payloads() {
        let s = Scribe::new();
        // Two logs with identical payloads (ids 0 and 1 → same features
        // differ; reuse feature(1) payload under a fresh request id).
        let mut dup = match feature(1) {
            Record::Feature(f) => f,
            _ => unreachable!(),
        };
        dup.request_id = 99;
        s.publish_all("f", vec![feature(1), Record::Feature(dup), feature(2)]);
        s.publish_all(
            "e",
            vec![event(1, true), event(99, false), event(2, true)],
        );
        let joined = batch_join(&s, "f", "e");
        let st = duplication_stats(&joined);
        assert_eq!(st.rows, 3);
        assert_eq!(st.unique_rows, 2);
    }

    #[test]
    fn batch_join_drops_unmatched() {
        let s = Scribe::new();
        s.publish_all("f", (0..3).map(feature));
        s.publish("e", event(7, true)); // no matching feature log
        assert!(batch_join(&s, "f", "e").is_empty());
    }

    #[test]
    fn streaming_join_handles_out_of_order_arrival() {
        let s = Scribe::new();
        let mut j = StreamingJoiner::new();
        // Event arrives before its feature log.
        s.publish("e", event(1, true));
        assert!(j.poll(&s, "f", "e").is_empty());
        s.publish("f", feature(1));
        let got = j.poll(&s, "f", "e");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].label, 1.0);
        assert_eq!(j.pending(), (0, 0));
        // Feature first, then event.
        s.publish("f", feature(2));
        assert!(j.poll(&s, "f", "e").is_empty());
        assert_eq!(j.pending(), (1, 0));
        s.publish("e", event(2, false));
        let got = j.poll(&s, "f", "e");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].label, 0.0);
    }

    #[test]
    fn streaming_matches_batch_on_same_data() {
        let s = Scribe::new();
        s.publish_all("f", (0..20).map(feature));
        s.publish_all("e", (0..20).map(|i| event(i, i % 3 == 0)));
        let batch = batch_join(&s, "f", "e");
        let mut j = StreamingJoiner::new();
        let mut stream = j.poll(&s, "f", "e");
        stream.sort_by_key(|x| x.timestamp);
        let mut batch_sorted = batch.clone();
        batch_sorted.sort_by_key(|x| x.timestamp);
        assert_eq!(stream, batch_sorted);
    }
}
