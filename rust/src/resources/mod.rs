//! Node resource model: translates the worker pipeline's *measured*
//! per-sample costs (CPU seconds, bytes moved per stage) into projected
//! utilization and saturation throughput on the paper's hardware classes
//! (Table 10) — the machinery behind Fig 8, Fig 9, Table 7, and Table 9.
//!
//! Method: run the real pipeline on this host, measure per-sample CPU
//! time and count per-stage bytes; estimate memory traffic per stage with
//! pass multipliers (TLS decrypt amplifies memory bandwidth ≈3×, §7.2;
//! decompress/decode/serialize each re-touch their bytes); then, for a
//! target node, compute the throughput at which each resource saturates.
//! The minimum is the node's achievable throughput, and per-resource
//! utilization at that point reproduces the Fig 9 breakdown.

use crate::config::NodeSpec;
use crate::metrics::EtlMetrics;

/// Per-sample cost vector measured from a real pipeline run.
#[derive(Clone, Copy, Debug, Default)]
pub struct PerSampleCost {
    /// CPU seconds per sample (single-thread measured).
    pub cpu_secs: f64,
    /// Estimated memory-traffic bytes per sample.
    pub mem_bytes: f64,
    /// NIC receive bytes per sample (compressed storage reads).
    pub net_rx_bytes: f64,
    /// NIC transmit bytes per sample (serialized tensors).
    pub net_tx_bytes: f64,
    /// Resident bytes per sample held in buffers (memory capacity).
    pub resident_bytes: f64,
    /// CPU split for the Fig 9 stack (fractions of cpu_secs).
    pub frac_extract: f64,
    pub frac_transform: f64,
    pub frac_misc: f64,
}

/// Memory-traffic pass multipliers (how many times each stage's bytes
/// cross the memory bus). TLS ≈3× is from the paper (§7.2); the others
/// are one read + one write pass per transformation of the data.
pub mod passes {
    pub const NET_RX: f64 = 2.0; // NIC → kernel → user
    pub const TLS: f64 = 3.0; // §7.2: "TLS operations amplify ... by 3×"
    pub const DECOMPRESS: f64 = 2.0;
    pub const DECODE: f64 = 2.0;
    pub const TRANSFORM: f64 = 2.0;
    pub const SERIALIZE: f64 = 2.0;
    pub const NET_TX: f64 = 2.0;
}

impl PerSampleCost {
    /// Derive from pipeline metrics accumulated over a measured run.
    pub fn from_metrics(m: &EtlMetrics) -> PerSampleCost {
        let samples = m.samples.get().max(1) as f64;
        let storage_rx = m.storage_rx_bytes.get() as f64;
        let extracted = m.extract_out_bytes.get() as f64;
        let transformed = m.transform_out_bytes.get() as f64;
        let tx = m.tensor_tx_bytes.get() as f64;
        // Memory traffic: every stage's bytes times its pass count.
        let mem = storage_rx * (passes::NET_RX + passes::TLS + passes::DECOMPRESS)
            + extracted * passes::DECODE
            + (extracted + transformed) * passes::TRANSFORM
            + tx * (passes::SERIALIZE + passes::NET_TX);
        let cpu = m.total_secs();
        // Extraction = decompress/decrypt/decode (t_extract); the read
        // stage (network receive) and load stage (serialize/send) are the
        // "miscellaneous" datacenter-tax cycles of Fig 9.
        let extract_cpu = m.t_extract.secs();
        let transform_cpu = m.t_transform.secs();
        let misc_cpu = (cpu - extract_cpu - transform_cpu).max(0.0);
        PerSampleCost {
            cpu_secs: cpu / samples,
            mem_bytes: mem / samples,
            net_rx_bytes: storage_rx / samples,
            net_tx_bytes: tx / samples,
            resident_bytes: (extracted + tx) / samples,
            frac_extract: if cpu > 0.0 { extract_cpu / cpu } else { 0.0 },
            frac_transform: if cpu > 0.0 { transform_cpu / cpu } else { 0.0 },
            frac_misc: if cpu > 0.0 { misc_cpu / cpu } else { 0.0 },
        }
    }
}

/// Which resource binds first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bottleneck {
    Cpu,
    MemoryBandwidth,
    MemoryCapacity,
    NicRx,
    NicTx,
}

impl Bottleneck {
    pub fn name(&self) -> &'static str {
        match self {
            Bottleneck::Cpu => "CPU",
            Bottleneck::MemoryBandwidth => "memory BW",
            Bottleneck::MemoryCapacity => "memory capacity",
            Bottleneck::NicRx => "NIC rx",
            Bottleneck::NicTx => "NIC tx",
        }
    }
}

/// Utilization of one node at a given throughput.
#[derive(Clone, Copy, Debug)]
pub struct Utilization {
    pub samples_per_sec: f64,
    pub cpu: f64,
    pub mem_bw: f64,
    pub mem_cap: f64,
    pub nic_rx: f64,
    pub nic_tx: f64,
}

/// Saturation analysis of a pipeline on a node class.
#[derive(Clone, Debug)]
pub struct Saturation {
    pub node: &'static str,
    pub max_samples_per_sec: f64,
    pub bottleneck: Bottleneck,
    pub at_saturation: Utilization,
}

/// Host-speed calibration: measured per-sample CPU seconds on *this*
/// machine are translated to a reference-core budget. A C-v1-era core
/// (18-core Broadwell class) delivers roughly `HOST_CORE_EQUIV` of one
/// core of this host.
pub const HOST_CORE_EQUIV: f64 = 0.5;

/// Project utilization on `node` at `sps` samples/sec, with work spread
/// over all cores (workers run one pipeline thread per core).
pub fn utilization_at(cost: &PerSampleCost, node: &NodeSpec, sps: f64) -> Utilization {
    let cpu_capacity =
        node.physical_cores as f64 / (cost.cpu_secs / HOST_CORE_EQUIV).max(1e-18);
    // Buffered working set ~2s of throughput.
    let resident = cost.resident_bytes * sps * 2.0;
    Utilization {
        samples_per_sec: sps,
        cpu: sps / cpu_capacity,
        mem_bw: sps * cost.mem_bytes / (node.peak_mem_bw_gbps * 1e9),
        mem_cap: resident / (node.memory_gb * 1e9),
        nic_rx: sps * cost.net_rx_bytes * 8.0 / (node.nic_gbps * 1e9),
        nic_tx: sps * cost.net_tx_bytes * 8.0 / (node.nic_gbps * 1e9),
    }
}

/// Paper §6.2: memory bandwidth saturates at ≈70% of peak in practice.
pub const MEMBW_PRACTICAL_FRAC: f64 = 0.70;
/// Practical NIC ceiling (paper: ~10 of 12.5 Gbps reachable).
pub const NIC_PRACTICAL_FRAC: f64 = 0.80;

/// Find the node's saturation throughput and binding resource.
pub fn saturation(cost: &PerSampleCost, node: &NodeSpec) -> Saturation {
    let u1 = utilization_at(cost, node, 1.0);
    // Max sps per resource = practical limit / per-sps utilization.
    let candidates = [
        (Bottleneck::Cpu, 1.0 / u1.cpu.max(1e-18)),
        (
            Bottleneck::MemoryBandwidth,
            MEMBW_PRACTICAL_FRAC / u1.mem_bw.max(1e-18),
        ),
        (Bottleneck::MemoryCapacity, 0.9 / u1.mem_cap.max(1e-18)),
        (Bottleneck::NicRx, NIC_PRACTICAL_FRAC / u1.nic_rx.max(1e-18)),
        (Bottleneck::NicTx, NIC_PRACTICAL_FRAC / u1.nic_tx.max(1e-18)),
    ];
    let (bottleneck, sps) = candidates
        .iter()
        .copied()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    Saturation {
        node: node.name,
        max_samples_per_sec: sps,
        bottleneck,
        at_saturation: utilization_at(cost, node, sps),
    }
}

/// Trainer-side loading cost (Fig 8): per *wire byte* loaded, derived
/// from a measured client decode run + network-stack pass constants.
#[derive(Clone, Copy, Debug)]
pub struct LoadingCost {
    /// CPU seconds per wire byte (TLS + deserialization + memory mgmt).
    pub cpu_secs_per_byte: f64,
    /// Memory-bus passes per wire byte.
    pub mem_passes: f64,
}

/// Production loading paths (AES-NI TLS offload-assisted + tuned Thrift
/// C++) move roughly 3x more bytes per cycle than this repo's portable
/// implementation; Fig 8 models the production trainer, so the measured
/// per-byte cost is scaled by this efficiency factor (documented in
/// EXPERIMENTS.md).
pub const PRODUCTION_LOADING_EFF: f64 = 3.0;

impl LoadingCost {
    pub fn standard(measured_cpu_secs_per_byte: f64) -> LoadingCost {
        LoadingCost {
            cpu_secs_per_byte: measured_cpu_secs_per_byte
                / PRODUCTION_LOADING_EFF,
            // RX + TLS + deser + copy-to-pinned (Fig 8's "datacenter tax").
            mem_passes: passes::NET_RX + passes::TLS + 2.0,
        }
    }

    /// (CPU util, memBW util) on a trainer host at `gbps` of loading.
    pub fn trainer_utilization(
        &self,
        node: &crate::config::TrainerNodeSpec,
        gbps: f64,
    ) -> (f64, f64) {
        let bytes_per_sec = gbps * 1e9 / 8.0;
        let cpu = bytes_per_sec * self.cpu_secs_per_byte / HOST_CORE_EQUIV
            / node.total_cores() as f64;
        let mem = bytes_per_sec * self.mem_passes / (node.peak_mem_bw_gbps * 1e9);
        (cpu, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainerNodeSpec;
    use std::time::Duration;

    fn cost(cpu: f64, mem: f64, rx: f64, tx: f64) -> PerSampleCost {
        PerSampleCost {
            cpu_secs: cpu,
            mem_bytes: mem,
            net_rx_bytes: rx,
            net_tx_bytes: tx,
            resident_bytes: 1000.0,
            frac_extract: 0.3,
            frac_transform: 0.6,
            frac_misc: 0.1,
        }
    }

    #[test]
    fn from_metrics_accounts_all_stages() {
        let m = EtlMetrics::default();
        m.samples.add(100);
        m.storage_rx_bytes.add(10_000);
        m.extract_out_bytes.add(30_000);
        m.transform_out_bytes.add(15_000);
        m.tensor_tx_bytes.add(20_000);
        m.t_read.add(Duration::from_millis(100));
        m.t_extract.add(Duration::from_millis(200));
        m.t_transform.add(Duration::from_millis(600));
        m.t_load.add(Duration::from_millis(100));
        let c = PerSampleCost::from_metrics(&m);
        assert!((c.cpu_secs - 0.01).abs() < 1e-9);
        assert!(c.mem_bytes > (10_000f64 + 30_000.0 + 20_000.0) / 100.0);
        assert!((c.frac_transform - 0.6).abs() < 1e-9);
        // Extraction excludes the read stage (that's misc/datacenter tax).
        assert!((c.frac_extract - 0.2).abs() < 1e-9);
        assert!((c.frac_misc - 0.2).abs() < 1e-9);
    }

    #[test]
    fn cpu_bound_pipeline_saturates_on_cpu() {
        // Heavy compute, tiny bytes (an RM1-flavored transform load).
        let c = cost(1e-3, 1e4, 1e3, 1e3);
        let s = saturation(&c, &NodeSpec::c_v1());
        assert_eq!(s.bottleneck, Bottleneck::Cpu);
        assert!(s.at_saturation.cpu > 0.95);
        assert!(s.at_saturation.mem_bw < 0.5);
    }

    #[test]
    fn nic_bound_pipeline_saturates_on_rx() {
        // Cheap compute, fat reads (RM2: bound on ingress NIC, §6.3).
        let c = cost(1e-6, 1e4, 150_000.0, 1e3);
        let s = saturation(&c, &NodeSpec::c_v1());
        assert_eq!(s.bottleneck, Bottleneck::NicRx);
        assert!(s.at_saturation.nic_rx > 0.75);
    }

    #[test]
    fn membw_becomes_bottleneck_on_cv3() {
        // §6.3's projection: per-core memory bandwidth shrinks on newer
        // nodes, flipping a CPU-bound load to membw-bound.
        let c = cost(2.4e-5, 1.1e6, 1e4, 1e4);
        let v3 = saturation(&c, &NodeSpec::c_v3());
        assert_eq!(v3.bottleneck, Bottleneck::MemoryBandwidth);
    }

    #[test]
    fn trainer_loading_utilization_scales_linearly() {
        let lc = LoadingCost::standard(2e-9);
        let node = TrainerNodeSpec::v100_node();
        let (cpu1, mem1) = lc.trainer_utilization(&node, 4.0);
        let (cpu2, mem2) = lc.trainer_utilization(&node, 16.0);
        assert!((cpu2 / cpu1 - 4.0).abs() < 1e-9);
        assert!((mem2 / mem1 - 4.0).abs() < 1e-9);
        assert!(cpu2 > 0.0 && mem2 > 0.0);
    }

    #[test]
    fn utilization_components_nonnegative() {
        let c = cost(1e-4, 1e5, 1e4, 5e3);
        let u = utilization_at(&c, &NodeSpec::c_v2(), 1000.0);
        for v in [u.cpu, u.mem_bw, u.mem_cap, u.nic_rx, u.nic_tx] {
            assert!(v >= 0.0);
        }
    }
}
