//! The broker's decode-once stripe buffer: ref-counted shared stripe
//! payloads held under a [`MemoryBudget`] that other in-memory consumers
//! (the worker [`crate::dpp::TensorCache`]) can share, with single-flight
//! fetches so concurrent sessions never duplicate a storage read.

use super::SharedStripe;
use crate::data::{DenseColumn, SparseColumn};
use crate::metrics::Counter;
use crate::schema::FeatureId;
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_or_recover, wait_or_recover, Condvar, Mutex};
use crate::tectonic::FileId;
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;

/// One byte pool shared by every cache that pins decoded training data in
/// memory (broker stripe buffers, the preprocessed-tensor cache): each
/// consumer reserves before holding and releases on eviction, so the
/// *sum* stays bounded no matter which layer is hot.
pub struct MemoryBudget {
    total: u64,
    used: AtomicU64,
    /// High-water mark of `used`, for resident-bytes reporting. Advisory
    /// only (Relaxed; racing reservations may record a slightly stale
    /// peak) — never consulted by admission decisions.
    peak: AtomicU64,
}

impl MemoryBudget {
    pub fn new(total: u64) -> Arc<MemoryBudget> {
        Arc::new(MemoryBudget {
            total,
            used: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        })
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    // Relaxed loads: reporting reads of `used`/`peak` want a recent
    // value, not a synchronized one; both are plain counters with no
    // data published through them.
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::Relaxed)
    }

    /// Highest `used` ever observed by a successful reservation.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Reserve `bytes` if the pool has room.
    //
    // Relaxed CAS loop: the budget invariant (`used + bytes <= total`)
    // is enforced by the compare_exchange itself — a stale initial load
    // only costs a retry. No memory is published by a reservation; the
    // buffers it guards hand data over under their own mutexes.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let Some(next) = cur.checked_add(bytes) else {
                return false;
            };
            if next > self.total {
                return false;
            }
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    // Relaxed max-CAS: `peak` is advisory (see the
                    // field doc); racing reservations may settle the
                    // high-water mark in any order, monotone either way.
                    let mut p = self.peak.load(Ordering::Relaxed);
                    while next > p {
                        match self.peak.compare_exchange_weak(
                            p,
                            next,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        ) {
                            Ok(_) => break,
                            Err(now) => p = now,
                        }
                    }
                    return true;
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Return `bytes` to the pool. Saturates at zero: the pool can
    /// never go negative, and a defensive over-release clamps instead
    /// of wrapping (see `budget_reserve_release`).
    //
    // Relaxed CAS loop: like try_reserve, the subtraction is made
    // atomic by the CAS; release carries no payload to synchronize.
    pub fn release(&self, bytes: u64) {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }
}

/// Buffer key: one decoded stripe of one file.
pub type StripeKey = (FileId, usize);

/// What a fetch produced, before the buffer takes ownership.
pub struct FetchedStripe {
    pub stripe: SharedStripe,
    /// Features the payload was decoded with (a superset of every
    /// registered session's projection at fetch time).
    pub proj: HashSet<FeatureId>,
    /// Storage bytes fetched.
    pub fetched_bytes: u64,
    /// Stream extents wanted / physical I/Os issued after coalescing.
    pub extents: usize,
    pub ios: usize,
}

/// How one serve was satisfied.
pub enum ServeOutcome {
    /// Another session already paid the fetch + decode.
    Hit {
        payload: Arc<SharedStripe>,
        /// Storage bytes this hit avoided re-reading.
        saved_bytes: u64,
    },
    /// This serve fetched and decoded the stripe.
    Fetched {
        payload: Arc<SharedStripe>,
        fetched_bytes: u64,
        extents: usize,
        ios: usize,
    },
}

struct ReadyEntry {
    payload: Arc<SharedStripe>,
    proj: HashSet<FeatureId>,
    fetched_bytes: u64,
    mem_bytes: u64,
    last_used: u64,
    /// Whether `mem_bytes` is reserved against the budget.
    charged: bool,
}

enum Slot {
    /// A fetch is in flight; waiters block on the condvar.
    Loading,
    Ready(ReadyEntry),
}

struct BufState {
    entries: HashMap<StripeKey, Slot>,
    tick: u64,
}

/// Budget-bounded map of decoded stripes. Entries are dropped eagerly
/// once the last registered session consumes them (`remaining == 0`) and
/// lazily (LRU, unreferenced first) under budget pressure.
pub struct StripeBuffer {
    state: Mutex<BufState>,
    cv: Condvar,
    budget: Arc<MemoryBudget>,
    pub evictions: Counter,
}

impl StripeBuffer {
    pub fn new(budget: Arc<MemoryBudget>) -> StripeBuffer {
        StripeBuffer {
            state: Mutex::new(BufState {
                entries: HashMap::new(),
                tick: 0,
            }),
            cv: Condvar::new(),
            budget,
            evictions: Counter::new(),
        }
    }

    pub fn budget(&self) -> &Arc<MemoryBudget> {
        &self.budget
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.state, "stripe buffer").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serve one stripe: a buffered payload decoded with a sufficient
    /// projection is returned directly; otherwise `fetch` runs exactly
    /// once (concurrent callers for the same key wait instead of
    /// duplicating the storage read). `remaining` is the number of
    /// *other* registered serves still expected for this key — the entry
    /// is released as soon as it reaches zero, and never cached when the
    /// caller was the last one interested.
    pub fn serve<F>(
        &self,
        key: StripeKey,
        needed: &[FeatureId],
        remaining: usize,
        fetch: F,
    ) -> Result<ServeOutcome>
    where
        F: FnOnce() -> Result<FetchedStripe>,
    {
        enum Action {
            Hit,
            Refetch,
            Wait,
            Load,
        }
        let mut st = lock_or_recover(&self.state, "stripe buffer");
        loop {
            let action = match st.entries.get(&key) {
                Some(Slot::Ready(e)) => {
                    if needed.iter().all(|f| e.proj.contains(f)) {
                        Action::Hit
                    } else {
                        Action::Refetch
                    }
                }
                Some(Slot::Loading) => Action::Wait,
                None => Action::Load,
            };
            match action {
                Action::Hit => {
                    st.tick += 1;
                    let tick = st.tick;
                    let (payload, saved) = match st.entries.get_mut(&key) {
                        Some(Slot::Ready(e)) => {
                            e.last_used = tick;
                            (e.payload.clone(), e.fetched_bytes)
                        }
                        _ => unreachable!("checked Ready above"),
                    };
                    if remaining == 0 {
                        // Last interested session: free the memory now.
                        if let Some(Slot::Ready(e)) = st.entries.remove(&key) {
                            if e.charged {
                                self.budget.release(e.mem_bytes);
                            }
                        }
                    }
                    self.check_accounting(&st);
                    return Ok(ServeOutcome::Hit {
                        payload,
                        saved_bytes: saved,
                    });
                }
                Action::Refetch => {
                    // Decoded with an insufficient projection (an earlier,
                    // narrower registration): drop it and refetch with the
                    // wider union.
                    if let Some(Slot::Ready(e)) = st.entries.remove(&key) {
                        if e.charged {
                            self.budget.release(e.mem_bytes);
                        }
                    }
                    break;
                }
                Action::Wait => {
                    st = wait_or_recover(&self.cv, st, "stripe buffer");
                }
                Action::Load => break,
            }
        }
        st.entries.insert(key, Slot::Loading);
        drop(st);

        // The guard clears the Loading slot and wakes waiters on *any*
        // early exit — fetch error or fetch panic (a worker dying
        // mid-decode) — so peers parked on the condvar retry instead of
        // blocking forever on a slot no one will ever fill.
        let mut cleanup = LoadGuard {
            buf: self,
            key,
            armed: true,
        };
        let fetched = fetch()?;
        let payload = Arc::new(fetched.stripe);
        let mem = payload.mem_bytes();
        let mut st = lock_or_recover(&self.state, "stripe buffer");
        cleanup.armed = false;
        let charged = remaining > 0 && self.reserve_evicting(&mut st, mem);
        if charged {
            st.tick += 1;
            let tick = st.tick;
            st.entries.insert(
                key,
                Slot::Ready(ReadyEntry {
                    payload: payload.clone(),
                    proj: fetched.proj,
                    fetched_bytes: fetched.fetched_bytes,
                    mem_bytes: mem,
                    last_used: tick,
                    charged: true,
                }),
            );
        } else {
            // Nobody else wants it, or the budget is pinned solid: serve
            // this caller without caching.
            st.entries.remove(&key);
        }
        self.check_accounting(&st);
        drop(st);
        self.cv.notify_all();
        Ok(ServeOutcome::Fetched {
            payload,
            fetched_bytes: fetched.fetched_bytes,
            extents: fetched.extents,
            ios: fetched.ios,
        })
    }

    /// Drop a buffered stripe (e.g. its last registered session went
    /// away without consuming it). In-flight loads are left alone.
    pub fn release(&self, key: StripeKey) {
        let mut st = lock_or_recover(&self.state, "stripe buffer");
        if matches!(st.entries.get(&key), Some(Slot::Ready(_))) {
            if let Some(Slot::Ready(e)) = st.entries.remove(&key) {
                if e.charged {
                    self.budget.release(e.mem_bytes);
                }
            }
        }
        self.check_accounting(&st);
    }

    /// Debug/model invariant: bytes charged by Ready entries never
    /// exceed the pool's `used` (the budget is shared with other
    /// consumers — e.g. the tensor cache — so equality only holds when
    /// this buffer is the sole consumer), and `used` never exceeds
    /// `total`.
    #[cfg(any(debug_assertions, loom))]
    fn check_accounting(&self, st: &BufState) {
        let charged: u64 = st
            .entries
            .values()
            .map(|s| match s {
                Slot::Ready(e) if e.charged => e.mem_bytes,
                _ => 0,
            })
            .sum();
        let used = self.budget.used();
        assert!(
            charged <= used,
            "buffer charged {charged} bytes > budget used {used}"
        );
        assert!(
            used <= self.budget.total(),
            "budget used {used} > total {}",
            self.budget.total()
        );
    }

    #[cfg(not(any(debug_assertions, loom)))]
    fn check_accounting(&self, _st: &BufState) {}

    /// Reserve `bytes`, evicting least-recently-used entries that no
    /// session currently holds a handle to. Returns false when the pool
    /// cannot fit the reservation even after evicting everything
    /// evictable (entries pinned by live `Arc` handles stay).
    fn reserve_evicting(&self, st: &mut BufState, bytes: u64) -> bool {
        loop {
            if self.budget.try_reserve(bytes) {
                return true;
            }
            let victim = st
                .entries
                .iter()
                .filter_map(|(k, slot)| match slot {
                    Slot::Ready(e)
                        if e.charged
                            && Arc::strong_count(&e.payload) == 1 =>
                    {
                        Some((*k, e.last_used))
                    }
                    _ => None,
                })
                .min_by_key(|&(_, t)| t)
                .map(|(k, _)| k);
            let Some(k) = victim else {
                return false;
            };
            if let Some(Slot::Ready(e)) = st.entries.remove(&k) {
                self.budget.release(e.mem_bytes);
                self.evictions.inc();
            }
        }
    }
}

/// Unwind guard for the un-locked fetch window of [`StripeBuffer::serve`]:
/// while armed, dropping it removes the `Loading` slot and wakes every
/// waiter, so neither a fetch `Err` nor a fetch panic strands peers.
struct LoadGuard<'a> {
    buf: &'a StripeBuffer,
    key: StripeKey,
    armed: bool,
}

impl Drop for LoadGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            let mut st =
                lock_or_recover(&self.buf.state, "stripe load cleanup");
            st.entries.remove(&self.key);
            drop(st);
            self.buf.cv.notify_all();
        }
    }
}

/// Identity of one cacheable column slice within a stripe. `Meta` covers
/// the row-level payload every projection needs (labels, timestamps, and
/// the dedup inverse index when present); `Feature` is one feature's
/// column.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ColumnId {
    Meta,
    Feature(FeatureId),
}

/// One decoded column payload, shareable across every session whose
/// projection includes it — regardless of what else each session
/// projects.
pub enum SharedColumn {
    Dense(DenseColumn),
    Sparse(SparseColumn),
    /// Per-stripe row metadata. `inverse` is present iff the stripe is
    /// `Encoding::Dedup`; `col_rows` is the row count the feature
    /// columns carry (unique rows under dedup, total rows otherwise).
    Meta {
        labels: Vec<f32>,
        timestamps: Vec<u64>,
        inverse: Option<Vec<u32>>,
        col_rows: usize,
    },
}

impl SharedColumn {
    pub fn mem_bytes(&self) -> u64 {
        match self {
            SharedColumn::Dense(c) => {
                (c.present.words().len() * 8 + c.values.len() * 4) as u64
            }
            SharedColumn::Sparse(c) => (c.offsets.len() * 4
                + c.ids.len() * 8
                + c.scores.as_ref().map_or(0, |s| s.len() * 4))
                as u64,
            SharedColumn::Meta {
                labels,
                timestamps,
                inverse,
                ..
            } => (labels.len() * 4
                + timestamps.len() * 8
                + inverse.as_ref().map_or(0, |i| i.len() * 4))
                as u64,
        }
    }
}

/// What a column-grain fetch produced: each requested column's payload
/// plus the storage bytes attributable to it (for hit-savings
/// accounting), and the whole fetch's I/O stats.
pub struct FetchedColumns {
    pub cols: Vec<(ColumnId, SharedColumn, u64)>,
    pub fetched_bytes: u64,
    pub extents: usize,
    pub ios: usize,
}

/// How one column-grain serve was satisfied: every needed column's
/// payload, plus how many came from cache vs a fresh fetch.
pub struct ColumnServe {
    pub cols: Vec<(ColumnId, Arc<SharedColumn>)>,
    pub hits: usize,
    /// Storage bytes the cached columns avoided re-reading.
    pub saved_bytes: u64,
    pub fetched_cols: usize,
    pub fetched_bytes: u64,
    pub extents: usize,
    pub ios: usize,
}

type ColKey = (StripeKey, ColumnId);

struct ColEntry {
    payload: Arc<SharedColumn>,
    /// Storage bytes this column's fetch paid (a hit saves these).
    io_bytes: u64,
    mem_bytes: u64,
    last_used: u64,
    charged: bool,
}

enum ColSlot {
    Loading,
    Ready(ColEntry),
}

struct ColState {
    entries: HashMap<ColKey, ColSlot>,
    tick: u64,
}

/// Budget-bounded map of decoded *columns*: the column-grain sibling of
/// [`StripeBuffer`]. A session's projection is served from any wider
/// cached decode — sessions with different projections, predicates, or
/// epochs hit the same column entries. Eviction is popularity-aware:
/// victims are the coldest (lowest live per-feature demand) unpinned
/// columns, LRU among equals, and a column never evicts one hotter than
/// itself.
pub struct ColumnBuffer {
    state: Mutex<ColState>,
    cv: Condvar,
    budget: Arc<MemoryBudget>,
    pub evictions: Counter,
}

impl ColumnBuffer {
    pub fn new(budget: Arc<MemoryBudget>) -> ColumnBuffer {
        ColumnBuffer {
            state: Mutex::new(ColState {
                entries: HashMap::new(),
                tick: 0,
            }),
            cv: Condvar::new(),
            budget,
            evictions: Counter::new(),
        }
    }

    pub fn budget(&self) -> &Arc<MemoryBudget> {
        &self.budget
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.state, "column buffer").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serve one stripe's `needed` columns: cached columns are returned
    /// directly, missing ones are fetched exactly once fleet-wide (the
    /// fetch closure receives only the still-missing subset, so a serve
    /// overlapping an in-flight load fetches just its private columns
    /// and waits for the shared ones). `remaining` counts the *other*
    /// registered serves still expected for this stripe — at zero, all
    /// of the stripe's cached columns are dropped after this serve.
    /// `demand` supplies the live per-column popularity used for
    /// admission and eviction order.
    pub fn serve<F>(
        &self,
        key: StripeKey,
        needed: &[ColumnId],
        remaining: usize,
        demand: &dyn Fn(ColumnId) -> f64,
        mut fetch: F,
    ) -> Result<ColumnServe>
    where
        F: FnMut(&[ColumnId]) -> Result<FetchedColumns>,
    {
        let mut acquired: HashMap<ColumnId, Arc<SharedColumn>> =
            HashMap::new();
        let mut hits = 0usize;
        let mut saved_bytes = 0u64;
        let mut fetched_cols = 0usize;
        let mut fetched_bytes = 0u64;
        let mut extents = 0usize;
        let mut ios = 0usize;
        let mut st = lock_or_recover(&self.state, "column buffer");
        loop {
            let mut missing: Vec<ColumnId> = Vec::new();
            let mut loading = false;
            st.tick += 1;
            let tick = st.tick;
            for &c in needed {
                if acquired.contains_key(&c) {
                    continue;
                }
                match st.entries.get_mut(&(key, c)) {
                    Some(ColSlot::Ready(e)) => {
                        e.last_used = tick;
                        hits += 1;
                        saved_bytes += e.io_bytes;
                        acquired.insert(c, e.payload.clone());
                    }
                    Some(ColSlot::Loading) => loading = true,
                    None => missing.push(c),
                }
            }
            if !missing.is_empty() {
                for &c in &missing {
                    st.entries.insert((key, c), ColSlot::Loading);
                }
                drop(st);
                // Same unwind discipline as the stripe path: the guard
                // clears every Loading slot this serve claimed and wakes
                // waiters on fetch error or panic.
                let mut cleanup = ColLoadGuard {
                    buf: self,
                    key,
                    cols: missing.clone(),
                    armed: true,
                };
                let got = fetch(&missing)?;
                let mut locked =
                    lock_or_recover(&self.state, "column buffer");
                cleanup.armed = false;
                fetched_bytes += got.fetched_bytes;
                extents += got.extents;
                ios += got.ios;
                for (c, col, io_bytes) in got.cols {
                    let payload = Arc::new(col);
                    let mem = payload.mem_bytes();
                    let charged = remaining > 0
                        && self.reserve_evicting(
                            &mut locked,
                            mem,
                            demand(c),
                            demand,
                        );
                    if charged {
                        locked.tick += 1;
                        let t = locked.tick;
                        locked.entries.insert(
                            (key, c),
                            ColSlot::Ready(ColEntry {
                                payload: payload.clone(),
                                io_bytes,
                                mem_bytes: mem,
                                last_used: t,
                                charged: true,
                            }),
                        );
                    } else {
                        locked.entries.remove(&(key, c));
                    }
                    fetched_cols += 1;
                    acquired.insert(c, payload);
                }
                // Defensive: a fetch that returned fewer columns than
                // asked must not strand Loading slots.
                for &c in &missing {
                    if matches!(
                        locked.entries.get(&(key, c)),
                        Some(ColSlot::Loading)
                    ) {
                        locked.entries.remove(&(key, c));
                    }
                }
                self.check_accounting(&locked);
                st = locked;
                self.cv.notify_all();
                continue;
            }
            if loading {
                st = wait_or_recover(&self.cv, st, "column buffer");
                continue;
            }
            break;
        }
        if remaining == 0 {
            // Last registered session for this stripe: free all of its
            // cached columns now (in-flight loads are left alone).
            let gone: Vec<ColKey> = st
                .entries
                .iter()
                .filter(|((sk, _), slot)| {
                    *sk == key && matches!(slot, ColSlot::Ready(_))
                })
                .map(|(k, _)| *k)
                .collect();
            for k in gone {
                if let Some(ColSlot::Ready(e)) = st.entries.remove(&k) {
                    if e.charged {
                        self.budget.release(e.mem_bytes);
                    }
                }
            }
        }
        self.check_accounting(&st);
        drop(st);
        let cols = needed
            .iter()
            .filter_map(|c| acquired.get(c).map(|p| (*c, p.clone())))
            .collect();
        Ok(ColumnServe {
            cols,
            hits,
            saved_bytes,
            fetched_cols,
            fetched_bytes,
            extents,
            ios,
        })
    }

    /// Drop every cached column of one stripe (its last registered
    /// session went away without consuming it).
    pub fn release_stripe(&self, key: StripeKey) {
        let mut st = lock_or_recover(&self.state, "column buffer");
        let gone: Vec<ColKey> = st
            .entries
            .iter()
            .filter(|((sk, _), slot)| {
                *sk == key && matches!(slot, ColSlot::Ready(_))
            })
            .map(|(k, _)| *k)
            .collect();
        for k in gone {
            if let Some(ColSlot::Ready(e)) = st.entries.remove(&k) {
                if e.charged {
                    self.budget.release(e.mem_bytes);
                }
            }
        }
        self.check_accounting(&st);
    }

    /// Same invariant as [`StripeBuffer::check_accounting`], at column
    /// grain.
    #[cfg(any(debug_assertions, loom))]
    fn check_accounting(&self, st: &ColState) {
        let charged: u64 = st
            .entries
            .values()
            .map(|s| match s {
                ColSlot::Ready(e) if e.charged => e.mem_bytes,
                _ => 0,
            })
            .sum();
        let used = self.budget.used();
        assert!(
            charged <= used,
            "column buffer charged {charged} bytes > budget used {used}"
        );
        assert!(
            used <= self.budget.total(),
            "budget used {used} > total {}",
            self.budget.total()
        );
    }

    #[cfg(not(any(debug_assertions, loom)))]
    fn check_accounting(&self, _st: &ColState) {}

    /// Reserve `bytes`, evicting the coldest unpinned columns first
    /// (lowest live demand, LRU among equals). Stops — and declines the
    /// reservation — when the cheapest victim is hotter than the column
    /// being admitted: popular columns are never displaced by unpopular
    /// ones.
    fn reserve_evicting(
        &self,
        st: &mut ColState,
        bytes: u64,
        incoming_demand: f64,
        demand: &dyn Fn(ColumnId) -> f64,
    ) -> bool {
        loop {
            if self.budget.try_reserve(bytes) {
                return true;
            }
            let victim = st
                .entries
                .iter()
                .filter_map(|(k, slot)| match slot {
                    ColSlot::Ready(e)
                        if e.charged
                            && Arc::strong_count(&e.payload) == 1 =>
                    {
                        Some((*k, demand(k.1), e.last_used))
                    }
                    _ => None,
                })
                .min_by(|a, b| {
                    a.1.partial_cmp(&b.1)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.2.cmp(&b.2))
                });
            let Some((k, victim_demand, _)) = victim else {
                return false;
            };
            if victim_demand > incoming_demand {
                return false;
            }
            if let Some(ColSlot::Ready(e)) = st.entries.remove(&k) {
                self.budget.release(e.mem_bytes);
                self.evictions.inc();
            }
        }
    }
}

/// Unwind guard for the un-locked fetch window of
/// [`ColumnBuffer::serve`]: clears every Loading slot the serve claimed
/// and wakes waiters, so neither a fetch `Err` nor a panic strands
/// peers.
struct ColLoadGuard<'a> {
    buf: &'a ColumnBuffer,
    key: StripeKey,
    cols: Vec<ColumnId>,
    armed: bool,
}

impl Drop for ColLoadGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            let mut st =
                lock_or_recover(&self.buf.state, "column load cleanup");
            for &c in &self.cols {
                if matches!(
                    st.entries.get(&(self.key, c)),
                    Some(ColSlot::Loading)
                ) {
                    st.entries.remove(&(self.key, c));
                }
            }
            drop(st);
            self.buf.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ColumnarBatch;

    fn stripe_of(bytes: usize) -> SharedStripe {
        // approx_bytes counts labels at 4 bytes each.
        SharedStripe::Columnar(ColumnarBatch {
            num_rows: bytes / 4,
            labels: vec![0.0; bytes / 4],
            ..Default::default()
        })
    }

    fn fetched(bytes: usize) -> FetchedStripe {
        FetchedStripe {
            stripe: stripe_of(bytes),
            proj: HashSet::new(),
            fetched_bytes: bytes as u64,
            extents: 4,
            ios: 1,
        }
    }

    fn key(f: u64, s: usize) -> StripeKey {
        (FileId(f), s)
    }

    #[test]
    fn budget_reserve_release() {
        let b = MemoryBudget::new(100);
        assert!(b.try_reserve(60));
        assert!(!b.try_reserve(50));
        assert!(b.try_reserve(40));
        assert_eq!(b.used(), 100);
        b.release(70);
        assert_eq!(b.used(), 30);
        // Over-release saturates instead of wrapping.
        b.release(1000);
        assert_eq!(b.used(), 0);
        assert!(!b.try_reserve(101), "never exceeds total");
        assert_eq!(b.total(), 100);
    }

    #[test]
    fn serve_caches_then_hits_then_releases() {
        let buf = StripeBuffer::new(MemoryBudget::new(1 << 20));
        let out = buf
            .serve(key(1, 0), &[], 1, || Ok(fetched(400)))
            .unwrap();
        assert!(matches!(out, ServeOutcome::Fetched { .. }));
        assert_eq!(buf.len(), 1);
        assert_eq!(buf.budget().used(), 400);
        // Second (last) interested serve hits and frees the entry.
        let out = buf
            .serve(key(1, 0), &[], 0, || panic!("must not refetch"))
            .unwrap();
        match out {
            ServeOutcome::Hit { saved_bytes, .. } => {
                assert_eq!(saved_bytes, 400)
            }
            _ => panic!("expected hit"),
        }
        assert!(buf.is_empty());
        assert_eq!(buf.budget().used(), 0);
    }

    #[test]
    fn last_consumer_not_cached() {
        let buf = StripeBuffer::new(MemoryBudget::new(1 << 20));
        let out = buf
            .serve(key(1, 0), &[], 0, || Ok(fetched(400)))
            .unwrap();
        assert!(matches!(out, ServeOutcome::Fetched { .. }));
        assert!(buf.is_empty(), "no other session wants it");
        assert_eq!(buf.budget().used(), 0);
    }

    #[test]
    fn lru_eviction_under_pressure_skips_pinned() {
        let buf = StripeBuffer::new(MemoryBudget::new(1000));
        // A: cached and immediately dropped by the caller (unpinned).
        let a = buf
            .serve(key(1, 0), &[], 2, || Ok(fetched(600)))
            .unwrap();
        drop(a);
        // B: would not fit next to A → A is evicted.
        let _b = buf
            .serve(key(1, 1), &[], 2, || Ok(fetched(600)))
            .unwrap();
        assert_eq!(buf.evictions.get(), 1);
        assert_eq!(buf.len(), 1);
        assert_eq!(buf.budget().used(), 600);
        // C: B's payload is still held by `_b` (pinned) → nothing to
        // evict, C is served uncached.
        let c = buf
            .serve(key(1, 2), &[], 2, || Ok(fetched(600)))
            .unwrap();
        assert!(matches!(c, ServeOutcome::Fetched { .. }));
        assert_eq!(buf.len(), 1, "pinned entry survives, C uncached");
        assert_eq!(buf.budget().used(), 600);
    }

    #[test]
    fn fetch_error_clears_loading_slot() {
        let buf = StripeBuffer::new(MemoryBudget::new(1 << 20));
        let err = buf.serve(key(2, 0), &[], 1, || {
            anyhow::bail!("storage down")
        });
        assert!(err.is_err());
        assert!(buf.is_empty());
        // A later serve retries cleanly.
        let ok = buf
            .serve(key(2, 0), &[], 1, || Ok(fetched(40)))
            .unwrap();
        assert!(matches!(ok, ServeOutcome::Fetched { .. }));
        assert_eq!(buf.len(), 1);
    }

    #[test]
    fn fetch_panic_clears_loading_slot_and_wakes_waiters() {
        use std::sync::Barrier;
        let buf = Arc::new(StripeBuffer::new(MemoryBudget::new(1 << 20)));
        let gate = Arc::new(Barrier::new(2));
        // Loader: panics mid-fetch (a worker dying mid-decode) after a
        // waiter has had time to park on the Loading slot.
        let loader = {
            let buf = buf.clone();
            let gate = gate.clone();
            std::thread::spawn(move || {
                let _ = buf.serve(key(9, 0), &[], 1, || {
                    gate.wait();
                    panic!("decode blew up");
                });
            })
        };
        gate.wait();
        // Waiter: without the unwind guard this serve would block
        // forever on a Loading slot no one will ever fill; with it, the
        // waiter retries and pays the fetch itself.
        let out = buf
            .serve(key(9, 0), &[], 1, || Ok(fetched(40)))
            .unwrap();
        assert!(matches!(out, ServeOutcome::Fetched { .. }));
        assert!(loader.join().is_err(), "loader should have panicked");
        assert_eq!(buf.len(), 1);
        assert_eq!(buf.budget().used(), 40);
    }

    fn col_of(bytes: usize) -> SharedColumn {
        // Meta counts labels at 4 bytes each.
        SharedColumn::Meta {
            labels: vec![0.0; bytes / 4],
            timestamps: Vec::new(),
            inverse: None,
            col_rows: bytes / 4,
        }
    }

    fn fetched_cols(
        ids: &[ColumnId],
        bytes_each: usize,
    ) -> FetchedColumns {
        FetchedColumns {
            cols: ids
                .iter()
                .map(|&c| (c, col_of(bytes_each), bytes_each as u64))
                .collect(),
            fetched_bytes: (ids.len() * bytes_each) as u64,
            extents: ids.len(),
            ios: 1,
        }
    }

    fn feat(id: u32) -> ColumnId {
        ColumnId::Feature(crate::schema::FeatureId(id))
    }

    const FLAT: &dyn Fn(ColumnId) -> f64 = &|_| 1.0;

    #[test]
    fn budget_tracks_peak() {
        let b = MemoryBudget::new(100);
        assert!(b.try_reserve(60));
        b.release(60);
        assert!(b.try_reserve(30));
        assert_eq!(b.used(), 30);
        assert_eq!(b.peak(), 60, "peak survives release");
    }

    #[test]
    fn column_serve_hits_wider_cached_decode() {
        let buf = ColumnBuffer::new(MemoryBudget::new(1 << 20));
        // Session A decodes Meta + features 1,2.
        let wide = [ColumnId::Meta, feat(1), feat(2)];
        let out = buf
            .serve(key(1, 0), &wide, 2, FLAT, |miss| {
                Ok(fetched_cols(miss, 400))
            })
            .unwrap();
        assert_eq!(out.fetched_cols, 3);
        assert_eq!(out.hits, 0);
        assert_eq!(buf.len(), 3);
        // Session B projects {2, 3}: hits Meta + 2 from A's wider
        // decode, fetches only 3.
        let narrow = [ColumnId::Meta, feat(2), feat(3)];
        let out = buf
            .serve(key(1, 0), &narrow, 1, FLAT, |miss| {
                assert_eq!(miss, &[feat(3)]);
                Ok(fetched_cols(miss, 400))
            })
            .unwrap();
        assert_eq!(out.hits, 2);
        assert_eq!(out.saved_bytes, 800);
        assert_eq!(out.fetched_cols, 1);
        assert_eq!(out.cols.len(), 3);
    }

    #[test]
    fn column_last_consumer_frees_stripe() {
        let buf = ColumnBuffer::new(MemoryBudget::new(1 << 20));
        let cols = [ColumnId::Meta, feat(1)];
        buf.serve(key(1, 0), &cols, 1, FLAT, |m| {
            Ok(fetched_cols(m, 100))
        })
        .unwrap();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf.budget().used(), 200);
        // Last interested serve: the whole stripe's columns drop.
        let out = buf
            .serve(key(1, 0), &cols, 0, FLAT, |_| {
                panic!("must not refetch")
            })
            .unwrap();
        assert_eq!(out.hits, 2);
        assert!(buf.is_empty());
        assert_eq!(buf.budget().used(), 0);
    }

    #[test]
    fn column_eviction_prefers_cold_columns() {
        let buf = ColumnBuffer::new(MemoryBudget::new(1000));
        let demand = |c: ColumnId| match c {
            ColumnId::Feature(f) => f.0 as f64,
            ColumnId::Meta => 100.0,
        };
        // Hot feature 9 and cold feature 1, both unpinned.
        drop(
            buf.serve(key(1, 0), &[feat(9)], 2, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        drop(
            buf.serve(key(1, 0), &[feat(1)], 2, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        // Feature 5 needs room: the cold column (1) goes, the hot one
        // (9) stays.
        drop(
            buf.serve(key(1, 1), &[feat(5)], 2, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        assert_eq!(buf.evictions.get(), 1);
        let st = lock_or_recover(&buf.state, "test");
        assert!(st.entries.contains_key(&(key(1, 0), feat(9))));
        assert!(!st.entries.contains_key(&(key(1, 0), feat(1))));
        drop(st);
        // A colder column (0) cannot displace hotter residents: served
        // uncached instead.
        drop(
            buf.serve(key(1, 2), &[feat(0)], 2, &demand, |m| {
                Ok(fetched_cols(m, 400))
            })
            .unwrap(),
        );
        assert_eq!(buf.evictions.get(), 1, "no further eviction");
        assert_eq!(buf.len(), 2, "feat 0 not admitted");
    }

    #[test]
    fn column_release_stripe_frees_budget() {
        let buf = ColumnBuffer::new(MemoryBudget::new(1 << 20));
        buf.serve(key(3, 0), &[ColumnId::Meta, feat(1)], 5, FLAT, |m| {
            Ok(fetched_cols(m, 800))
        })
        .unwrap();
        buf.serve(key(3, 1), &[ColumnId::Meta], 5, FLAT, |m| {
            Ok(fetched_cols(m, 800))
        })
        .unwrap();
        assert_eq!(buf.budget().used(), 2400);
        buf.release_stripe(key(3, 0));
        assert_eq!(buf.budget().used(), 800, "other stripe survives");
        assert_eq!(buf.len(), 1);
        // Releasing a missing stripe is a no-op.
        buf.release_stripe(key(3, 9));
    }

    #[test]
    fn column_fetch_error_clears_loading_slots() {
        let buf = ColumnBuffer::new(MemoryBudget::new(1 << 20));
        let cols = [ColumnId::Meta, feat(1)];
        let err = buf.serve(key(2, 0), &cols, 1, FLAT, |_| {
            anyhow::bail!("storage down")
        });
        assert!(err.is_err());
        assert!(buf.is_empty());
        let ok = buf
            .serve(key(2, 0), &cols, 1, FLAT, |m| {
                Ok(fetched_cols(m, 40))
            })
            .unwrap();
        assert_eq!(ok.fetched_cols, 2);
        assert_eq!(buf.len(), 2);
    }

    #[test]
    fn release_frees_budget() {
        let buf = StripeBuffer::new(MemoryBudget::new(1 << 20));
        let out = buf
            .serve(key(3, 0), &[], 5, || Ok(fetched(800)))
            .unwrap();
        drop(out);
        assert_eq!(buf.budget().used(), 800);
        buf.release(key(3, 0));
        assert_eq!(buf.budget().used(), 0);
        assert!(buf.is_empty());
        // Releasing a missing key is a no-op.
        buf.release(key(3, 1));
    }
}
